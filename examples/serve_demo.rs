//! Serving demo: quantize a model, start the TCP inference server, and
//! drive it with a batch of client requests, reporting latency stats.
//!
//!     cargo run --release --example serve_demo
//!
//! The PJRT client is not Send, so the server owns the main thread and
//! the demo client runs on a worker thread — exactly the deployment shape
//! of the real binary (`faar serve`).

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::Result;

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::Tokenizer;
use nvfp4_faar::pipeline::{Method, Workbench};
use nvfp4_faar::serve::Generator;
use nvfp4_faar::util::{json::Json, stats};

const N_REQUESTS: usize = 8;

fn main() -> Result<()> {
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    cfg.pretrain_steps = 300;
    cfg.stage1_steps = 40;
    cfg.stage2_steps = 0; // FAAR stage-1 only: fast demo

    let wb = Workbench::open(cfg)?;
    let outcome = wb.quantize(Method::Faar)?;
    let generator = Generator::new(&wb.rt, outcome.params.clone());
    let vocab = wb.rt.config().vocab;

    let addr = "127.0.0.1:7746";
    // client thread: waits for the listener, fires N requests, collects latency
    let client = std::thread::spawn(move || -> Result<Vec<f64>> {
        let tok = Tokenizer::new(vocab);
        let mut latencies = vec![];
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
            }
        };
        let mut reader = BufReader::new(stream.try_clone()?);
        for i in 0..N_REQUESTS {
            let prompt = tok.decode(&[(i as i32 * 13) % vocab as i32, 5, 9, 2]);
            let req = Json::obj(vec![
                ("prompt", Json::str(prompt.as_str())),
                ("max_tokens", Json::num(12.0)),
            ]);
            stream.write_all(req.to_string().as_bytes())?;
            stream.write_all(b"\n")?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let resp = Json::parse(&line)?;
            if let Some(err) = resp.get("error") {
                anyhow::bail!("server error: {err:?}");
            }
            let ms = resp.req("latency_ms")?.as_f64()?;
            println!(
                "  req {i}: {:>6.1} ms   \"{}\" → \"{}\"",
                ms,
                prompt,
                resp.req("text")?.as_str()?
            );
            latencies.push(ms);
        }
        Ok(latencies)
    });

    // server owns the main thread; exits after one connection closes
    generator.serve(addr, Some(1))?;

    let latencies = client.join().expect("client thread panicked")?;
    println!(
        "\nserved {} requests: mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms per 12-token completion",
        latencies.len(),
        stats::mean(&latencies),
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 95.0),
    );
    Ok(())
}
