//! Serving demo: quantize a model, start the concurrent batched TCP
//! inference server, and drive it with several interleaved clients,
//! reporting latency stats.
//!
//!     cargo run --release --example serve_demo
//!
//! The PJRT client is not Send, so the scheduler owns the main thread
//! and the demo clients run on worker threads — exactly the deployment
//! shape of the real binary (`faar serve`). Requests from all clients
//! are micro-batched into shared decode steps (`--max-batch` worth per
//! scheduler tick); per-connection responses still arrive in request
//! order.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::Tokenizer;
use nvfp4_faar::pipeline::{Method, Workbench};
use nvfp4_faar::serve::client::{Client, ClientRequest};
use nvfp4_faar::serve::{Generator, ServeOptions};
use nvfp4_faar::util::stats;

const N_CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 4;
const MAX_TOKENS: usize = 12;

fn client(addr: &str, id: usize, vocab: usize) -> Result<Vec<f64>> {
    let tok = Tokenizer::new(vocab);
    let mut latencies = vec![];
    // retry until the server thread has bound the listener
    let mut cl = loop {
        match Client::connect_timeout(addr, std::time::Duration::from_secs(120)) {
            Ok(c) => break c,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    };
    for i in 0..REQS_PER_CLIENT {
        let prompt = tok.decode(&[((id * 7 + i * 13) % vocab) as i32, 5, 9, 2]);
        let req = ClientRequest::text(prompt.as_str()).max_tokens(MAX_TOKENS);
        let resp = cl
            .request(&req)?
            .map_err(|e| anyhow::anyhow!("server error: {}: {}", e.code, e.message))?;
        println!(
            "  client {id} req {i}: {:>6.1} ms   \"{}\" → \"{}\"",
            resp.latency_ms, prompt, resp.text
        );
        latencies.push(resp.latency_ms);
    }
    Ok(latencies)
}

fn main() -> Result<()> {
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    cfg.pretrain_steps = 300;
    cfg.stage1_steps = 40;
    cfg.stage2_steps = 0; // FAAR stage-1 only: fast demo

    let wb = Workbench::open(cfg)?;
    let outcome = wb.quantize(Method::Faar)?;
    let generator = Generator::new(&wb.rt, outcome.params.clone());
    let vocab = wb.rt.config().vocab;

    let addr = "127.0.0.1:7746";
    // interleaved clients: each fires a ping-pong request stream; the
    // scheduler micro-batches across all of them
    let clients: Vec<_> = (0..N_CLIENTS)
        .map(|id| std::thread::spawn(move || client(addr, id, vocab)))
        .collect();

    // scheduler owns the main thread; exits once all demo clients drain
    let opts = ServeOptions { max_batch: N_CLIENTS, ..ServeOptions::default() };
    let t0 = std::time::Instant::now();
    let sched = generator.serve_with(addr, Some(N_CLIENTS), opts)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies = vec![];
    for c in clients {
        latencies.extend(c.join().expect("client thread panicked")?);
    }
    let total_tokens = (latencies.len() * MAX_TOKENS) as f64;
    println!(
        "\nserved {} requests from {N_CLIENTS} clients: mean {:.1} ms  p50 {:.1} ms  \
         p95 {:.1} ms per {MAX_TOKENS}-token completion",
        latencies.len(),
        stats::mean(&latencies),
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 95.0),
    );
    println!(
        "throughput {:.0} tok/s over {:.2}s; scheduler: {} steps, {} batched (peak batch {})",
        total_tokens / wall,
        wall,
        sched.steps,
        sched.batched_steps,
        sched.peak_batch,
    );
    Ok(())
}
