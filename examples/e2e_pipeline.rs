//! End-to-end validation driver (the EXPERIMENTS.md §E2E run).
//!
//! Exercises every layer of the stack on a real small workload:
//!
//!   1. pretrain a transformer LM from scratch on the synthetic corpus
//!      mixture, through the AOT `pretrain_step` XLA graph (loss curve
//!      logged to results/e2e/loss_curve.json),
//!   2. capture calibration activations from the frozen checkpoint,
//!   3. quantize with RTN (baseline) and FAAR+2FA (full method —
//!      stage-1 Pallas soft-quant jobs + stage-2 global alignment),
//!   4. harden + pack true `.nvfp4` payloads,
//!   5. evaluate PPL / hidden-cosine on both corpora + all four zero-shot
//!      probes, and write the headline comparison to results/e2e/.
//!
//!     cargo run --release --example e2e_pipeline [-- --model tiny]

#![allow(clippy::field_reassign_with_default)]

use std::path::PathBuf;

use anyhow::Result;

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::tasks::TaskKind;
use nvfp4_faar::pipeline::{pack_model, Method, Workbench};
use nvfp4_faar::util::{cli::Args, json::Json, stats};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let mut cfg = PipelineConfig::default();
    cfg.model = "tiny".into();
    cfg.pretrain_steps = 600;
    cfg.apply_args(&args)?;
    let out_dir = PathBuf::from(&cfg.out_dir).join("e2e");
    std::fs::create_dir_all(&out_dir)?;

    let t0 = std::time::Instant::now();
    println!("=== E2E: model={} ===", cfg.model);
    println!("[1/5] pretrain (or load cached checkpoint) + calibration capture");
    let wb = Workbench::open(cfg)?;
    println!(
        "      checkpoint: {} params",
        wb.fp.total_params()
    );

    let mut records = vec![];
    let mut faar_packed_mib = 0.0;
    for method in [Method::Bf16, Method::Rtn, Method::Faar2fa] {
        println!("[2/5] quantize: {}", method.name());
        let outcome = wb.quantize(method)?;
        println!("      done in {:.1}s", outcome.wall_s);

        if outcome.faar.is_some() {
            println!("[3/5] harden + pack .nvfp4 payloads");
            let dir = out_dir.join("packed_faar2fa");
            let bytes = pack_model(&wb.rt, &outcome.params, &dir)?;
            faar_packed_mib = bytes as f64 / (1 << 20) as f64;
            let fp_mib = (wb.fp.total_params() * 4) as f64 / (1 << 20) as f64;
            println!(
                "      packed {:.2} MiB vs fp32 {:.2} MiB ({:.1}x compression)",
                faar_packed_mib,
                fp_mib,
                fp_mib / faar_packed_mib
            );
        }

        println!("[4/5] evaluate: PPL + cosine on both corpora, 4 probe suites");
        let wiki = wb.lm_metrics(&outcome, "wiki")?;
        let c4 = wb.lm_metrics(&outcome, "c4")?;
        let mut accs = vec![];
        for k in TaskKind::all() {
            accs.push(wb.task_accuracy(&outcome, k, 120)?);
        }
        let avg = stats::mean(&accs);
        println!(
            "      {:<10} wiki ppl {:.3} cos {:.2}% | c4 ppl {:.3} cos {:.2}% | tasks avg {:.1}%",
            method.name(),
            wiki.ppl,
            wiki.cosine_pct,
            c4.ppl,
            c4.cosine_pct,
            avg
        );
        records.push(Json::obj(vec![
            ("method", Json::str(method.name())),
            ("wiki_ppl", Json::Num(wiki.ppl)),
            ("wiki_cos_pct", Json::Num(wiki.cosine_pct)),
            ("c4_ppl", Json::Num(c4.ppl)),
            ("c4_cos_pct", Json::Num(c4.cosine_pct)),
            (
                "task_acc_pct",
                Json::Arr(accs.iter().map(|&a| Json::Num(a)).collect()),
            ),
            ("task_avg_pct", Json::Num(avg)),
            ("quantize_wall_s", Json::Num(outcome.wall_s)),
        ]));
    }

    println!("[5/5] write results/e2e/summary.json");
    let doc = Json::obj(vec![
        ("model", Json::str(wb.cfg.model.as_str())),
        ("config", wb.cfg.to_json()),
        ("packed_mib", Json::Num(faar_packed_mib)),
        ("total_wall_s", Json::Num(t0.elapsed().as_secs_f64())),
        ("methods", Json::Arr(records)),
    ]);
    std::fs::write(out_dir.join("summary.json"), doc.to_string_pretty())?;
    println!(
        "=== E2E complete in {:.0}s → {}/summary.json ===",
        t0.elapsed().as_secs_f64(),
        out_dir.display()
    );
    Ok(())
}
