//! L1 kernel parity: Pallas soft-quant vs the jnp oracle vs the rust
//! codec, all on the same random weights, executed through the real AOT
//! artifacts. This is the cross-language bit-faithfulness check for the
//! whole NVFP4 numerics stack, plus a latency comparison.
//!
//!     cargo run --release --example kernel_parity

use std::path::Path;

use anyhow::Result;

use nvfp4_faar::formats::nvfp4;
use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::rng::Rng;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"), "nano")?;
    let d = rt.config().d_model;
    let mut rng = Rng::new(7);
    let mut w = Tensor::zeros(&[d, d]);
    rng.fill_normal(&mut w.data, 0.0, 0.05);

    // rust-side preparation (scale / interval / v_init)
    let p = nvfp4::prepare(&w);
    let beta = 12.0f32;

    let args = vec![
        Value::F32(w.clone()),
        Value::F32(p.lower.clone()),
        Value::F32(p.upper.clone()),
        Value::F32(p.scale.clone()),
        Value::F32(p.v_init.clone()),
        Value::scalar_f32(beta),
    ];

    println!("soft-quant parity on [{d}, {d}] weights:");
    let pallas = rt.exec("kernel_softquant", &args)?[0].as_tensor()?.clone();
    let jnp = rt.exec("kernel_softquant_jnp", &args)?[0].as_tensor()?.clone();
    println!("  pallas vs jnp     max |Δ| = {:.3e}", max_abs_diff(&pallas.data, &jnp.data));

    // rust reference of the same formula
    let mut rust = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let h = 1.0 / (1.0 + (-beta * (p.v_init.data[i] - 0.5)).exp());
        rust[i] = nvfp4::sign(w.data[i])
            * (p.lower.data[i] + h * (p.upper.data[i] - p.lower.data[i]))
            * p.scale.data[i];
    }
    println!("  pallas vs rust    max |Δ| = {:.3e}", max_abs_diff(&pallas.data, &rust));

    // RTN path: artifact computes scales in-graph; rust codec end to end
    let rtn_art = rt.exec("kernel_rtn", &[Value::F32(w.clone())])?[0].as_tensor()?.clone();
    let rtn_rust = nvfp4::rtn_quant(&w, &p);
    println!("  rtn artifact vs rust codec max |Δ| = {:.3e}",
             max_abs_diff(&rtn_art.data, &rtn_rust.data));

    // latency comparison (interpret-mode pallas vs fused jnp lowering)
    for name in ["kernel_softquant", "kernel_softquant_jnp"] {
        let t0 = std::time::Instant::now();
        let iters = 50;
        for _ in 0..iters {
            rt.exec(name, &args)?;
        }
        println!(
            "  {name}: {:.3} ms/exec",
            t0.elapsed().as_secs_f64() * 1e3 / iters as f64
        );
    }

    assert!(max_abs_diff(&pallas.data, &jnp.data) < 2e-6);
    assert!(max_abs_diff(&pallas.data, &rust) < 1e-5);
    // RTN recomputes scales + FindInterval in-graph; XLA's folded
    // reciprocals flip rare boundary elements one node over (see
    // tests/integration_runtime.rs) — semantic contract: <1% differ.
    let rtn_mismatch = rtn_art
        .data
        .iter()
        .zip(&rtn_rust.data)
        .filter(|(a, b)| (*a - *b).abs() > 1e-7)
        .count();
    println!("  rtn boundary flips: {rtn_mismatch}/{}", rtn_art.data.len());
    assert!(rtn_mismatch * 100 < rtn_art.data.len());
    println!("parity OK (tolerances met)");
    Ok(())
}
