//! Artifact latency probe (perf tooling): measures per-exec latency of
//! the stage-1/stage-2/eval graphs for a preset under the current machine
//! load. Used to size table-run schedules (EXPERIMENTS.md §Perf).

use std::path::Path;
use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::rng::Rng;
fn main() {
    let rt = Runtime::load(Path::new("artifacts"), "tiny").unwrap();
    let cfg = rt.config().clone();
    let mut rng = Rng::new(1);
    for (k, n) in rt.manifest.qshapes() {
        let name = format!("stage1_step_{k}x{n}");
        let mut x = Tensor::zeros(&[cfg.stage1_rows, k]); rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut w = Tensor::zeros(&[k, n]); rng.fill_normal(&mut w.data, 0.0, 0.05);
        let p = nvfp4_faar::formats::nvfp4::prepare(&w);
        let args = vec![Value::F32(x), Value::F32(w), Value::F32(p.lower), Value::F32(p.upper),
            Value::F32(p.scale), Value::F32(p.v_init), Value::F32(Tensor::zeros(&[k,n])), Value::F32(Tensor::zeros(&[k,n])),
            Value::scalar_f32(1.0), Value::scalar_f32(10.0), Value::scalar_f32(1e-2), Value::scalar_f32(1e-2)];
        rt.exec(&name, &args).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..20 { rt.exec(&name, &args).unwrap(); }
        println!("{name}: {:.1} ms/exec", t0.elapsed().as_secs_f64()*50.0);
    }
    // stage2
    let spec = rt.manifest.artifact("stage2_step").unwrap().clone();
    let mut args = vec![];
    for ispec in &spec.inputs {
        match ispec.dtype {
            nvfp4_faar::runtime::DType::F32 => {
                let mut t = Tensor::zeros(&ispec.shape);
                if ispec.name.starts_with("upper") || ispec.name.starts_with("scale") { t.data.fill(0.01); }
                if ispec.name.starts_with("v.") { t.data.fill(0.5); }
                args.push(Value::F32(t));
            }
            nvfp4_faar::runtime::DType::I32 => {
                let numel: usize = ispec.shape.iter().product();
                args.push(Value::I32(vec![1; numel], ispec.shape.clone()));
            }
        }
    }
    rt.exec("stage2_step", &args).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..5 { rt.exec("stage2_step", &args).unwrap(); }
    println!("stage2_step: {:.1} ms/exec", t0.elapsed().as_secs_f64()*200.0);
    // eval fwd
    let params = nvfp4_faar::train::ParamStore::init(&rt.manifest, 1);
    let mut a2 = params.values();
    a2.push(Value::I32(vec![1; cfg.eval_batch*(cfg.seq_len+1)], vec![cfg.eval_batch, cfg.seq_len+1]));
    rt.exec("lm_fwd_aq", &a2).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..10 { rt.exec("lm_fwd_aq", &a2).unwrap(); }
    println!("lm_fwd_aq: {:.1} ms/exec", t0.elapsed().as_secs_f64()*100.0);
}
