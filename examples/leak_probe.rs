//! Memory-leak regression probe: executes one artifact thousands of times
//! and reports RSS growth per exec. Guards the execute_b fix in
//! runtime::Value::to_buffer (the xla crate's literal-execute path leaks
//! every input buffer). Expected output: +0.00 KB/exec.

use std::path::Path;
use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::tensor::Tensor;
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for l in s.lines() { if l.starts_with("VmRSS") {
        return l.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0; } }
    0.0
}
fn main() {
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    let w = Value::F32(Tensor::full(&[2, 64, 64], 0.01));
    rt.exec("prepare_64x64", &[w.clone()]).unwrap();
    let base = rss_mb();
    for i in 0..5000 {
        rt.exec("prepare_64x64", &[w.clone()]).unwrap();
        if i % 1000 == 999 { println!("exec {}: RSS {:.1} MB (+{:.2} KB/exec)", i+1, rss_mb(), (rss_mb()-base)*1024.0/(i as f64+1.0)); }
    }
}
