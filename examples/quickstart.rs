//! Quickstart: quantize a model to NVFP4 with RTN vs FAAR and compare.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the `nano` preset with a short schedule so it finishes in well
//! under a minute; the first run pretrains a checkpoint and caches it
//! under results/models/.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::pipeline::{Method, Workbench};

fn main() -> Result<()> {
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    // the rounding problem only bites once the checkpoint is sharp
    // (see EXPERIMENTS.md): train nano to ~convergence (≈1 min once,
    // then cached), short-ish FAAR schedule
    cfg.pretrain_steps = 4000;
    cfg.stage1_steps = 100;
    cfg.stage2_steps = 300;

    // Workbench = runtime + pretrained checkpoint + calibration capture
    let wb = Workbench::open(cfg)?;

    println!("\n{:<16}{:>12}{:>14}", "method", "PPL (wiki)", "cosine (%)");
    for method in [Method::Bf16, Method::Rtn, Method::Faar2fa] {
        let outcome = wb.quantize(method)?;
        let lm = wb.lm_metrics(&outcome, "wiki")?;
        println!("{:<16}{:>12.3}{:>14.2}", method.name(), lm.ppl, lm.cosine_pct);
    }
    println!("\nFAAR+2FA should sit between BF16 and RTN — the learnable");
    println!("rounding recovers part of the NVFP4 quantization loss.");
    Ok(())
}
