//! Property-based tests over the format-codec stack (util::prop — the
//! offline stand-in for proptest). These pin the invariants the whole
//! pipeline leans on, over adversarial input distributions — including
//! the `FormatCodec`/`QuantTensor` contract for all three codecs.

use nvfp4_faar::formats::codec::{self, rtn_decisions, FormatCodec, FormatKind, QuantTensor};
use nvfp4_faar::formats::{e2m1, e4m3, nvfp4};
use nvfp4_faar::quant::rounding::RoundingScheme;
use nvfp4_faar::quant::round_with;
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::prop::{check_msg, gen};

const ALL_KINDS: [FormatKind; 3] = [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1];

fn tensor_from(v: Vec<f32>, cols: usize) -> Tensor {
    let rows = v.len() / cols;
    Tensor::new(v[..rows * cols].to_vec(), vec![rows, cols])
}

#[test]
fn prop_e4m3_roundtrip_idempotent() {
    check_msg(
        "e4m3_idempotent",
        300,
        |rng| gen::f32_wide(rng, 64),
        |xs| {
            for &x in xs {
                let r1 = e4m3::roundtrip(x);
                if r1.is_nan() {
                    continue;
                }
                let r2 = e4m3::roundtrip(r1);
                if r1 != r2 {
                    return Err(format!("{x} -> {r1} -> {r2}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_e4m3_error_bound() {
    check_msg(
        "e4m3_relative_error",
        300,
        |rng| gen::f32_wide(rng, 64),
        |xs| {
            for &x in xs {
                let a = x.abs();
                if !(2.0f32.powi(-6)..448.0).contains(&a) {
                    continue; // normals only
                }
                let r = e4m3::roundtrip(x);
                let rel = (r - x).abs() / a;
                if rel > 1.0 / 16.0 + 1e-6 {
                    return Err(format!("x={x} r={r} rel={rel}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_e2m1_rtn_is_nearest() {
    check_msg(
        "e2m1_nearest",
        500,
        |rng| (0..64).map(|_| rng.range_f64(0.0, 6.0) as f32).collect::<Vec<_>>(),
        |xs| {
            for &x in xs {
                let q = e2m1::decode(e2m1::encode_rtn(x));
                let d = (q - x).abs();
                for &n in &e2m1::NODES {
                    if (n - x).abs() + 1e-6 < d {
                        return Err(format!("x={x}: chose {q}, {n} closer"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prepare_invariants_heavy_tails() {
    check_msg(
        "prepare_invariants",
        60,
        |rng| gen::f32_heavy(rng, 32 * 16),
        |xs| {
            let w = tensor_from(xs.clone(), 16);
            let p = nvfp4::prepare(&w);
            for i in 0..w.numel() {
                let (lo, up, s, v) =
                    (p.lower.data[i], p.upper.data[i], p.scale.data[i], p.v_init.data[i]);
                if !(lo <= up) {
                    return Err(format!("i={i}: lo {lo} > up {up}"));
                }
                if !(0.0..=1.0).contains(&v) || !v.is_finite() {
                    return Err(format!("i={i}: v_init {v}"));
                }
                if !s.is_finite() || s < 0.0 {
                    return Err(format!("i={i}: scale {s}"));
                }
                // wt must sit inside [lo, up] modulo the saturation clamp
                if s > 0.0 {
                    let wt = (w.data[i].abs() / s).min(6.0);
                    if wt < lo - 1e-4 || wt > up + 1e-4 {
                        return Err(format!("i={i}: wt {wt} outside [{lo}, {up}]"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rtn_error_never_above_alternatives() {
    check_msg(
        "rtn_optimal_pointwise",
        40,
        |rng| gen::f32_normal(rng, 32 * 16, 0.05),
        |xs| {
            let w = tensor_from(xs.clone(), 16);
            let p = nvfp4::prepare(&w);
            let q_rtn = round_with(&w, &p, RoundingScheme::Rtn);
            let q_lo = round_with(&w, &p, RoundingScheme::Lower);
            let q_up = round_with(&w, &p, RoundingScheme::Upper);
            for i in 0..w.numel() {
                let e = (q_rtn.data[i] - w.data[i]).abs();
                let e_lo = (q_lo.data[i] - w.data[i]).abs();
                let e_up = (q_up.data[i] - w.data[i]).abs();
                if e > e_lo + 1e-6 || e > e_up + 1e-6 {
                    return Err(format!("i={i}: rtn {e} vs lo {e_lo} up {e_up}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_roundtrip_arbitrary_decisions() {
    check_msg(
        "pack_roundtrip",
        40,
        |rng| {
            let w = gen::f32_heavy(rng, 32 * 16);
            let v: Vec<f32> = (0..32 * 16).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            (w, v)
        },
        |(wv, vv)| {
            let w = tensor_from(wv.clone(), 16);
            let v = tensor_from(vv.clone(), 16);
            let p = nvfp4::prepare(&w);
            let expect = nvfp4::hard_quant(&w, &p, &v);
            let packed = nvfp4::PackedTensor::pack(&w, &p, &v);
            let back = nvfp4::PackedTensor::from_bytes(&packed.to_bytes())
                .map_err(|e| e.to_string())?;
            let deq = back.unpack();
            for i in 0..w.numel() {
                let d = (deq.data[i] - expect.data[i]).abs();
                let tol = 1e-6 * expect.data[i].abs().max(1e-5);
                if d > tol {
                    return Err(format!("i={i}: {} vs {}", deq.data[i], expect.data[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_tensor_roundtrip_all_codecs() {
    // pack → to_bytes → from_bytes → dequantize equals hard_quant, for
    // every codec, under arbitrary binary decisions (K=64 satisfies both
    // the 16- and 32-element block constraints)
    for kind in ALL_KINDS {
        let c = codec::codec_for(kind);
        check_msg(
            &format!("qt_roundtrip_{}", c.name()),
            30,
            |rng| {
                let w = gen::f32_heavy(rng, 64 * 16);
                let v: Vec<f32> =
                    (0..64 * 16).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
                (w, v)
            },
            |(wv, vv)| {
                let w = tensor_from(wv.clone(), 16);
                let v = tensor_from(vv.clone(), 16);
                let p = c.prepare(&w);
                let expect = nvfp4::hard_quant(&w, &p, &v);
                let q = c.encode(&w, &p, &v);
                let back = QuantTensor::from_bytes(&q.to_bytes()).map_err(|e| e.to_string())?;
                if back != q {
                    return Err(format!("{}: container round-trip not identical", c.name()));
                }
                let deq = back.dequantize().map_err(|e| e.to_string())?;
                for i in 0..w.numel() {
                    let d = (deq.data[i] - expect.data[i]).abs();
                    let tol = 1e-5 * expect.data[i].abs().max(1e-5);
                    if d > tol {
                        return Err(format!(
                            "{}: i={i}: {} vs {}",
                            c.name(),
                            deq.data[i],
                            expect.data[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_double_quantization_is_identity() {
    // quantizing an already-quantized tensor (with its own scale context)
    // must be the identity for every codec
    for kind in ALL_KINDS {
        let c = codec::codec_for(kind);
        check_msg(
            &format!("qt_idempotent_{}", c.name()),
            30,
            |rng| gen::f32_normal(rng, 64 * 16, 0.5),
            |xs| {
                let w = tensor_from(xs.clone(), 16);
                let p = c.prepare(&w);
                let t1 = c
                    .encode(&w, &p, &rtn_decisions(&p))
                    .dequantize()
                    .map_err(|e| e.to_string())?;
                let p2 = codec::prepare_with_scales(&t1, p.scale.clone(), p.s_global.clone());
                let t2 = c
                    .encode(&t1, &p2, &rtn_decisions(&p2))
                    .dequantize()
                    .map_err(|e| e.to_string())?;
                for i in 0..t1.numel() {
                    let d = (t2.data[i] - t1.data[i]).abs();
                    if d > 1e-6 * t1.data[i].abs().max(1e-6) {
                        return Err(format!(
                            "{}: i={i}: requantized {} != {}",
                            c.name(),
                            t2.data[i],
                            t1.data[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn grid_monotone_and_block_sizes() {
    for c in codec::all_codecs() {
        let g = c.grid();
        assert_eq!(g[0], 0.0, "{} grid must start at 0", c.name());
        assert!(
            g.windows(2).all(|w| w[0] < w[1]),
            "{} grid not strictly increasing: {g:?}",
            c.name()
        );
        assert!(g.iter().all(|x| x.is_finite()));
        // the grid must agree with the E2M1 decode table the codes index
        for (i, &node) in g.iter().enumerate() {
            assert_eq!(e2m1::decode(i as u8), node);
        }
    }
    assert_eq!(codec::codec_for(FormatKind::Nvfp4).block_size(), 16);
    assert_eq!(codec::codec_for(FormatKind::Mxfp4).block_size(), 32);
    assert_eq!(codec::codec_for(FormatKind::E2m1).block_size(), 0);
}

#[test]
fn prop_container_rejects_truncation() {
    check_msg(
        "qt_truncation",
        40,
        |rng| gen::f32_normal(rng, 32 * 16, 0.1),
        |xs| {
            let w = tensor_from(xs.clone(), 16);
            let c = codec::codec_for(FormatKind::Nvfp4);
            let p = c.prepare(&w);
            let bytes = c.encode(&w, &p, &rtn_decisions(&p)).to_bytes();
            for cut in [0usize, 3, 4, 11, 30, bytes.len() / 2, bytes.len() - 1] {
                if QuantTensor::from_bytes(&bytes[..cut]).is_ok() {
                    return Err(format!("accepted truncation at {cut}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantization_error_bounded_by_interval() {
    check_msg(
        "error_bounded",
        40,
        |rng| gen::f32_heavy(rng, 16 * 16),
        |xs| {
            let w = tensor_from(xs.clone(), 16);
            let p = nvfp4::prepare(&w);
            let q = nvfp4::rtn_quant(&w, &p);
            for i in 0..w.numel() {
                let s = p.scale.data[i];
                if s <= 0.0 {
                    continue;
                }
                let width = (p.upper.data[i] - p.lower.data[i]) * s;
                let clip = (w.data[i].abs() - 6.0 * s).max(0.0);
                let e = (q.data[i] - w.data[i]).abs();
                if e > width / 2.0 + clip + 1e-5 {
                    return Err(format!(
                        "i={i}: err {e} > half-width {} + clip {clip}",
                        width / 2.0
                    ));
                }
            }
            Ok(())
        },
    );
}
