//! Chaos soak of the serving engine under deterministic fault
//! injection: a seeded [`FaultPlan`] drives backend step errors, typed
//! KV exhaustion, injected latency, and outright panics through the
//! real TCP/HTTP front ends, and the suite pins the recovery contract:
//!
//! * every request resolves with a *structured* terminal — a completion
//!   or an error whose code is machine-matchable — never silence, never
//!   a wedged connection;
//! * the scheduler survives every injected panic (`backend_panic`) and
//!   keeps serving later requests bit-exactly;
//! * after the server drains, the native backend reports
//!   `kv_outstanding() == 0` — faults never leak KV pages;
//! * deadlines, overload shedding (`overloaded` + `Retry-After`), and
//!   graceful drain (`shutting_down`, `/readyz` flip) behave identically
//!   over both transports;
//! * torn client writes (byte-level chunking with mid-frame stalls)
//!   decode exactly like a single clean write.
//!
//! The CI smoke tests run in seconds; the deep soak is `#[ignore]`d and
//! run on demand (`cargo test --test chaos_serve -- --ignored`).

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::{
    native_manifest, quantize_store, KvFormat, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::client::{Client, ClientRequest, RetryPolicy};
use nvfp4_faar::serve::fault::torn_chunks;
use nvfp4_faar::serve::{
    generate_greedy, serve_on, CodecKind, FaultBackend, FaultPlan, ModelEntry, ModelRegistry,
    ServeOptions, SpecDecoder, SyntheticBackend, Transport,
};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::json::Json;

const VOCAB: usize = 96;
const SEQ_LEN: usize = 16;

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(VOCAB, SEQ_LEN, 1234)
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect("fault plan")
}

/// tests must fail, not hang, if the server wedges
fn tcp_client(addr: SocketAddr) -> Client {
    Client::connect_timeout(addr, Duration::from_secs(30)).expect("connect")
}

fn http_client(addr: SocketAddr) -> Client {
    Client::connect_http_timeout(addr, Duration::from_secs(30)).expect("connect http")
}

/// Every chaos reply must be a structured terminal: a completion, or an
/// error carrying one of the codes the failure model documents.
fn assert_structured(reply: &nvfp4_faar::serve::client::Reply) {
    if let Err(e) = reply {
        assert!(
            matches!(e.code.as_str(), "backend" | "backend_panic"),
            "unstructured chaos terminal: {e:?}"
        );
        assert!(e.message.contains("injected fault"), "fault origin lost: {e:?}");
    }
}

/// Scripted faults against the TCP-JSONL front end. With one ping-pong
/// client and `max_batch` irrelevant (one request in flight at a time),
/// the decode-tick arithmetic is exact: ticks 0.. are consumed one per
/// step, a faulted tick aborts exactly the in-flight request, and every
/// later request decodes bit-exactly as if no fault ever happened.
#[test]
fn chaos_tcp_scripted_faults_structured_and_bit_exact_after() {
    // r0 dies at tick 2 (step error), r1 survives the 2ms latency at
    // tick 3 then dies at tick 5 (typed KV exhaustion), r2 dies at its
    // final tick 9, r3 panics at tick 12; r4..r7 decode clean
    let fault = FaultBackend::new(backend(), plan("step_err=2+9,kv=5,panic=12,latency=3:2"));
    let reference = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let (stats, replies) = std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = tcp_client(addr);
            (0..8u64)
                .map(|i| {
                    let prompt = vec![(i % 7) as i32 + 1, 2];
                    let req = ClientRequest::tokens(prompt.clone()).max_tokens(4);
                    (prompt, cl.request(&req).expect("transport"))
                })
                .collect::<Vec<_>>()
        });
        let stats = serve_on(&fault, listener, Some(1), ServeOptions::default()).unwrap();
        (stats, cl.join().unwrap())
    });

    for (_, reply) in &replies {
        assert_structured(reply);
    }
    let codes: Vec<&str> = replies
        .iter()
        .map(|(_, r)| r.as_ref().err().map(|e| e.code.as_str()).unwrap_or("ok"))
        .collect();
    assert_eq!(
        codes,
        ["backend", "backend", "backend", "backend_panic", "ok", "ok", "ok", "ok"],
        "fault schedule did not land on the scripted ticks"
    );
    assert_eq!(stats.errors, 4);
    assert_eq!(stats.backend_panics, 1);
    assert_eq!(stats.completed, 4);
    // the KV fault carries the typed error's context through the wire
    assert!(replies[1].1.as_ref().unwrap_err().message.contains("kv exhaustion"));
    // survivors are bit-exact: an injected fault only removes work, it
    // never perturbs the tokens of requests that complete
    for (prompt, reply) in &replies {
        if let Ok(c) = reply {
            let expect = generate_greedy(&reference, prompt, 4).unwrap();
            assert_eq!(&c.tokens, &expect, "post-fault decode diverged for {prompt:?}");
        }
    }
}

/// The same failure model over HTTP: injected faults surface as 500s
/// with the structured code in the body, the connection stays usable
/// (keep-alive), and clean requests still answer 200 with exact tokens.
#[test]
fn chaos_http_faults_map_to_500_and_connection_survives() {
    // r0 (ticks 0,1) panics at tick 1; r1 (ticks 2,3,4) errors at tick
    // 4; r2 decodes clean
    let fault = FaultBackend::new(backend(), plan("panic=1,step_err=4"));
    let reference = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { transport: Transport::Http, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = http_client(addr);
            let mut out = Vec::new();
            for i in 0..3 {
                let reply =
                    cl.request(&ClientRequest::tokens(vec![i + 1, 2]).max_tokens(3)).unwrap();
                out.push((reply, cl.last_status()));
            }
            out
        });
        let stats = serve_on(&fault, listener, Some(1), opts).unwrap();
        let out = cl.join().unwrap();

        assert_eq!(stats.backend_panics, 1);
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.completed, 1);
        assert_eq!(out[0].0.as_ref().unwrap_err().code, "backend_panic");
        assert_eq!(out[0].1, Some(500), "backend_panic must map to 500");
        assert_eq!(out[1].0.as_ref().unwrap_err().code, "backend");
        assert_eq!(out[1].1, Some(500), "backend must map to 500");
        let clean = out[2].0.as_ref().expect("clean request after two 500s");
        assert_eq!(out[2].1, Some(200));
        assert_eq!(clean.tokens, generate_greedy(&reference, &[3, 2], 3).unwrap());
    });
}

fn native_backend() -> NativeBackend {
    let manifest = native_manifest("nano").expect("nano preset");
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    let mut opts = NativeOptions { use_cache: true, ..NativeOptions::default() };
    if let Ok(name) = std::env::var("FAAR_TEST_KV_FORMAT") {
        opts.kv_format = KvFormat::parse(&name)
            .unwrap_or_else(|| panic!("unknown FAAR_TEST_KV_FORMAT '{name}'"));
    }
    NativeBackend::new(model, opts)
}

/// The drain invariant on the real pure-rust backend: step errors, KV
/// exhaustion, and a mid-serve panic must all release their slots'
/// pages — after the server drains, zero KV pages remain outstanding.
#[test]
fn chaos_native_faults_drain_to_zero_kv_outstanding() {
    let fault = FaultBackend::new(native_backend(), plan("step_err=1,panic=3,kv=6"));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let (stats, replies) = std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = tcp_client(addr);
            (0..5i32)
                .map(|i| {
                    let req = ClientRequest::tokens(vec![i * 31 + 1, 7]).max_tokens(3);
                    cl.request(&req).expect("transport")
                })
                .collect::<Vec<_>>()
        });
        let stats = serve_on(&fault, listener, Some(1), ServeOptions::default()).unwrap();
        (stats, cl.join().unwrap())
    });

    for reply in &replies {
        assert_structured(reply);
    }
    assert!(stats.errors >= 3, "three scripted faults must fail requests: {stats:?}");
    assert_eq!(stats.backend_panics, 1);
    assert!(stats.completed >= 1, "requests after the fault window must complete");
    let native = fault.inner();
    assert_eq!(native.kv_outstanding(), 0, "injected faults leaked KV pages");
    assert_eq!(native.cached_slots(), 0, "injected faults leaked slot cache entries");
}

/// Multi-model + speculative decoding under scripted faults: exactly
/// two requests die (one `backend`, one `backend_panic`), the registry
/// keeps routing afterwards, and every surviving completion — the
/// draft-paired model's included — is bit-identical to its own model's
/// sequential reference. A speculative round that is aborted mid-fault
/// must roll back cleanly rather than leave half-verified tokens.
#[test]
fn chaos_multi_model_spec_survivors_stay_bit_exact() {
    let registry = ModelRegistry::new(vec![
        ModelEntry {
            name: "alpha".into(),
            backend: SyntheticBackend::new(VOCAB, SEQ_LEN, 1111),
            spec: None,
        },
        ModelEntry {
            name: "beta".into(),
            backend: SyntheticBackend::new(VOCAB, SEQ_LEN, 2222),
            spec: Some(SpecDecoder::new(
                SyntheticBackend::new(VOCAB, SEQ_LEN, 2222).with_divergence(0.25, 9),
                3,
            )),
        },
    ])
    .unwrap();
    let fault = FaultBackend::new(registry, plan("step_err=1,panic=4"));
    let alpha_ref = SyntheticBackend::new(VOCAB, SEQ_LEN, 1111);
    let beta_ref = SyntheticBackend::new(VOCAB, SEQ_LEN, 2222);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        models: vec!["alpha".into(), "beta".into()],
        ..ServeOptions::default()
    };

    let (stats, replies) = std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = tcp_client(addr);
            (0..6usize)
                .map(|i| {
                    let model = if i % 2 == 0 { "alpha" } else { "beta" };
                    let prompt = vec![(i * 13 % VOCAB) as i32 + 1, 5];
                    let req =
                        ClientRequest::tokens(prompt.clone()).max_tokens(4).model(model);
                    (model, prompt, cl.request(&req).expect("transport"))
                })
                .collect::<Vec<_>>()
        });
        let stats = serve_on(&fault, listener, Some(1), opts).unwrap();
        (stats, cl.join().unwrap())
    });

    for (_, _, reply) in &replies {
        assert_structured(reply);
    }
    // two fault ticks, one in-flight request each: exactly two casualties
    assert_eq!(stats.errors, 2, "scripted ticks must abort exactly two requests");
    assert_eq!(stats.backend_panics, 1);
    assert_eq!(stats.completed, 4);
    let mut survivors = 0;
    for (model, prompt, reply) in &replies {
        if let Ok(c) = reply {
            survivors += 1;
            let reference: &SyntheticBackend =
                if *model == "beta" { &beta_ref } else { &alpha_ref };
            let expect = generate_greedy(reference, prompt, 4).unwrap();
            assert_eq!(
                &c.tokens, &expect,
                "model {model} diverged after faults for {prompt:?}"
            );
        }
    }
    assert_eq!(survivors, 4);
}

/// Overload protection end to end: a burst past capacity sheds the
/// stale tail with structured `overloaded` + a `retry_after_ms` hint,
/// and a second client riding `request_with_retry` keeps backing off on
/// the hint until the burst clears — completing without ever risking a
/// double execution (only pre-admission rejections retry).
#[test]
fn chaos_overload_sheds_tail_and_retry_recovers() {
    // ~2ms per step * 8 tokens = ~16ms per request; 30 pipelined
    // requests are ~480ms of work against a 60ms queue-wait bound, so
    // the head completes and the tail sheds
    let b = backend().with_costs(Duration::from_millis(2), Duration::ZERO);
    let reference = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        max_batch: 1,
        max_queue_wait_ms: 60,
        ..ServeOptions::default()
    };
    const BURST: usize = 30;

    let (stats, burst_replies, retried) = std::thread::scope(|s| {
        let burst = s.spawn(move || {
            let mut cl = tcp_client(addr);
            for i in 0..BURST {
                cl.send(&ClientRequest::tokens(vec![(i % 9) as i32 + 1]).max_tokens(8))
                    .expect("send");
            }
            (0..BURST).map(|_| cl.read_reply().expect("transport")).collect::<Vec<_>>()
        });
        let retrier = s.spawn(move || {
            // join mid-burst: the first attempt sheds, the hint-driven
            // backoff retries until the queue clears
            std::thread::sleep(Duration::from_millis(100));
            let mut cl = tcp_client(addr);
            let policy = RetryPolicy { max_retries: 40, base_ms: 20, cap_ms: 500, seed: 7 };
            cl.request_with_retry(&ClientRequest::tokens(vec![2, 3]).max_tokens(4), &policy)
                .expect("transport")
        });
        let stats = serve_on(&b, listener, Some(2), opts).unwrap();
        (stats, burst.join().unwrap(), retrier.join().unwrap())
    });

    let shed: Vec<_> = burst_replies.iter().filter_map(|r| r.as_ref().err()).collect();
    let completed = burst_replies.iter().filter(|r| r.is_ok()).count();
    assert!(completed >= 1, "the head of the burst must complete");
    assert!(!shed.is_empty(), "the tail of the burst must shed");
    for e in &shed {
        assert_eq!(e.code, "overloaded", "sheds must be structured: {e:?}");
        assert_eq!(e.retry_after_ms, Some(60), "sheds must carry the retry hint");
    }
    // the retrier's own shed attempts count too, so >=, not ==
    assert!(stats.shed as usize >= shed.len(), "server-side shed accounting: {stats:?}");
    // the first burst request never waited: it must not have shed
    assert!(burst_replies[0].is_ok(), "head request wrongly shed");
    let got = retried.expect("retry must recover once the burst clears");
    assert_eq!(got.tokens, generate_greedy(&reference, &[2, 3], 4).unwrap());
}

/// Deadlines over the wire: a request-level `deadline_ms` and the
/// server-wide `--default-deadline-ms` both evict slow decodes with a
/// structured `deadline_exceeded` (HTTP 504), mid-flight.
#[test]
fn chaos_deadlines_evict_over_both_transports() {
    // per-request deadline over TCP
    {
        let b = backend().with_costs(Duration::from_millis(2), Duration::ZERO);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let cl = s.spawn(move || {
                let mut cl = tcp_client(addr);
                let req = ClientRequest::tokens(vec![3]).max_tokens(1000).deadline_ms(25);
                cl.request(&req).expect("transport")
            });
            let stats = serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
            let reply = cl.join().unwrap();
            assert_eq!(reply.unwrap_err().code, "deadline_exceeded");
            assert_eq!(stats.deadline_evictions, 1);
        });
    }
    // server default deadline over HTTP: 504 with the structured code
    {
        let b = backend().with_costs(Duration::from_millis(2), Duration::ZERO);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions {
            transport: Transport::Http,
            default_deadline_ms: 25,
            ..ServeOptions::default()
        };
        std::thread::scope(|s| {
            let cl = s.spawn(move || {
                let mut cl = http_client(addr);
                let reply = cl
                    .request(&ClientRequest::tokens(vec![3]).max_tokens(1000))
                    .expect("transport");
                (reply, cl.last_status())
            });
            let stats = serve_on(&b, listener, Some(1), opts).unwrap();
            let (reply, status) = cl.join().unwrap();
            assert_eq!(reply.unwrap_err().code, "deadline_exceeded");
            assert_eq!(status, Some(504), "deadline_exceeded must map to 504");
            assert_eq!(stats.deadline_evictions, 1);
        });
    }
}

/// Writes raw bytes and collects every `HTTP/1.1` status code read back
/// until the server closes the connection.
fn read_http_statuses(stream: TcpStream) -> Vec<u16> {
    let mut reader = BufReader::new(stream);
    let mut statuses = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            return statuses;
        }
        if let Some(rest) = line.strip_prefix("HTTP/1.1 ") {
            statuses.push(rest.split_whitespace().next().unwrap().parse().expect("status"));
        }
    }
}

/// `GET /healthz` and `GET /readyz` both answer 200 on a live server.
#[test]
fn chaos_health_endpoints_report_live() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { transport: Transport::Http, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
            stream
                .write_all(
                    b"GET /healthz HTTP/1.1\r\ncontent-length: 0\r\n\r\n\
                      GET /readyz HTTP/1.1\r\ncontent-length: 0\r\n\r\n",
                )
                .expect("write");
            let _ = stream.shutdown(std::net::Shutdown::Write);
            read_http_statuses(stream)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        assert_eq!(cl.join().unwrap(), [200, 200]);
    });
}

/// Graceful drain end to end: once `begin_drain` fires, `/readyz`
/// flips to 503 while `/healthz` stays 200, requests enqueued after the
/// flip are refused with `shutting_down`, the in-flight request is
/// evicted when the drain budget expires, and the server exits.
#[test]
fn chaos_drain_flips_readiness_and_evicts_in_flight() {
    let b = backend().with_costs(Duration::from_millis(2), Duration::ZERO);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        transport: Transport::Auto,
        drain_timeout_ms: 200,
        ..ServeOptions::default()
    };
    let lifecycle = opts.lifecycle.clone();

    std::thread::scope(|s| {
        // in-flight long decode: admitted before the drain, evicted when
        // the drain budget expires
        let in_flight = s.spawn(move || {
            let mut cl = tcp_client(addr);
            cl.request(&ClientRequest::tokens(vec![3]).max_tokens(100_000)).expect("transport")
        });
        // health probe: connects while live, sends only after the flip
        let probe_lc = lifecycle.clone();
        let probe = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
            while !probe_lc.draining() {
                std::thread::sleep(Duration::from_millis(5));
            }
            stream
                .write_all(
                    b"GET /healthz HTTP/1.1\r\ncontent-length: 0\r\n\r\n\
                      GET /readyz HTTP/1.1\r\ncontent-length: 0\r\n\r\n",
                )
                .expect("write");
            let _ = stream.shutdown(std::net::Shutdown::Write);
            read_http_statuses(stream)
        });
        // late client: connects while live, submits only after the flip
        let late_lc = lifecycle.clone();
        let late = s.spawn(move || {
            let mut cl = tcp_client(addr);
            while !late_lc.draining() {
                std::thread::sleep(Duration::from_millis(5));
            }
            cl.request(&ClientRequest::tokens(vec![4]).max_tokens(2)).expect("transport")
        });
        let trigger_lc = lifecycle.clone();
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            trigger_lc.begin_drain();
        });

        let stats = serve_on(&b, listener, Some(3), opts).unwrap();

        assert_eq!(
            probe.join().unwrap(),
            [200, 503],
            "liveness must stay 200 while readiness flips to 503"
        );
        let late_err = late.join().unwrap().unwrap_err();
        assert_eq!(late_err.code, "shutting_down", "post-drain request not refused");
        let in_flight_err = in_flight.join().unwrap().unwrap_err();
        assert_eq!(in_flight_err.code, "shutting_down", "in-flight decode not evicted");
        assert!(stats.drain_evictions >= 2, "drain accounting: {stats:?}");
        assert_eq!(stats.completed, 0);
    });
}

fn read_json_line(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).expect("read line");
    Json::parse(&line).expect("reply is JSON")
}

/// Torn client writes: the request bytes arrive in deterministic 1-7
/// byte chunks with mid-frame stalls, under the incremental decoder —
/// the decode must be byte-for-byte identical to a clean single write.
#[test]
fn chaos_torn_writes_decode_exactly() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { codec: CodecKind::Incremental, ..ServeOptions::default() };
    let line = "{\"tokens\":[3,4],\"max_tokens\":5}\n";

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
            let _ = stream.set_nodelay(true);
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            for (chunk, stall) in torn_chunks(line.as_bytes(), 5) {
                stream.write_all(&chunk).expect("write");
                stream.flush().expect("flush");
                std::thread::sleep(stall);
            }
            let reply = read_json_line(&mut reader);
            drop(reader);
            let _ = stream.shutdown(std::net::Shutdown::Both);
            reply
                .req("tokens")
                .expect("tokens field")
                .as_arr()
                .expect("tokens array")
                .iter()
                .map(|t| t.as_f64().expect("token id") as i32)
                .collect::<Vec<i32>>()
        });
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        let got = cl.join().unwrap();
        assert_eq!(stats.completed, 1);
        assert_eq!(
            got,
            generate_greedy(&b, &[3, 4], 5).unwrap(),
            "torn writes changed the decode"
        );
    });
}

/// Deep soak (run with `--ignored`): six concurrent clients, thirty
/// requests each, against a 3% probabilistic error rate plus scripted
/// panics — every single request must resolve with a structured
/// terminal, the accounting must balance exactly, and the server must
/// drain cleanly at the end.
#[test]
#[ignore = "deep soak; run on demand with --ignored"]
fn chaos_soak_err_rate_all_requests_resolve() {
    let fault = FaultBackend::new(
        backend().with_costs(Duration::from_micros(200), Duration::from_micros(5)),
        plan("seed=31,err_rate=0.03,panic=50+333,latency=17:3+171:5"),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 6;
    const REQS: usize = 30;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let (stats, replies) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = tcp_client(addr);
                    (0..REQS)
                        .map(|r| {
                            let prompt = vec![((c * 17 + r * 3) % VOCAB) as i32, 1];
                            let req = ClientRequest::tokens(prompt)
                                .max_tokens(3 + (c + r) % 5);
                            cl.request(&req).expect("transport")
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let stats = serve_on(&fault, listener, Some(N), opts).unwrap();
        let replies: Vec<_> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, replies)
    });

    assert_eq!(replies.len(), N * REQS, "every request must resolve");
    for reply in &replies {
        assert_structured(reply);
    }
    let failed = replies.iter().filter(|r| r.is_err()).count() as u64;
    assert_eq!(stats.errors, failed);
    assert_eq!(stats.completed, (N * REQS) as u64 - failed);
    assert_eq!(stats.cancelled, 0, "ping-pong clients never cancel");
    assert!(stats.errors > 0, "3% error rate over ~700 ticks must fire");
    assert!(stats.completed > 0, "chaos must not starve all requests");
    assert!(stats.backend_panics >= 2, "scripted panics must both fire and be contained");
}
