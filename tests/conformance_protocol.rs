//! Protocol conformance corpus, replayed through BOTH frame codecs.
//!
//! Every file in `tests/corpus/protocol/*.bin` is the raw byte stream
//! of one connection. The filename carries the expected verdicts:
//! `<verdicts>__<name>.bin`, where `<verdicts>` is a `+`-separated
//! sequence of `ok` (an accepted request) or a structured error code
//! (`bad_json`, `oversized`, `bad_request`, `bad_token`,
//! `empty_prompt`, ...), and `none` means the stream produces no
//! events at all (blank lines).
//!
//! For each entry, the harness decodes the bytes with the line codec
//! and the incremental codec — whole-buffer, byte-at-a-time, and at
//! seeded random splits — and asserts:
//!
//! 1. each codec's verdicts are invariant under chunking,
//! 2. both codecs produce the *same outcome sequence* (accept/reject
//!    decision, error code, and for accepts the identical parsed
//!    request), and
//! 3. that sequence matches the verdicts pinned in the filename.
//!
//! The corpus is decoded under fixed limits (`max_line_bytes: 256`,
//! `max_tokens_cap: 8`, vocab 96) documented in the corpus README;
//! boundary entries (`ok__exact-line-limit`, `oversized__line-257`)
//! are built against exactly those numbers.

use std::path::PathBuf;

use nvfp4_faar::data::Tokenizer;
use nvfp4_faar::serve::codec::{decoder_for, CodecLimits, DecodeEvent};
use nvfp4_faar::serve::{parse_request, CodecKind, ServeOptions};
use nvfp4_faar::util::rng::Rng;

const VOCAB: usize = 96;

fn corpus_opts() -> ServeOptions {
    ServeOptions { max_line_bytes: 256, max_tokens_cap: 8, ..ServeOptions::default() }
}

/// The request-level outcome of one decoded frame/rejection — the
/// level at which the two codecs are specified to agree.
#[derive(Debug, PartialEq)]
enum Outcome {
    Accept { prompt: Vec<i32>, max_tokens: usize, stream: bool },
    Reject(&'static str),
}

impl Outcome {
    fn label(&self) -> &str {
        match self {
            Outcome::Accept { .. } => "ok",
            Outcome::Reject(code) => code,
        }
    }
}

fn outcomes(events: &[DecodeEvent], tok: &Tokenizer, opts: &ServeOptions) -> Vec<Outcome> {
    events
        .iter()
        .map(|ev| match ev {
            DecodeEvent::Reject(e) => Outcome::Reject(e.code),
            DecodeEvent::Frame(text) => match parse_request(text, tok, VOCAB, opts) {
                Ok(r) => Outcome::Accept {
                    prompt: r.prompt,
                    max_tokens: r.max_tokens,
                    stream: r.stream,
                },
                Err(e) => Outcome::Reject(e.code),
            },
        })
        .collect()
}

/// Decodes `bytes` split at the given chunk boundaries.
fn run_chunked(kind: CodecKind, bytes: &[u8], splits: &[usize]) -> Vec<DecodeEvent> {
    let mut dec = decoder_for(kind, CodecLimits::from_options(&corpus_opts()));
    let mut out = Vec::new();
    let mut at = 0;
    for &cut in splits {
        dec.feed(&bytes[at..cut], &mut out);
        at = cut;
    }
    dec.feed(&bytes[at..], &mut out);
    dec.finish(&mut out);
    out
}

/// Chunk-invariant event sequence for `bytes` under `kind`: decoded
/// whole, byte-at-a-time, and at seeded random splits, all of which
/// must agree before the result is used.
fn decode(kind: CodecKind, bytes: &[u8], rng: &mut Rng, name: &str) -> Vec<DecodeEvent> {
    let whole = run_chunked(kind, bytes, &[]);
    let single: Vec<usize> = (1..bytes.len()).collect();
    assert_eq!(
        run_chunked(kind, bytes, &single),
        whole,
        "{name}: {kind:?} byte-at-a-time decode diverged"
    );
    for round in 0..4 {
        let mut splits: Vec<usize> = (1..bytes.len()).filter(|_| rng.below(4) == 0).collect();
        splits.dedup();
        assert_eq!(
            run_chunked(kind, bytes, &splits),
            whole,
            "{name}: {kind:?} random-split decode diverged (round {round})"
        );
    }
    whole
}

#[test]
fn conformance_corpus_codecs_agree_and_match_verdicts() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus/protocol");
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "bin"))
        .collect();
    entries.sort();
    assert!(entries.len() >= 30, "corpus unexpectedly small: {} entries", entries.len());

    let tok = Tokenizer::new(VOCAB);
    let opts = corpus_opts();
    let mut rng = Rng::new(0xC0DE_C0DE);
    for path in entries {
        let name = path.file_stem().unwrap().to_str().unwrap().to_string();
        let bytes = std::fs::read(&path).expect("read corpus entry");
        let (verdicts, _) = name
            .split_once("__")
            .unwrap_or_else(|| panic!("{name}: corpus filename needs '<verdicts>__<name>'"));
        let expected: Vec<&str> =
            if verdicts == "none" { vec![] } else { verdicts.split('+').collect() };

        let line = decode(CodecKind::Line, &bytes, &mut rng, &name);
        let incr = decode(CodecKind::Incremental, &bytes, &mut rng, &name);
        let lo = outcomes(&line, &tok, &opts);
        let io = outcomes(&incr, &tok, &opts);
        assert_eq!(lo, io, "{name}: codecs disagree");
        let labels: Vec<&str> = lo.iter().map(|o| o.label()).collect();
        assert_eq!(labels, expected, "{name}: verdicts do not match filename");
    }
}
