//! End-to-end tests of the concurrent batched serving engine over real
//! TCP sockets, driven by the deterministic `SyntheticBackend` — no AOT
//! artifacts or XLA backend needed, so these run everywhere (and in CI
//! under a hard timeout: a deadlocked scheduler fails the build rather
//! than hanging it). All wire traffic goes through the typed
//! `serve::client` — the same client the load-generator bench uses — so
//! the protocol has exactly one implementation on each side.
//!
//! The load-bearing assertions: responses produced by the micro-batching
//! scheduler are token-identical to the sequential `generate` /
//! `generate_greedy` path for the same prompts and parameters, seeded
//! sampling reproduces across runs, and streaming frames concatenate to
//! the non-streaming response.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use nvfp4_faar::data::Tokenizer;
use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::{
    native_manifest, quantize_store, KvFormat, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::client::{Client, ClientRequest, Completion};
use nvfp4_faar::serve::{
    generate, generate_greedy, serve_on, CodecKind, GenParams, ModelEntry, ModelRegistry,
    ServeOptions, SpecDecoder, SyntheticBackend,
};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::json::Json;

const VOCAB: usize = 96;
const SEQ_LEN: usize = 16;

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(VOCAB, SEQ_LEN, 1234)
}

/// tests must fail, not hang, if the server wedges
fn client(addr: SocketAddr) -> Client {
    Client::connect_timeout(addr, Duration::from_secs(30)).expect("connect")
}

fn ok(reply: anyhow::Result<nvfp4_faar::serve::client::Reply>) -> Completion {
    reply.expect("transport").expect("unexpected protocol error")
}

fn err_code(reply: anyhow::Result<nvfp4_faar::serve::client::Reply>) -> String {
    reply.expect("transport").expect_err("expected a protocol error").code
}

#[test]
fn serve_interleaved_clients_match_sequential() {
    // a slow-ish step (500µs fixed) guarantees requests pile up between
    // step boundaries, so this test exercises real micro-batching rather
    // than degenerate batch-of-1 scheduling
    let b = backend().with_costs(Duration::from_micros(500), Duration::from_micros(5));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 8;
    const REQS: usize = 3;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let (stats, all) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = client(addr);
                    let mut outs = vec![];
                    for r in 0..REQS {
                        let prompt =
                            vec![((c * 11 + r * 5) % VOCAB) as i32, (c % 7) as i32 + 1, 7];
                        let max_tokens = 4 + (c + r) % 5;
                        let req =
                            ClientRequest::tokens(prompt.clone()).max_tokens(max_tokens);
                        let got = ok(cl.request(&req));
                        assert!(got.queue_ms >= 0.0);
                        outs.push((prompt, max_tokens, got.tokens));
                    }
                    outs
                })
            })
            .collect();
        let stats = serve_on(&b, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, all)
    });

    assert_eq!(stats.completed as usize, N * REQS);
    assert_eq!(stats.cancelled, 0);
    assert_eq!(stats.errors, 0);
    assert!(stats.batched_steps > 0, "interleaved load never micro-batched");
    assert!(stats.peak_batch > 1 && stats.peak_batch <= 4);
    for (prompt, max_tokens, got) in &all {
        let expect = generate_greedy(&b, prompt, *max_tokens).unwrap();
        assert_eq!(
            got, &expect,
            "batched decode diverged from sequential for prompt {prompt:?}"
        );
    }
}

#[test]
fn serve_malformed_oversized_and_invalid_requests() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        max_batch: 2,
        max_line_bytes: 512,
        max_tokens_cap: 8,
        ..ServeOptions::default()
    };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            let raw = |cl: &mut Client, line: &str| {
                cl.send_raw(line).expect("send");
                err_code(cl.read_reply())
            };
            assert_eq!(raw(&mut cl, "this is not json"), "bad_json");
            assert_eq!(raw(&mut cl, r#"{"tokens":[9999]}"#), "bad_token");
            assert_eq!(raw(&mut cl, r#"{"tokens":[-1],"max_tokens":4}"#), "bad_token");
            assert_eq!(raw(&mut cl, r#"{"prompt":""}"#), "empty_prompt");
            assert_eq!(raw(&mut cl, r#"{"max_tokens":4}"#), "bad_request");
            // oversized line: consumed and rejected, connection survives
            let long = format!(r#"{{"prompt":"{}"}}"#, "x".repeat(600));
            assert_eq!(raw(&mut cl, &long), "oversized");
            // zero max_tokens: valid, completes empty
            let got = ok(cl.request(&ClientRequest::tokens(vec![5]).max_tokens(0)));
            assert!(got.tokens.is_empty());
            // valid request afterwards still decodes, clamped to the cap
            ok(cl.request(&ClientRequest::tokens(vec![1, 2]).max_tokens(100000))).tokens
        });
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        let got = cl.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[1, 2], 8).unwrap(), "cap-clamped decode");
        // 2 decoded requests completed; the rest were protocol rejections
        assert_eq!(stats.completed, 2);
    });
}

/// Sampling parameters are validated at the protocol boundary: every
/// malformed `params` object is rejected with a structured `bad_params`
/// error and the connection keeps serving.
#[test]
fn serve_rejects_bad_sampling_params() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            let raw = |cl: &mut Client, params: &str| {
                cl.send_raw(&format!(r#"{{"tokens":[1],"params":{params}}}"#)).expect("send");
                err_code(cl.read_reply())
            };
            assert_eq!(raw(&mut cl, r#"{"temperature":0}"#), "bad_params");
            assert_eq!(raw(&mut cl, r#"{"temperature":-0.5}"#), "bad_params");
            assert_eq!(raw(&mut cl, r#"{"temperature":1e999}"#), "bad_params");
            assert_eq!(raw(&mut cl, r#"{"top_p":0}"#), "bad_params");
            assert_eq!(raw(&mut cl, r#"{"top_p":1.01}"#), "bad_params");
            assert_eq!(raw(&mut cl, r#"{"top_k":0}"#), "bad_params");
            let spam: Vec<String> = (0..17).map(|i| (i % VOCAB).to_string()).collect();
            assert_eq!(
                raw(&mut cl, &format!(r#"{{"stop_tokens":[{}]}}"#, spam.join(","))),
                "bad_params"
            );
            assert_eq!(raw(&mut cl, r#"{"typo_knob":1}"#), "bad_params");
            // the connection is still usable for a valid request
            ok(cl.request(&ClientRequest::tokens(vec![3, 4]).max_tokens(4))).tokens
        });
        let stats = serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
        assert_eq!(cl.join().unwrap(), generate_greedy(&b, &[3, 4], 4).unwrap());
        assert_eq!(stats.completed, 1);
    });
}

/// The acceptance contract of the v2 API: a seeded sampled request is
/// reproducible across runs (same seed → same tokens), diverges across
/// seeds, matches the sequential `generate` reference exactly, and
/// greedy v1 lines are untouched by any of it.
#[test]
fn serve_sampled_requests_are_seeded_and_reproducible() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            let sampled = ClientRequest::tokens(vec![2, 9])
                .max_tokens(12)
                .sampled(0.8, 42)
                .top_p(0.9);
            let a = ok(cl.request(&sampled)).tokens;
            let b_ = ok(cl.request(&sampled)).tokens;
            let other_seed = ok(cl.request(&sampled.clone().sampled(0.8, 43))).tokens;
            let greedy = ok(cl.request(&ClientRequest::tokens(vec![2, 9]).max_tokens(12)));
            (a, b_, other_seed, greedy.tokens)
        });
        serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
        let (a, b_, other_seed, greedy) = cl.join().unwrap();
        assert_eq!(a, b_, "same seed must reproduce the same continuation");
        assert_ne!(a, other_seed, "different seeds should diverge");
        let params = GenParams { temperature: 0.8, top_p: 0.9, seed: 42, ..GenParams::default() };
        assert_eq!(a, generate(&b, &[2, 9], 12, params).unwrap(), "server != sequential");
        assert_eq!(greedy, generate_greedy(&b, &[2, 9], 12).unwrap(), "v1 greedy regressed");
    });
}

/// `stream: true` emits one frame per token, in order, and the frames
/// concatenate to exactly the tokens of the equivalent non-streaming
/// response — for greedy and seeded sampling alike.
#[test]
fn serve_streaming_frames_concatenate_to_response() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            for req in [
                ClientRequest::tokens(vec![4, 5]).max_tokens(9),
                ClientRequest::tokens(vec![4, 5]).max_tokens(9).sampled(1.1, 7).top_k(20),
            ] {
                let reference = ok(cl.request(&req)).tokens;
                let (frames, terminal) = cl.request_stream(&req).expect("stream transport");
                let terminal = terminal.expect("unexpected protocol error");
                let streamed: Vec<i32> = frames.iter().map(|f| f.token).collect();
                assert_eq!(
                    streamed, terminal.tokens,
                    "frames must concatenate to the terminal response"
                );
                assert_eq!(terminal.tokens, reference, "streaming changed the decode");
                for (i, f) in frames.iter().enumerate() {
                    assert_eq!(f.index, i, "frames out of order");
                }
            }
        });
        serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
        cl.join().unwrap();
    });
}

/// Server-side stop conditions over the wire: a stop token ends the
/// request early (stop token excluded from the output).
#[test]
fn serve_stop_tokens_cut_the_continuation() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let greedy = generate_greedy(&b, &[6, 1], 12).unwrap();
    // stop on the first token that does not occur earlier in the stream,
    // so the stop cannot fire before the index we expect
    let k = (1..greedy.len()).find(|&k| !greedy[..k].contains(&greedy[k])).unwrap();
    let stop = greedy[k];

    std::thread::scope(|s| {
        let expect = &greedy[..k];
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            let mut req = ClientRequest::tokens(vec![6, 1]).max_tokens(12);
            req.stop_tokens = vec![stop];
            ok(cl.request(&req)).tokens
        });
        serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
        assert_eq!(cl.join().unwrap(), expect, "stop token did not cut the continuation");
    });
}

#[test]
fn serve_pipelined_responses_keep_request_order() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            // fire everything before reading anything: completion order
            // differs (max_tokens vary) but response order must not
            let lens = [9usize, 1, 7, 2, 5];
            for (i, &n) in lens.iter().enumerate() {
                cl.send(&ClientRequest::tokens(vec![i as i32 + 1]).max_tokens(n))
                    .expect("send");
                if i == 2 {
                    // a malformed line in the middle keeps its position
                    cl.send_raw("{broken").expect("send");
                }
            }
            let mut got = vec![];
            for i in 0..lens.len() + 1 {
                if i == 3 {
                    assert_eq!(err_code(cl.read_reply()), "bad_json", "error out of order");
                } else {
                    got.push(ok(cl.read_reply()).tokens);
                }
            }
            (lens, got)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        let (lens, got) = cl.join().unwrap();
        assert_eq!(got.len(), lens.len());
        for (i, (&n, tokens)) in lens.iter().zip(&got).enumerate() {
            let expect = generate_greedy(&b, &[i as i32 + 1], n).unwrap();
            assert_eq!(tokens, &expect, "response {i} out of order or wrong");
        }
    });
}

#[test]
fn serve_disconnect_mid_decode_does_not_wedge_the_server() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    std::thread::scope(|s| {
        s.spawn(move || {
            // fire a long decode and vanish without reading the response
            let mut cl = client(addr);
            cl.send(&ClientRequest::tokens(vec![3]).max_tokens(64)).expect("send");
            cl.shutdown();
        });
        let survivor = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let mut cl = client(addr);
            ok(cl.request(&ClientRequest::tokens(vec![4, 5]).max_tokens(6))).tokens
        });
        let stats = serve_on(&b, listener, Some(2), opts).unwrap();
        let got = survivor.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[4, 5], 6).unwrap());
        // the survivor always completes; the vanished client either
        // completed (dropped on write) or was cancelled mid-decode
        assert!(stats.completed >= 1);
        assert_eq!(stats.errors, 0);
    });
}

// ---------------------------------------------------------------------------
// Split-read regressions: request bytes arriving in adversarially chunked
// reads must decode exactly like a single write, under BOTH frame codecs.

/// A raw socket for byte-level wire tests the typed client cannot express.
fn raw_socket(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    let _ = s.set_nodelay(true);
    s
}

/// Write `bytes` split at the given cut points, flushing and pausing at
/// each cut so the server's reader observes genuinely separate reads.
fn write_split(s: &mut TcpStream, bytes: &[u8], cuts: &[usize]) {
    let mut at = 0;
    for &cut in cuts {
        s.write_all(&bytes[at..cut]).expect("write");
        s.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(30));
        at = cut;
    }
    s.write_all(&bytes[at..]).expect("write");
    s.flush().expect("flush");
}

fn read_json_line(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).expect("read line");
    Json::parse(&line).expect("reply is JSON")
}

fn reply_tokens(v: &Json) -> Vec<i32> {
    v.req("tokens")
        .expect("tokens field")
        .as_arr()
        .expect("tokens array")
        .iter()
        .map(|t| t.as_f64().expect("token id") as i32)
        .collect()
}

/// A multi-byte UTF-8 character, a `\"` escape, and the final `\r\n` all
/// straddling read boundaries: the request must decode exactly like a
/// single-write request, under both codecs.
#[test]
fn serve_split_reads_cross_utf8_escape_and_crlf_boundaries() {
    for codec in [CodecKind::Line, CodecKind::Incremental] {
        let b = backend();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions { codec, ..ServeOptions::default() };
        let text = "{\"prompt\":\"héllo \\\" wörld\",\"max_tokens\":4}\r\n";
        let bytes = text.as_bytes().to_vec();
        // cut inside the 2-byte é, right after the escape backslash, and
        // between \r and \n
        let e_lead = bytes.iter().position(|&x| x == 0xC3).unwrap();
        let bslash = bytes.iter().position(|&x| x == b'\\').unwrap();
        let cr = bytes.iter().position(|&x| x == b'\r').unwrap();
        let cuts = [e_lead + 1, bslash + 1, cr + 1];

        std::thread::scope(|s| {
            let bytes = &bytes;
            let cl = s.spawn(move || {
                let mut sock = raw_socket(addr);
                let mut reader = BufReader::new(sock.try_clone().unwrap());
                write_split(&mut sock, bytes, &cuts);
                reply_tokens(&read_json_line(&mut reader))
            });
            serve_on(&b, listener, Some(1), opts).unwrap();
            let got = cl.join().unwrap();
            // the prompt decodes through the server tokenizer: three
            // unknown words (map to token 0)
            let prompt = Tokenizer::new(VOCAB).encode("héllo \" wörld");
            assert_eq!(prompt, vec![0, 0, 0], "tokenizer contract drifted");
            let expect = generate_greedy(&b, &prompt, 4).unwrap();
            assert_eq!(got, expect, "split reads changed the decode under {codec:?}");
        });
    }
}

/// A line of exactly `max_line_bytes` is accepted; one byte more is a
/// single `oversized` rejection and the connection keeps serving — under
/// both codecs, regardless of how the oversized line was chunked.
#[test]
fn serve_exact_length_bound_accepted_one_more_rejected() {
    for codec in [CodecKind::Line, CodecKind::Incremental] {
        let b = backend();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions { codec, max_line_bytes: 256, ..ServeOptions::default() };
        let shell = r#"{"prompt":"","max_tokens":2}"#;
        let pad = 256 - shell.len();
        let exact = format!("{{\"prompt\":\"{}\",\"max_tokens\":2}}", "a".repeat(pad));
        assert_eq!(exact.len(), 256);
        let over = format!("{{\"prompt\":\"{}\",\"max_tokens\":2}}", "a".repeat(pad + 1));

        std::thread::scope(|s| {
            let (exact, over) = (&exact, &over);
            let cl = s.spawn(move || {
                let mut cl = client(addr);
                let at_limit = ok({
                    cl.send_raw(exact).expect("send");
                    cl.read_reply()
                });
                cl.send_raw(over).expect("send");
                let code = err_code(cl.read_reply());
                // the connection survives the rejection
                let after = ok(cl.request(&ClientRequest::tokens(vec![1]).max_tokens(2)));
                (at_limit.tokens, code, after.tokens)
            });
            serve_on(&b, listener, Some(1), opts).unwrap();
            let (at_limit, code, after) = cl.join().unwrap();
            assert_eq!(at_limit, generate_greedy(&b, &[0], 2).unwrap(), "{codec:?}");
            assert_eq!(code, "oversized", "{codec:?}");
            assert_eq!(after, generate_greedy(&b, &[1], 2).unwrap(), "{codec:?}");
        });
    }
}

/// The incremental codec accepts a pretty-printed document spanning
/// several lines (newlines are whitespace inside a JSON document); the
/// line codec — by its framing contract — rejects each fragment line.
#[test]
fn serve_incremental_codec_accepts_multiline_documents() {
    let doc = "{\n  \"tokens\": [1, 2],\n  \"max_tokens\": 3\n}";
    // incremental: one request, decoded normally
    {
        let b = backend();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions { codec: CodecKind::Incremental, ..ServeOptions::default() };
        std::thread::scope(|s| {
            let cl = s.spawn(move || {
                let mut cl = client(addr);
                cl.send_raw(doc).expect("send");
                let multi = ok(cl.read_reply());
                // the same connection still frames single-line requests
                let single = ok(cl.request(&ClientRequest::tokens(vec![1, 2]).max_tokens(3)));
                (multi.tokens, single.tokens)
            });
            serve_on(&b, listener, Some(1), opts).unwrap();
            let (multi, single) = cl.join().unwrap();
            let expect = generate_greedy(&b, &[1, 2], 3).unwrap();
            assert_eq!(multi, expect, "multi-line document mis-decoded");
            assert_eq!(single, expect);
        });
    }
    // line codec: the first fragment line is already a bad_json reject
    {
        let b = backend();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions { codec: CodecKind::Line, ..ServeOptions::default() };
        std::thread::scope(|s| {
            let cl = s.spawn(move || {
                let mut cl = client(addr);
                cl.send_raw(doc).expect("send");
                err_code(cl.read_reply())
            });
            serve_on(&b, listener, Some(1), opts).unwrap();
            assert_eq!(cl.join().unwrap(), "bad_json");
        });
    }
}

/// A `{"cancel": seq}` control frame recorded BEFORE its request is
/// admitted deterministically evicts it: the scheduler refuses
/// admission and answers with a structured `cancelled` error. The
/// cancel is consumed exactly once — the connection's next request
/// decodes normally.
#[test]
fn serve_cancel_before_admission_is_deterministic() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            cl.cancel(0).expect("send cancel");
            cl.send(&ClientRequest::tokens(vec![3]).max_tokens(8)).expect("send");
            let code = err_code(cl.read_reply());
            let done = ok(cl.request(&ClientRequest::tokens(vec![4]).max_tokens(4)));
            (code, done.tokens)
        });
        let stats = serve_on(&b, listener, Some(1), ServeOptions::default()).unwrap();
        let (code, tokens) = cl.join().unwrap();
        assert_eq!(code, "cancelled");
        assert_eq!(tokens, generate_greedy(&b, &[4], 4).unwrap());
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.completed, 1);
    });
}

/// Two hosted models behind one [`ModelRegistry`] over real TCP:
/// interleaved requests route by the protocol `"model"` field (no field
/// = entry 0), each response token-identical to its own model's
/// sequential reference — the draft-paired entry included, since
/// speculative decode is bit-exact — and unknown names get a structured
/// `unknown_model` rejection before ever occupying a slot.
#[test]
fn serve_models_route_interleaved_requests_to_their_backends() {
    let alpha = SyntheticBackend::new(VOCAB, SEQ_LEN, 1111);
    let beta = SyntheticBackend::new(VOCAB, SEQ_LEN, 2222);
    let registry = ModelRegistry::new(vec![
        ModelEntry {
            name: "alpha".into(),
            backend: SyntheticBackend::new(VOCAB, SEQ_LEN, 1111),
            spec: None,
        },
        ModelEntry {
            name: "beta".into(),
            backend: SyntheticBackend::new(VOCAB, SEQ_LEN, 2222),
            spec: Some(SpecDecoder::new(
                SyntheticBackend::new(VOCAB, SEQ_LEN, 2222).with_divergence(0.25, 9),
                3,
            )),
        },
    ])
    .unwrap();
    let opts = ServeOptions { max_batch: 4, models: registry.names(), ..ServeOptions::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 3;
    const REQS: usize = 3;
    let (stats, all) = std::thread::scope(|s| {
        let registry = &registry;
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = client(addr);
                    let mut outs = vec![];
                    for r in 0..REQS {
                        let model = match (c + r) % 3 {
                            0 => None,
                            1 => Some("alpha"),
                            _ => Some("beta"),
                        };
                        let prompt = vec![((c * 13 + r * 7) % VOCAB) as i32, 5];
                        let mut req = ClientRequest::tokens(prompt.clone()).max_tokens(6);
                        if let Some(m) = model {
                            req = req.model(m);
                        }
                        outs.push((model, prompt, ok(cl.request(&req)).tokens));
                    }
                    // rejected at the protocol boundary, not the scheduler
                    let bad = ClientRequest::tokens(vec![1]).max_tokens(2).model("nope");
                    cl.send(&bad).expect("send");
                    assert_eq!(err_code(cl.read_reply()), "unknown_model");
                    outs
                })
            })
            .collect();
        let stats = serve_on(registry, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, all)
    });
    assert_eq!(stats.completed as usize, N * REQS);
    assert_eq!(stats.errors, 0, "protocol rejections never reach the scheduler");
    for (model, prompt, got) in &all {
        let reference = match model {
            Some("beta") => &beta,
            _ => &alpha, // named "alpha" or defaulted to entry 0
        };
        let expect = generate_greedy(reference, prompt, 6).unwrap();
        assert_eq!(&expect, got, "model {model:?} diverged for {prompt:?}");
    }
    let spec = stats.spec;
    assert!(spec.rounds > 0 && spec.drafted > 0, "beta requests never drafted: {spec:?}");
    assert!(spec.accepted <= spec.drafted);
    assert_eq!(stats.model_queues.len(), 2);
    let admitted: u64 = stats.model_queues.iter().map(|q| q.admitted).sum();
    let finished: u64 = stats.model_queues.iter().map(|q| q.completed).sum();
    assert_eq!(admitted as usize, N * REQS);
    assert_eq!(finished as usize, N * REQS);
}

fn native_backend(use_cache: bool) -> NativeBackend {
    native_backend_with(NativeOptions { use_cache, ..NativeOptions::default() })
}

/// Build a nano-preset native backend with explicit options. CI runs the
/// whole `serve_native` suite under both KV number formats by setting
/// `FAAR_TEST_KV_FORMAT=f32|e4m3` (unset defaults to the option's value).
fn native_backend_with(mut opts: NativeOptions) -> NativeBackend {
    let manifest = native_manifest("nano").expect("nano preset");
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    if let Ok(name) = std::env::var("FAAR_TEST_KV_FORMAT") {
        opts.kv_format = KvFormat::parse(&name)
            .unwrap_or_else(|| panic!("unknown FAAR_TEST_KV_FORMAT '{name}'"));
    }
    NativeBackend::new(model, opts)
}

/// The serving engine over the NATIVE pure-rust backend, end to end over
/// real TCP with interleaved clients: batched KV-cached decode must be
/// token-identical to the sequential reference on the same backend — the
/// same invariant the synthetic test pins, now with a real model whose
/// weights stay packed the whole time.
#[test]
fn serve_native_interleaved_clients_match_sequential() {
    let backend = native_backend(true);
    let vocab = 256;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 4;
    const REQS: usize = 2;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let (stats, all) = std::thread::scope(|s| {
        let backend = &backend;
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = client(addr);
                    let mut outs = vec![];
                    for r in 0..REQS {
                        let prompt = vec![
                            ((c * 37 + r * 11) % vocab) as i32,
                            ((c * 7 + 3) % vocab) as i32,
                        ];
                        let max_tokens = 3 + (c + r) % 4;
                        let req =
                            ClientRequest::tokens(prompt.clone()).max_tokens(max_tokens);
                        outs.push((prompt, max_tokens, ok(cl.request(&req)).tokens));
                    }
                    outs
                })
            })
            .collect();
        let stats = serve_on(backend, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, all)
    });

    assert_eq!(stats.completed as usize, N * REQS);
    assert_eq!(stats.errors, 0);
    for (prompt, max_tokens, got) in &all {
        let expect = generate_greedy(&backend, prompt, *max_tokens).unwrap();
        assert_eq!(got, &expect, "native batched decode diverged for prompt {prompt:?}");
    }
    // every request's KV pages were freed as its slot retired
    assert_eq!(backend.kv_outstanding(), 0, "KV pages leaked across requests");
    assert_eq!(backend.cached_slots(), 0, "slot cache entries leaked");
}

/// Sampling + streaming through the NATIVE backend over real TCP: a
/// seeded `temperature=0.8, top_p=0.9` request reproduces across
/// requests, its stream frames concatenate to the non-streaming
/// response, and no KV state leaks.
#[test]
fn serve_native_sampled_streaming_reproducible() {
    let backend = native_backend(true);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let backend = &backend;
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            let req = ClientRequest::tokens(vec![9, 33]).max_tokens(6).sampled(0.8, 5).top_p(0.9);
            let a = ok(cl.request(&req)).tokens;
            let (frames, terminal) = cl.request_stream(&req).expect("stream transport");
            let terminal = terminal.expect("unexpected protocol error");
            let streamed: Vec<i32> = frames.iter().map(|f| f.token).collect();
            assert_eq!(streamed, terminal.tokens);
            assert_eq!(a, terminal.tokens, "seeded native sampling did not reproduce");
            a
        });
        serve_on(backend, listener, Some(1), ServeOptions::default()).unwrap();
        let got = cl.join().unwrap();
        let params = GenParams { temperature: 0.8, top_p: 0.9, seed: 5, ..GenParams::default() };
        assert_eq!(got, generate(backend, &[9, 33], 6, params).unwrap());
    });
    assert_eq!(backend.kv_outstanding(), 0);
    assert_eq!(backend.cached_slots(), 0);
}

/// KV-cached decode and no-cache decode must be token-identical on the
/// same model — the cached incremental step replays exactly the float
/// ops of the full-window recompute.
#[test]
fn serve_native_kv_cache_matches_no_cache() {
    let cached = native_backend(true);
    let plain = native_backend(false);
    for (prompt, n) in [(vec![1, 2, 3], 16usize), (vec![250, 4], 8), (vec![77], 24)] {
        let a = generate_greedy(&cached, &prompt, n).unwrap();
        let b = generate_greedy(&plain, &prompt, n).unwrap();
        assert_eq!(a, b, "KV-cached decode diverged from no-cache for {prompt:?}");
    }
    assert_eq!(cached.kv_outstanding(), 0);
}

/// A client that fires a long decode and vanishes must not leave its KV
/// pages behind: the scheduler's cancellation path releases the slot.
#[test]
fn serve_native_disconnect_frees_kv_pages() {
    let backend = native_backend(true);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let stats = std::thread::scope(|s| {
        let backend = &backend;
        s.spawn(move || {
            let mut cl = client(addr);
            cl.send(&ClientRequest::tokens(vec![3]).max_tokens(48)).expect("send");
            cl.shutdown();
        });
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let mut cl = client(addr);
            ok(cl.request(&ClientRequest::tokens(vec![4, 5]).max_tokens(4)));
        });
        serve_on(backend, listener, Some(2), opts).unwrap()
    });
    assert!(stats.completed >= 1);
    assert_eq!(
        backend.kv_outstanding(),
        0,
        "disconnected request left KV pages outstanding"
    );
    assert_eq!(backend.cached_slots(), 0);
}

/// An explicit `{"cancel": seq}` frame mid-decode evicts the slot at the
/// next scheduler tick and frees its KV pages. The reply is either the
/// completion (the decode won the race) or the structured cancellation —
/// never silence, never a leak.
#[test]
fn serve_native_cancel_mid_decode_frees_kv_pages() {
    let backend = native_backend(true);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let stats = std::thread::scope(|s| {
        let backend = &backend;
        s.spawn(move || {
            let mut cl = client(addr);
            cl.send(&ClientRequest::tokens(vec![3]).max_tokens(48)).expect("send");
            std::thread::sleep(Duration::from_millis(30));
            cl.cancel(0).expect("send cancel");
            match cl.read_reply().expect("transport") {
                Ok(c) => assert!(!c.tokens.is_empty(), "empty completion"),
                Err(e) => assert_eq!(e.code, "cancelled"),
            }
        });
        serve_on(backend, listener, Some(1), ServeOptions::default()).unwrap()
    });
    assert_eq!(stats.completed + stats.cancelled, 1, "the request must resolve exactly once");
    assert_eq!(backend.kv_outstanding(), 0, "cancelled request left KV pages outstanding");
    assert_eq!(backend.cached_slots(), 0);
}

/// Artifact-gated: the native forward pass against the REAL XLA
/// `lm_logits_pos_aq` graph, same packed store on both sides. The two
/// paths cannot be bit-identical (the graph computes activation scales
/// over the whole padded `[1, T]` window; the native path computes them
/// per token — DESIGN.md §9 documents the tolerance), so this asserts
/// close logits and an identical argmax, and skips like every other
/// artifact test when `make artifacts` has not run.
#[test]
fn serve_native_logits_close_to_xla() {
    use nvfp4_faar::runtime::{Runtime, Value};
    use std::path::Path;

    let skip = |why: &str| eprintln!("skipping serve_native_logits_close_to_xla: {why}");
    if !Path::new("artifacts/nano/manifest.json").exists() {
        return skip("artifacts/nano missing (run `make artifacts`)");
    }
    let rt = match Runtime::load(Path::new("artifacts"), "nano") {
        Ok(rt) => rt,
        Err(e) => return skip(&format!("runtime load failed ({e})")),
    };
    if let Err(e) = rt.executable("lm_logits_pos_aq") {
        return skip(&format!("XLA backend unavailable ({e})"));
    }
    // identical quantized store on both sides; the native preset layout
    // must agree with the real manifest for this to even marshal
    let fp = ParamStore::init(&rt.manifest, 42);
    let store = quantize_store(&rt.manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(rt.config(), &store, true).expect("model");
    let t = rt.config().seq_len;
    let prompt = [5i32, 9, 2, 14];
    let native = model.logits_window(&prompt).expect("native logits");

    let mut buf = vec![0i32; t];
    buf[..prompt.len()].copy_from_slice(&prompt);
    let mut args: Vec<Value> = nvfp4_faar::train::ParamSource::values(&store).expect("values");
    args.push(Value::I32(buf, vec![1, t]));
    args.push(Value::scalar_i32(prompt.len() as i32 - 1));
    let out = match rt.exec("lm_logits_pos_aq", &args) {
        Ok(o) => o,
        Err(e) => return skip(&format!("XLA exec failed ({e})")),
    };
    let xla = &out[0].as_tensor().expect("logits tensor").data;
    assert_eq!(native.len(), xla.len());
    // documented tolerance: cosine similarity >= 0.999 and identical
    // greedy argmax (DESIGN.md §9)
    let dot: f64 = native.iter().zip(xla).map(|(&a, &b)| a as f64 * b as f64).sum();
    let na: f64 = native.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = xla.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (na * nb).max(1e-30);
    assert!(cos >= 0.999, "native-vs-XLA logits cosine {cos} below tolerance");
    assert_eq!(
        nvfp4_faar::serve::argmax(&native),
        nvfp4_faar::serve::argmax(xla),
        "greedy argmax diverged between native and XLA paths"
    );
}

/// Artifact-gated: checks the token-identity invariant on the REAL XLA
/// path, where batched `lm_logits_pos_aq_b{B}` artifacts are separately
/// compiled modules — per-row independence is asserted by construction
/// in the synthetic tests but must be *verified* against the lowered
/// graphs. Skips (like the other artifact tests) when `make artifacts`
/// has not run or the `xla` dependency is the vendored stub.
#[test]
fn serve_runtime_batched_matches_sequential() {
    use nvfp4_faar::runtime::Runtime;
    use nvfp4_faar::serve::batch::decode_step;
    use nvfp4_faar::serve::{DecodeSlot, RuntimeBackend, StepBackend};
    use nvfp4_faar::train::{ParamStore, QuantParamStore};
    use std::path::Path;

    let skip = |why: &str| eprintln!("skipping serve_runtime_batched_matches_sequential: {why}");
    if !Path::new("artifacts/nano/manifest.json").exists() {
        return skip("artifacts/nano missing (run `make artifacts`)");
    }
    let rt = match Runtime::load(Path::new("artifacts"), "nano") {
        Ok(rt) => rt,
        Err(e) => return skip(&format!("runtime load failed ({e})")),
    };
    if let Err(e) = rt.executable("lm_logits_pos_aq") {
        return skip(&format!("XLA backend unavailable ({e})"));
    }
    if !rt.has_artifact("lm_logits_pos_aq_b4") {
        return skip("no batched serve artifacts lowered for this preset (re-run `make artifacts`)");
    }
    let params = QuantParamStore::dense_only(ParamStore::init(&rt.manifest, 7));
    let backend = match RuntimeBackend::new(&rt, &params) {
        Ok(b) => b,
        Err(e) => return skip(&format!("backend prepare failed ({e})")),
    };
    let t = backend.seq_len();
    let prompts: Vec<Vec<i32>> = (0..5i32).map(|i| vec![i + 1, 2 * i + 3]).collect();
    let sequential: Vec<Vec<i32>> =
        prompts.iter().map(|p| generate_greedy(&backend, p, 6).unwrap()).collect();
    // 5 slots exercise a padded b4 chunk plus a single-request call
    let mut slots: Vec<DecodeSlot> =
        prompts.iter().map(|p| DecodeSlot::new(p, 6, t).unwrap()).collect();
    while slots.iter().any(|s| !s.done()) {
        decode_step(&backend, &mut slots).unwrap();
    }
    for (slot, expect) in slots.iter().zip(&sequential) {
        assert_eq!(&slot.out, expect, "real-XLA batched decode diverged from sequential");
    }
}

#[test]
fn serve_slow_decode_outlives_read_timeout() {
    // 64 steps x 5ms fixed cost ≈ 320ms of decode, well past the 100ms
    // read timeout: the timeout must only reap *idle* connections, not a
    // ping-pong client waiting on its own response
    let b = backend().with_costs(Duration::from_millis(5), Duration::ZERO);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { read_timeout_ms: 100, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            ok(cl.request(&ClientRequest::tokens(vec![2]).max_tokens(64))).tokens
        });
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        let got = cl.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[2], 64).unwrap());
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.cancelled, 0);
    });
}

#[test]
fn serve_idle_connection_times_out_and_server_drains() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { read_timeout_ms: 200, ..ServeOptions::default() };

    std::thread::scope(|s| {
        s.spawn(move || {
            // connect, say nothing, hold the socket open past the timeout
            let cl = client(addr);
            std::thread::sleep(Duration::from_millis(800));
            drop(cl);
        });
        let t0 = std::time::Instant::now();
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        assert_eq!(stats.completed, 0);
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "server failed to drain on an idle connection"
        );
    });
}

/// Tentpole acceptance over real TCP: interleaved clients whose prompts
/// share a page-aligned prefix decode bit-identically to a cold run on a
/// reference backend without the prefix trie, under whichever
/// `FAAR_TEST_KV_FORMAT` the suite runs. After the server drains, the
/// only outstanding pages are the trie's, and clearing it frees them all.
#[test]
fn serve_native_prefix_cache_hits_bit_identical() {
    let backend = native_backend_with(NativeOptions {
        use_cache: true,
        prefix_cache: true,
        page_tokens: 4,
        ..NativeOptions::default()
    });
    let reference = native_backend_with(NativeOptions {
        use_cache: true,
        page_tokens: 4,
        ..NativeOptions::default()
    });
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 4;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };
    // two full 4-token pages of shared prefix, then a per-client suffix
    let base = [17i32, 3, 9, 250, 41, 8, 77, 5];

    let (stats, all) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = client(addr);
                    let mut prompt = base.to_vec();
                    prompt.push(((c * 31 + 2) % 256) as i32);
                    let got = ok(cl.request(&ClientRequest::tokens(prompt.clone()).max_tokens(5)));
                    (prompt, got.tokens)
                })
            })
            .collect();
        let stats = serve_on(&backend, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (stats, all)
    });

    assert_eq!(stats.completed as usize, N);
    assert_eq!(stats.errors, 0);
    for (prompt, got) in &all {
        let expect = generate_greedy(&reference, prompt, 5).unwrap();
        assert_eq!(got, &expect, "cache-hit decode diverged from cold run for {prompt:?}");
    }
    // the trie was consulted for every admit, and the shared prefix hit
    assert!(stats.cache.prefix_lookups >= N as u64, "missing lookups: {:?}", stats.cache);
    assert!(stats.cache.prefix_hits >= 1, "shared prefix never hit: {:?}", stats.cache);
    assert!(stats.cache.kv_pages_hwm > 0, "high-water mark never recorded");
    // slots drained; exactly the published trie pages remain outstanding
    assert_eq!(backend.cached_slots(), 0, "slot cache entries leaked");
    assert_eq!(
        backend.kv_outstanding() as u64,
        stats.cache.prefix_pages,
        "outstanding pages beyond the trie's after drain"
    );
    backend.clear_prefix_cache();
    assert_eq!(backend.kv_outstanding(), 0, "shared pages leaked after trie clear");
    assert_eq!(reference.kv_outstanding(), 0);
}

/// Chunked prefill must not change what the model says: a long prompt
/// served under a small per-step prefill budget decodes exactly like the
/// unchunked engine, and the scheduler reports the chunk accounting.
#[test]
fn serve_native_chunked_prefill_matches_unchunked() {
    let backend = native_backend_with(NativeOptions {
        use_cache: true,
        page_tokens: 4,
        ..NativeOptions::default()
    });
    let reference = native_backend(true);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, prefill_chunk_tokens: 8, ..ServeOptions::default() };
    // long enough that the 39 missing prefill tokens need five 8-token chunks
    let long: Vec<i32> = (0..40).map(|i| (i * 7 % 256) as i32).collect();
    let prompt = long.clone();

    let (stats, got) = std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = client(addr);
            ok(cl.request(&ClientRequest::tokens(prompt).max_tokens(5))).tokens
        });
        let stats = serve_on(&backend, listener, Some(1), opts).unwrap();
        (stats, cl.join().unwrap())
    });

    assert_eq!(stats.completed, 1);
    let expect = generate_greedy(&reference, &long, 5).unwrap();
    assert_eq!(got, expect, "chunked prefill changed the decode");
    assert!(stats.prefill_chunks > 1, "long prompt was never chunked: {stats:?}");
    assert_eq!(stats.prefill_tokens, 39, "chunk accounting drifted: {stats:?}");
    assert!(stats.budget_tokens >= stats.prefill_tokens);
    assert_eq!(backend.kv_outstanding(), 0, "KV pages leaked after chunked prefill");
    assert_eq!(reference.kv_outstanding(), 0);
}
