//! End-to-end tests of the concurrent batched serving engine over real
//! TCP sockets, driven by the deterministic `SyntheticBackend` — no AOT
//! artifacts or XLA backend needed, so these run everywhere (and in CI
//! under a hard timeout: a deadlocked scheduler fails the build rather
//! than hanging it).
//!
//! The load-bearing assertion: responses produced by the micro-batching
//! scheduler are token-identical to the sequential `generate_greedy`
//! path for the same prompts.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::{
    native_manifest, quantize_store, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::{generate_greedy, serve_on, ServeOptions, SyntheticBackend};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::json::Json;

const VOCAB: usize = 96;
const SEQ_LEN: usize = 16;

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(VOCAB, SEQ_LEN, 1234)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    // tests must fail, not hang, if the server wedges
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).expect("write");
    stream.write_all(b"\n").expect("write");
}

fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read");
    assert!(!line.trim().is_empty(), "server closed the connection early");
    Json::parse(&line).expect("response is JSON")
}

fn token_req(prompt: &[i32], max_tokens: usize) -> String {
    let ids: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(r#"{{"tokens":[{}],"max_tokens":{}}}"#, ids.join(","), max_tokens)
}

fn tokens_of(v: &Json) -> Vec<i32> {
    v.req("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect()
}

fn error_code(v: &Json) -> String {
    v.req("error").unwrap().req("code").unwrap().as_str().unwrap().to_string()
}

#[test]
fn serve_interleaved_clients_match_sequential() {
    // a slow-ish step (500µs fixed) guarantees requests pile up between
    // step boundaries, so this test exercises real micro-batching rather
    // than degenerate batch-of-1 scheduling
    let b = backend().with_costs(Duration::from_micros(500), Duration::from_micros(5));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 8;
    const REQS: usize = 3;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let (stats, all) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let (mut stream, mut reader) = connect(addr);
                    let mut outs = vec![];
                    for r in 0..REQS {
                        let prompt =
                            vec![((c * 11 + r * 5) % VOCAB) as i32, (c % 7) as i32 + 1, 7];
                        let max_tokens = 4 + (c + r) % 5;
                        send_line(&mut stream, &token_req(&prompt, max_tokens));
                        let v = read_json(&mut reader);
                        assert!(v.get("error").is_none(), "unexpected error: {v:?}");
                        assert!(v.req("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
                        outs.push((prompt, max_tokens, tokens_of(&v)));
                    }
                    outs
                })
            })
            .collect();
        let stats = serve_on(&b, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, all)
    });

    assert_eq!(stats.completed as usize, N * REQS);
    assert_eq!(stats.cancelled, 0);
    assert_eq!(stats.errors, 0);
    assert!(stats.batched_steps > 0, "interleaved load never micro-batched");
    assert!(stats.peak_batch > 1 && stats.peak_batch <= 4);
    for (prompt, max_tokens, got) in &all {
        let expect = generate_greedy(&b, prompt, *max_tokens).unwrap();
        assert_eq!(
            got, &expect,
            "batched decode diverged from sequential for prompt {prompt:?}"
        );
    }
}

#[test]
fn serve_malformed_oversized_and_invalid_requests() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        max_batch: 2,
        max_line_bytes: 512,
        max_tokens_cap: 8,
        ..ServeOptions::default()
    };

    std::thread::scope(|s| {
        let client = s.spawn(move || {
            let (mut stream, mut reader) = connect(addr);
            send_line(&mut stream, "this is not json");
            assert_eq!(error_code(&read_json(&mut reader)), "bad_json");
            send_line(&mut stream, r#"{"tokens":[9999]}"#);
            assert_eq!(error_code(&read_json(&mut reader)), "bad_token");
            send_line(&mut stream, r#"{"tokens":[-1],"max_tokens":4}"#);
            assert_eq!(error_code(&read_json(&mut reader)), "bad_token");
            send_line(&mut stream, r#"{"prompt":""}"#);
            assert_eq!(error_code(&read_json(&mut reader)), "empty_prompt");
            send_line(&mut stream, r#"{"max_tokens":4}"#);
            assert_eq!(error_code(&read_json(&mut reader)), "bad_request");
            // oversized line: consumed and rejected, connection survives
            send_line(&mut stream, &format!(r#"{{"prompt":"{}"}}"#, "x".repeat(600)));
            assert_eq!(error_code(&read_json(&mut reader)), "oversized");
            // zero max_tokens: valid, completes empty
            send_line(&mut stream, r#"{"tokens":[5],"max_tokens":0}"#);
            let v = read_json(&mut reader);
            assert!(v.get("error").is_none());
            assert!(tokens_of(&v).is_empty());
            // valid request afterwards still decodes, clamped to the cap
            send_line(&mut stream, r#"{"tokens":[1,2],"max_tokens":100000}"#);
            let v = read_json(&mut reader);
            assert!(v.get("error").is_none(), "unexpected error: {v:?}");
            tokens_of(&v)
        });
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        let got = client.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[1, 2], 8).unwrap(), "cap-clamped decode");
        // 2 decoded requests completed; the rest were protocol rejections
        assert_eq!(stats.completed, 2);
    });
}

#[test]
fn serve_pipelined_responses_keep_request_order() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let client = s.spawn(move || {
            let (mut stream, mut reader) = connect(addr);
            // fire everything before reading anything: completion order
            // differs (max_tokens vary) but response order must not
            let lens = [9usize, 1, 7, 2, 5];
            for (i, &n) in lens.iter().enumerate() {
                send_line(&mut stream, &token_req(&[i as i32 + 1], n));
                if i == 2 {
                    // a malformed line in the middle keeps its position
                    send_line(&mut stream, "{broken");
                }
            }
            let mut got = vec![];
            for i in 0..lens.len() + 1 {
                let v = read_json(&mut reader);
                if i == 3 {
                    assert_eq!(error_code(&v), "bad_json", "error out of order");
                } else {
                    got.push(tokens_of(&v));
                }
            }
            (lens, got)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        let (lens, got) = client.join().unwrap();
        assert_eq!(got.len(), lens.len());
        for (i, (&n, tokens)) in lens.iter().zip(&got).enumerate() {
            let expect = generate_greedy(&b, &[i as i32 + 1], n).unwrap();
            assert_eq!(tokens, &expect, "response {i} out of order or wrong");
        }
    });
}

#[test]
fn serve_disconnect_mid_decode_does_not_wedge_the_server() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    std::thread::scope(|s| {
        s.spawn(move || {
            // fire a long decode and vanish without reading the response
            let (mut stream, _reader) = connect(addr);
            send_line(&mut stream, &token_req(&[3], 64));
            let _ = stream.shutdown(Shutdown::Both);
        });
        let survivor = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let (mut stream, mut reader) = connect(addr);
            send_line(&mut stream, &token_req(&[4, 5], 6));
            let v = read_json(&mut reader);
            assert!(v.get("error").is_none(), "unexpected error: {v:?}");
            tokens_of(&v)
        });
        let stats = serve_on(&b, listener, Some(2), opts).unwrap();
        let got = survivor.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[4, 5], 6).unwrap());
        // the survivor always completes; the vanished client either
        // completed (dropped on write) or was cancelled mid-decode
        assert!(stats.completed >= 1);
        assert_eq!(stats.errors, 0);
    });
}

fn native_backend(use_cache: bool) -> NativeBackend {
    let manifest = native_manifest("nano").expect("nano preset");
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    NativeBackend::new(model, NativeOptions { use_cache, ..NativeOptions::default() })
}

/// The serving engine over the NATIVE pure-rust backend, end to end over
/// real TCP with interleaved clients: batched KV-cached decode must be
/// token-identical to the sequential reference on the same backend — the
/// same invariant the synthetic test pins, now with a real model whose
/// weights stay packed the whole time.
#[test]
fn serve_native_interleaved_clients_match_sequential() {
    let backend = native_backend(true);
    let vocab = 256;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    const N: usize = 4;
    const REQS: usize = 2;
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let (stats, all) = std::thread::scope(|s| {
        let backend = &backend;
        let handles: Vec<_> = (0..N)
            .map(|c| {
                s.spawn(move || {
                    let (mut stream, mut reader) = connect(addr);
                    let mut outs = vec![];
                    for r in 0..REQS {
                        let prompt = vec![
                            ((c * 37 + r * 11) % vocab) as i32,
                            ((c * 7 + 3) % vocab) as i32,
                        ];
                        let max_tokens = 3 + (c + r) % 4;
                        send_line(&mut stream, &token_req(&prompt, max_tokens));
                        let v = read_json(&mut reader);
                        assert!(v.get("error").is_none(), "unexpected error: {v:?}");
                        outs.push((prompt, max_tokens, tokens_of(&v)));
                    }
                    outs
                })
            })
            .collect();
        let stats = serve_on(backend, listener, Some(N), opts).unwrap();
        let all: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (stats, all)
    });

    assert_eq!(stats.completed as usize, N * REQS);
    assert_eq!(stats.errors, 0);
    for (prompt, max_tokens, got) in &all {
        let expect = generate_greedy(&backend, prompt, *max_tokens).unwrap();
        assert_eq!(got, &expect, "native batched decode diverged for prompt {prompt:?}");
    }
    // every request's KV pages were freed as its slot retired
    assert_eq!(backend.kv_outstanding(), 0, "KV pages leaked across requests");
    assert_eq!(backend.cached_slots(), 0, "slot cache entries leaked");
}

/// KV-cached decode and no-cache decode must be token-identical on the
/// same model — the cached incremental step replays exactly the float
/// ops of the full-window recompute.
#[test]
fn serve_native_kv_cache_matches_no_cache() {
    let cached = native_backend(true);
    let plain = native_backend(false);
    for (prompt, n) in [(vec![1, 2, 3], 16usize), (vec![250, 4], 8), (vec![77], 24)] {
        let a = generate_greedy(&cached, &prompt, n).unwrap();
        let b = generate_greedy(&plain, &prompt, n).unwrap();
        assert_eq!(a, b, "KV-cached decode diverged from no-cache for {prompt:?}");
    }
    assert_eq!(cached.kv_outstanding(), 0);
}

/// A client that fires a long decode and vanishes must not leave its KV
/// pages behind: the scheduler's cancellation path releases the slot.
#[test]
fn serve_native_disconnect_frees_kv_pages() {
    let backend = native_backend(true);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };

    let stats = std::thread::scope(|s| {
        let backend = &backend;
        s.spawn(move || {
            let (mut stream, _reader) = connect(addr);
            send_line(&mut stream, &token_req(&[3], 48));
            let _ = stream.shutdown(Shutdown::Both);
        });
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let (mut stream, mut reader) = connect(addr);
            send_line(&mut stream, &token_req(&[4, 5], 4));
            let v = read_json(&mut reader);
            assert!(v.get("error").is_none(), "unexpected error: {v:?}");
        });
        serve_on(backend, listener, Some(2), opts).unwrap()
    });
    assert!(stats.completed >= 1);
    assert_eq!(
        backend.kv_outstanding(),
        0,
        "disconnected request left KV pages outstanding"
    );
    assert_eq!(backend.cached_slots(), 0);
}

/// Artifact-gated: the native forward pass against the REAL XLA
/// `lm_logits_pos_aq` graph, same packed store on both sides. The two
/// paths cannot be bit-identical (the graph computes activation scales
/// over the whole padded `[1, T]` window; the native path computes them
/// per token — DESIGN.md §9 documents the tolerance), so this asserts
/// close logits and an identical argmax, and skips like every other
/// artifact test when `make artifacts` has not run.
#[test]
fn serve_native_logits_close_to_xla() {
    use nvfp4_faar::runtime::{Runtime, Value};
    use std::path::Path;

    let skip = |why: &str| eprintln!("skipping serve_native_logits_close_to_xla: {why}");
    if !Path::new("artifacts/nano/manifest.json").exists() {
        return skip("artifacts/nano missing (run `make artifacts`)");
    }
    let rt = match Runtime::load(Path::new("artifacts"), "nano") {
        Ok(rt) => rt,
        Err(e) => return skip(&format!("runtime load failed ({e})")),
    };
    if let Err(e) = rt.executable("lm_logits_pos_aq") {
        return skip(&format!("XLA backend unavailable ({e})"));
    }
    // identical quantized store on both sides; the native preset layout
    // must agree with the real manifest for this to even marshal
    let fp = ParamStore::init(&rt.manifest, 42);
    let store = quantize_store(&rt.manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(rt.config(), &store, true).expect("model");
    let t = rt.config().seq_len;
    let prompt = [5i32, 9, 2, 14];
    let native = model.logits_window(&prompt).expect("native logits");

    let mut buf = vec![0i32; t];
    buf[..prompt.len()].copy_from_slice(&prompt);
    let mut args: Vec<Value> = nvfp4_faar::train::ParamSource::values(&store).expect("values");
    args.push(Value::I32(buf, vec![1, t]));
    args.push(Value::scalar_i32(prompt.len() as i32 - 1));
    let out = match rt.exec("lm_logits_pos_aq", &args) {
        Ok(o) => o,
        Err(e) => return skip(&format!("XLA exec failed ({e})")),
    };
    let xla = &out[0].as_tensor().expect("logits tensor").data;
    assert_eq!(native.len(), xla.len());
    // documented tolerance: cosine similarity >= 0.999 and identical
    // greedy argmax (DESIGN.md §9)
    let dot: f64 = native.iter().zip(xla).map(|(&a, &b)| a as f64 * b as f64).sum();
    let na: f64 = native.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = xla.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (na * nb).max(1e-30);
    assert!(cos >= 0.999, "native-vs-XLA logits cosine {cos} below tolerance");
    assert_eq!(
        nvfp4_faar::serve::argmax(&native),
        nvfp4_faar::serve::argmax(xla),
        "greedy argmax diverged between native and XLA paths"
    );
}

/// Artifact-gated: checks the token-identity invariant on the REAL XLA
/// path, where batched `lm_logits_pos_aq_b{B}` artifacts are separately
/// compiled modules — per-row independence is asserted by construction
/// in the synthetic tests but must be *verified* against the lowered
/// graphs. Skips (like the other artifact tests) when `make artifacts`
/// has not run or the `xla` dependency is the vendored stub.
#[test]
fn serve_runtime_batched_matches_sequential() {
    use nvfp4_faar::runtime::Runtime;
    use nvfp4_faar::serve::batch::decode_step;
    use nvfp4_faar::serve::{DecodeSlot, RuntimeBackend, StepBackend};
    use nvfp4_faar::train::{ParamStore, QuantParamStore};
    use std::path::Path;

    let skip = |why: &str| eprintln!("skipping serve_runtime_batched_matches_sequential: {why}");
    if !Path::new("artifacts/nano/manifest.json").exists() {
        return skip("artifacts/nano missing (run `make artifacts`)");
    }
    let rt = match Runtime::load(Path::new("artifacts"), "nano") {
        Ok(rt) => rt,
        Err(e) => return skip(&format!("runtime load failed ({e})")),
    };
    if let Err(e) = rt.executable("lm_logits_pos_aq") {
        return skip(&format!("XLA backend unavailable ({e})"));
    }
    if !rt.has_artifact("lm_logits_pos_aq_b4") {
        return skip("no batched serve artifacts lowered for this preset (re-run `make artifacts`)");
    }
    let params = QuantParamStore::dense_only(ParamStore::init(&rt.manifest, 7));
    let backend = match RuntimeBackend::new(&rt, &params) {
        Ok(b) => b,
        Err(e) => return skip(&format!("backend prepare failed ({e})")),
    };
    let t = backend.seq_len();
    let prompts: Vec<Vec<i32>> = (0..5i32).map(|i| vec![i + 1, 2 * i + 3]).collect();
    let sequential: Vec<Vec<i32>> =
        prompts.iter().map(|p| generate_greedy(&backend, p, 6).unwrap()).collect();
    // 5 slots exercise a padded b4 chunk plus a single-request call
    let mut slots: Vec<DecodeSlot> =
        prompts.iter().map(|p| DecodeSlot::new(p, 6, t).unwrap()).collect();
    while slots.iter().any(|s| !s.done()) {
        decode_step(&backend, &mut slots).unwrap();
    }
    for (slot, expect) in slots.iter().zip(&sequential) {
        assert_eq!(&slot.out, expect, "real-XLA batched decode diverged from sequential");
    }
}

#[test]
fn serve_slow_decode_outlives_read_timeout() {
    // 64 steps x 5ms fixed cost ≈ 320ms of decode, well past the 100ms
    // read timeout: the timeout must only reap *idle* connections, not a
    // ping-pong client waiting on its own response
    let b = backend().with_costs(Duration::from_millis(5), Duration::ZERO);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { read_timeout_ms: 100, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let client = s.spawn(move || {
            let (mut stream, mut reader) = connect(addr);
            send_line(&mut stream, &token_req(&[2], 64));
            let v = read_json(&mut reader);
            assert!(v.get("error").is_none(), "unexpected error: {v:?}");
            tokens_of(&v)
        });
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        let got = client.join().unwrap();
        assert_eq!(got, generate_greedy(&b, &[2], 64).unwrap());
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.cancelled, 0);
    });
}

#[test]
fn serve_idle_connection_times_out_and_server_drains() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { read_timeout_ms: 200, ..ServeOptions::default() };

    std::thread::scope(|s| {
        s.spawn(move || {
            // connect, say nothing, hold the socket open past the timeout
            let (stream, _reader) = connect(addr);
            std::thread::sleep(Duration::from_millis(800));
            drop(stream);
        });
        let t0 = std::time::Instant::now();
        let stats = serve_on(&b, listener, Some(1), opts).unwrap();
        assert_eq!(stats.completed, 0);
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "server failed to drain on an idle connection"
        );
    });
}
