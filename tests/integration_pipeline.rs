//! Pipeline integration on the nano preset: pretraining learns, stage-1
//! reduces reconstruction loss, stage-2 runs, hardening + packing round-
//! trips, and the method registry produces distinct, finite models held
//! as packed `QuantTensor`s. Needs `make artifacts` (nano) and a real
//! XLA backend — without them each test skips with a notice rather than
//! failing, so tier-1 stays green in artifact-less environments.
//! Short schedules keep this under a couple of minutes.

#![allow(clippy::field_reassign_with_default)]

use std::path::Path;

use nvfp4_faar::calib::capture;
use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::Corpus;
use nvfp4_faar::eval::{self, FwdMode};
use nvfp4_faar::pipeline::{faar, harden, Method, Workbench};
use nvfp4_faar::runtime::Runtime;
use nvfp4_faar::train::{pretrain, ParamStore};

fn test_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    cfg.pretrain_steps = 120;
    cfg.calib_batches = 2;
    cfg.stage1_steps = 25;
    cfg.stage2_steps = 10;
    cfg.eval_batches = 2;
    cfg.out_dir = std::env::temp_dir()
        .join(format!("faar_it_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg
}

/// A ready runtime when the AOT artifacts exist *and* the XLA backend
/// can compile them (the `xla` dependency may be the vendored stub);
/// otherwise prints a skip notice. Tests that drive a raw `Runtime` use
/// the returned one; `Workbench`-based tests open their own and only
/// need the gate.
fn ready_runtime(test: &str) -> Option<Runtime> {
    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping {test}: artifacts/nano missing (run `make artifacts`)");
        return None;
    }
    match Runtime::load(Path::new("artifacts"), "nano") {
        Ok(rt) => match rt.executable("lm_fwd") {
            Ok(_) => Some(rt),
            Err(e) => {
                eprintln!("skipping {test}: XLA backend unavailable ({e})");
                None
            }
        },
        Err(e) => {
            eprintln!("skipping {test}: runtime load failed ({e})");
            None
        }
    }
}

#[test]
fn pretraining_reduces_loss() {
    let Some(rt) = ready_runtime("pretraining_reduces_loss") else { return };
    let corpus = Corpus::by_name("synthwiki", rt.config().vocab).unwrap();
    let init = ParamStore::init(&rt.manifest, 1);
    let (_, report) = pretrain(&rt, &[&corpus], init, 80, 2e-3, 10, 1).unwrap();
    let first: f64 = report.losses[..10].iter().sum::<f64>() / 10.0;
    let last: f64 = report.losses[report.losses.len() - 10..].iter().sum::<f64>() / 10.0;
    assert!(
        last < first - 0.3,
        "loss did not drop: {first:.3} -> {last:.3}"
    );
    assert!(report.tokens_per_s > 100.0);
}

#[test]
fn full_pipeline_stage1_stage2_harden() {
    if ready_runtime("full_pipeline_stage1_stage2_harden").is_none() {
        return;
    }
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();

    // stage 1 must beat the v_init reconstruction on its own objective:
    // compare hardened-FAAR layer MSE vs RTN layer MSE on calib rows
    let mut state = faar::prepare_all(&wb.rt, &wb.fp, &wb.cfg).unwrap();
    faar::stage1(&wb.rt, &wb.fp, &wb.calib, &wb.cfg, &mut state).unwrap();
    assert_eq!(state.stage1_losses.len(), 7 * wb.rt.config().n_layers);
    for (k, loss) in &state.stage1_losses {
        assert!(loss.is_finite(), "{k} loss not finite");
    }

    // V stays in [0,1]
    for (name, v) in &state.v {
        let (mn, mx) = v.data.iter().fold((1.0f32, 0.0f32), |(a, b), &x| (a.min(x), b.max(x)));
        assert!(mn >= 0.0 && mx <= 1.0, "{name} V out of range [{mn}, {mx}]");
    }

    // stage 2 runs and its loss log is finite and generally decreasing
    faar::stage2(&wb.rt, &wb.fp, &[&wb.wiki, &wb.c4], &wb.cfg, &mut state).unwrap();
    assert_eq!(state.stage2_log.len(), wb.cfg.stage2_steps);
    let first = state.stage2_log.first().unwrap().0;
    let last = state.stage2_log.last().unwrap().0;
    assert!(first.is_finite() && last.is_finite());

    // harden → packed store → eval path runs; PPL finite and sane
    let hardened = harden::harden_to_params(&wb.rt, &wb.fp, &state).unwrap();
    let ppl = eval::perplexity(
        &wb.rt,
        &hardened,
        &wb.wiki,
        FwdMode::ActQuant,
        1,
        wb.cfg.seed,
    )
    .unwrap();
    assert!(ppl.is_finite() && ppl > 1.0 && ppl < 1e4, "ppl {ppl}");

    // packing round-trips through disk, staying packed on the way back
    let dir = std::path::PathBuf::from(&wb.cfg.out_dir).join("packed");
    let bytes = harden::pack_model(&wb.rt, &hardened, &dir).unwrap();
    assert!(bytes > 0);
    let loaded = harden::load_packed(&wb.rt, &wb.fp, &dir).unwrap();
    assert_eq!(loaded.packed_payload_bytes(), bytes);
    for q in &wb.rt.manifest.qlinears {
        let a = hardened.get(&q.name).unwrap();
        let b = loaded.get(&q.name).unwrap();
        let maxd = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(maxd < 1e-6, "{}: packed roundtrip diff {maxd}", q.name);
    }
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn methods_distinct_finite_and_packed() {
    if ready_runtime("methods_distinct_finite_and_packed").is_none() {
        return;
    }
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let rtn = wb.quantize(Method::Rtn).unwrap();
    let gptq = wb.quantize(Method::Gptq).unwrap();
    let foursix = wb.quantize(Method::FourSix).unwrap();

    let name = &wb.rt.manifest.qlinears[0].name;
    let w_rtn = rtn.params.get(name).unwrap();
    let w_gptq = gptq.params.get(name).unwrap();
    let w_46 = foursix.params.get(name).unwrap();
    assert_ne!(w_rtn.data, w_gptq.data, "gptq should differ from rtn");
    assert_ne!(w_rtn.data, w_46.data, "4/6 should differ from rtn");
    for t in [&w_rtn, &w_gptq, &w_46] {
        assert!(t.data.iter().all(|x| x.is_finite()));
    }
    // non-quantized tensors untouched
    assert_eq!(
        rtn.params.get("tok_emb").unwrap().data,
        wb.fp.get("tok_emb").unwrap().data
    );

    // the canonical representation is packed: every qlinear is a
    // QuantTensor at ≈ numel/2 code bytes + numel/16 scale bytes
    let qlinears = &wb.rt.manifest.qlinears;
    assert_eq!(rtn.params.n_packed(), qlinears.len());
    let qnumel: usize = qlinears.iter().map(|q| wb.fp.get(&q.name).unwrap().numel()).sum();
    let payload = rtn.params.packed_payload_bytes();
    assert!(payload >= qnumel / 2, "payload {payload} below the 4-bit code floor");
    assert!(
        payload <= qnumel / 2 + qnumel / 16 + 64 * qlinears.len(),
        "payload {payload} not ≈ numel/2 + scale overhead (qnumel {qnumel})"
    );
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn calibration_shapes_match_manifest() {
    let Some(rt) = ready_runtime("calibration_shapes_match_manifest") else { return };
    let corpus = Corpus::by_name("synthwiki", rt.config().vocab).unwrap();
    let params = ParamStore::init(&rt.manifest, 3);
    let calib = capture(&rt, &[&corpus], &params, 2, 64, 3).unwrap();
    for q in &rt.manifest.qlinears {
        let set = calib.set(&q.capture).unwrap();
        assert_eq!(set.rows.len(), rt.config().n_layers);
        for rows in &set.rows {
            assert_eq!(rows.shape[1], q.k);
            assert!(rows.shape[0] > 0);
        }
        for h in &set.hessians {
            assert_eq!(h.k, q.k);
            assert!(h.n_rows > 0);
        }
    }
}

#[test]
fn eval_task_accuracy_runs() {
    if ready_runtime("eval_task_accuracy_runs").is_none() {
        return;
    }
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let out = wb.quantize(Method::Bf16).unwrap();
    let acc = wb
        .task_accuracy(&out, nvfp4_faar::data::tasks::TaskKind::ArcEasy, 20)
        .unwrap();
    assert!((0.0..=100.0).contains(&acc));
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn generator_produces_tokens() {
    if ready_runtime("generator_produces_tokens").is_none() {
        return;
    }
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let out = wb.quantize(Method::Rtn).unwrap();
    let gen = nvfp4_faar::serve::Generator::new(&wb.rt, out.params.clone());
    let toks = gen.generate(&[3, 1, 4, 1, 5], 8).unwrap();
    assert_eq!(toks.len(), 8);
    let vocab = wb.rt.config().vocab as i32;
    assert!(toks.iter().all(|&t| (0..vocab).contains(&t)));
    // deterministic greedy decode
    assert_eq!(toks, gen.generate(&[3, 1, 4, 1, 5], 8).unwrap());
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}
