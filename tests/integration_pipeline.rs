//! Pipeline integration on the nano preset: pretraining learns, stage-1
//! reduces reconstruction loss, stage-2 runs, hardening + packing round-
//! trips, and the method registry produces distinct, finite models.
//! Needs `make artifacts` (nano). Short schedules keep this under a
//! couple of minutes.

use std::path::Path;

use nvfp4_faar::calib::capture;
use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::Corpus;
use nvfp4_faar::eval::{self, FwdMode};
use nvfp4_faar::pipeline::{faar, harden, Method, Workbench};
use nvfp4_faar::runtime::Runtime;
use nvfp4_faar::train::{pretrain, ParamStore};

fn test_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    cfg.pretrain_steps = 120;
    cfg.calib_batches = 2;
    cfg.stage1_steps = 25;
    cfg.stage2_steps = 10;
    cfg.eval_batches = 2;
    cfg.out_dir = std::env::temp_dir()
        .join(format!("faar_it_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg
}

fn require_artifacts() {
    assert!(
        Path::new("artifacts/nano/manifest.json").exists(),
        "run `make artifacts` before integration tests"
    );
}

#[test]
fn pretraining_reduces_loss() {
    require_artifacts();
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    let corpus = Corpus::by_name("synthwiki", rt.config().vocab).unwrap();
    let init = ParamStore::init(&rt.manifest, 1);
    let (_, report) = pretrain(&rt, &[&corpus], init, 80, 2e-3, 10, 1).unwrap();
    let first: f64 = report.losses[..10].iter().sum::<f64>() / 10.0;
    let last: f64 = report.losses[report.losses.len() - 10..].iter().sum::<f64>() / 10.0;
    assert!(
        last < first - 0.3,
        "loss did not drop: {first:.3} -> {last:.3}"
    );
    assert!(report.tokens_per_s > 100.0);
}

#[test]
fn full_pipeline_stage1_stage2_harden() {
    require_artifacts();
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();

    // stage 1 must beat the v_init reconstruction on its own objective:
    // compare hardened-FAAR layer MSE vs RTN layer MSE on calib rows
    let mut state = faar::prepare_all(&wb.rt, &wb.fp, &wb.cfg).unwrap();
    faar::stage1(&wb.rt, &wb.fp, &wb.calib, &wb.cfg, &mut state).unwrap();
    assert_eq!(state.stage1_losses.len(), 7 * wb.rt.config().n_layers);
    for (k, loss) in &state.stage1_losses {
        assert!(loss.is_finite(), "{k} loss not finite");
    }

    // V stays in [0,1]
    for (name, v) in &state.v {
        let (mn, mx) = v.data.iter().fold((1.0f32, 0.0f32), |(a, b), &x| (a.min(x), b.max(x)));
        assert!(mn >= 0.0 && mx <= 1.0, "{name} V out of range [{mn}, {mx}]");
    }

    // stage 2 runs and its loss log is finite and generally decreasing
    faar::stage2(&wb.rt, &wb.fp, &[&wb.wiki, &wb.c4], &wb.cfg, &mut state).unwrap();
    assert_eq!(state.stage2_log.len(), wb.cfg.stage2_steps);
    let first = state.stage2_log.first().unwrap().0;
    let last = state.stage2_log.last().unwrap().0;
    assert!(first.is_finite() && last.is_finite());

    // harden → eval path runs; PPL finite and sane
    let hardened = harden::harden_to_params(&wb.rt, &wb.fp, &state).unwrap();
    let ppl = eval::perplexity(
        &wb.rt,
        &hardened,
        &wb.wiki,
        FwdMode::ActQuant,
        1,
        wb.cfg.seed,
    )
    .unwrap();
    assert!(ppl.is_finite() && ppl > 1.0 && ppl < 1e4, "ppl {ppl}");

    // packing round-trips through disk
    let dir = std::path::PathBuf::from(&wb.cfg.out_dir).join("packed");
    let bytes = harden::pack_model(&wb.rt, &wb.fp, &state, &dir).unwrap();
    assert!(bytes > 0);
    let loaded = harden::load_packed(&wb.rt, &wb.fp, &dir).unwrap();
    for q in &wb.rt.manifest.qlinears {
        let a = hardened.get(&q.name).unwrap();
        let b = loaded.get(&q.name).unwrap();
        let maxd = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(maxd < 1e-6, "{}: packed roundtrip diff {maxd}", q.name);
    }
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn methods_distinct_and_finite() {
    require_artifacts();
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let rtn = wb.quantize(Method::Rtn).unwrap();
    let gptq = wb.quantize(Method::Gptq).unwrap();
    let foursix = wb.quantize(Method::FourSix).unwrap();

    let name = &wb.rt.manifest.qlinears[0].name;
    let w_rtn = rtn.params.get(name).unwrap();
    let w_gptq = gptq.params.get(name).unwrap();
    let w_46 = foursix.params.get(name).unwrap();
    assert_ne!(w_rtn.data, w_gptq.data, "gptq should differ from rtn");
    assert_ne!(w_rtn.data, w_46.data, "4/6 should differ from rtn");
    for t in [w_rtn, w_gptq, w_46] {
        assert!(t.data.iter().all(|x| x.is_finite()));
    }
    // non-quantized tensors untouched
    assert_eq!(
        rtn.params.get("tok_emb").unwrap().data,
        wb.fp.get("tok_emb").unwrap().data
    );
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn calibration_shapes_match_manifest() {
    require_artifacts();
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    let corpus = Corpus::by_name("synthwiki", rt.config().vocab).unwrap();
    let params = ParamStore::init(&rt.manifest, 3);
    let calib = capture(&rt, &[&corpus], &params, 2, 64, 3).unwrap();
    for q in &rt.manifest.qlinears {
        let set = calib.set(&q.capture).unwrap();
        assert_eq!(set.rows.len(), rt.config().n_layers);
        for rows in &set.rows {
            assert_eq!(rows.shape[1], q.k);
            assert!(rows.shape[0] > 0);
        }
        for h in &set.hessians {
            assert_eq!(h.k, q.k);
            assert!(h.n_rows > 0);
        }
    }
}

#[test]
fn eval_task_accuracy_runs() {
    require_artifacts();
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let out = wb.quantize(Method::Bf16).unwrap();
    let acc = wb
        .task_accuracy(&out, nvfp4_faar::data::tasks::TaskKind::ArcEasy, 20)
        .unwrap();
    assert!((0.0..=100.0).contains(&acc));
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}

#[test]
fn generator_produces_tokens() {
    require_artifacts();
    let cfg = test_cfg();
    let wb = Workbench::open(cfg).unwrap();
    let out = wb.quantize(Method::Rtn).unwrap();
    let gen = nvfp4_faar::serve::Generator::new(&wb.rt, out.params.clone());
    let toks = gen.generate(&[3, 1, 4, 1, 5], 8).unwrap();
    assert_eq!(toks.len(), 8);
    let vocab = wb.rt.config().vocab as i32;
    assert!(toks.iter().all(|&t| (0..vocab).contains(&t)));
    // deterministic greedy decode
    assert_eq!(toks, gen.generate(&[3, 1, 4, 1, 5], 8).unwrap());
    let _ = std::fs::remove_dir_all(&wb.cfg.out_dir);
}
