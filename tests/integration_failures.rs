//! Failure injection: corrupted manifests, truncated artifacts, bad
//! checkpoints — every load path must fail loudly, not UB or hang.

use std::path::{Path, PathBuf};

use nvfp4_faar::runtime::Runtime;
use nvfp4_faar::train::ParamStore;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("faar_fail_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(d.join("cfg")).unwrap();
    d
}

#[test]
fn missing_manifest_errors() {
    let d = tmp_dir("missing");
    let err = format!("{:#}", Runtime::load(&d, "cfg").err().unwrap());
    assert!(err.contains("manifest.json"), "{err}");
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn corrupt_manifest_errors() {
    let d = tmp_dir("corrupt");
    std::fs::write(d.join("cfg/manifest.json"), "{not json").unwrap();
    assert!(Runtime::load(&d, "cfg").is_err());
    std::fs::write(d.join("cfg/manifest.json"), r#"{"config": {}}"#).unwrap();
    let err = format!("{:#}", Runtime::load(&d, "cfg").err().unwrap());
    assert!(err.contains("missing key"), "{err}");
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn truncated_artifact_errors_at_compile() {
    // real manifest, garbage HLO file
    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let d = tmp_dir("badhlo");
    std::fs::copy("artifacts/nano/manifest.json", d.join("cfg/manifest.json")).unwrap();
    // copy every artifact as an empty file
    let manifest = std::fs::read_to_string("artifacts/nano/manifest.json").unwrap();
    let v = nvfp4_faar::util::json::Json::parse(&manifest).unwrap();
    for (_, a) in v.req("artifacts").unwrap().as_obj().unwrap() {
        let f = a.req("file").unwrap().as_str().unwrap();
        std::fs::write(d.join("cfg").join(f), "HloModule garbage\n???").unwrap();
    }
    let rt = Runtime::load(&d, "cfg").unwrap();
    assert!(rt.executable("lm_fwd").is_err());
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn checkpoint_corruption_detected() {
    let d = tmp_dir("ckpt");
    let p = d.join("w.fwts");
    std::fs::write(&p, b"FWTS\x02\x00\x00\x00garbage").unwrap();
    assert!(ParamStore::load(&p).is_err());
    std::fs::write(&p, b"WRONG").unwrap();
    assert!(ParamStore::load(&p).is_err());
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn packed_tensor_corruption_detected() {
    use nvfp4_faar::formats::nvfp4::PackedTensor;
    // valid header, truncated payload
    let mut w = nvfp4_faar::tensor::Tensor::zeros(&[16, 16]);
    w.data[0] = 1.0;
    let p = nvfp4_faar::formats::nvfp4::prepare(&w);
    let packed = PackedTensor::pack(&w, &p, &p.v_init);
    let bytes = packed.to_bytes();
    assert!(PackedTensor::from_bytes(&bytes[..bytes.len() - 4]).is_err());
    assert!(PackedTensor::from_bytes(b"NVF").is_err());
}
