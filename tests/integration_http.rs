//! End-to-end tests of the HTTP/1.1 + SSE front end: the same
//! scheduler and admission loop as TCP-JSONL, behind a different
//! framing. The load-bearing assertions are parity ones — for the
//! same (prompt, params, seed), an HTTP client and a TCP client on
//! the *same listener* get token-identical answers, SSE frames arrive
//! in the same order as JSONL stream frames, and the HTTP status
//! mapping carries the same structured error codes the JSONL protocol
//! reports.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::{
    native_manifest, quantize_store, KvFormat, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::client::{Client, ClientRequest, Completion};
use nvfp4_faar::serve::{
    generate, generate_greedy, serve_on, GenParams, ServeOptions, SyntheticBackend, Transport,
};
use nvfp4_faar::train::ParamStore;

const VOCAB: usize = 96;
const SEQ_LEN: usize = 16;

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(VOCAB, SEQ_LEN, 1234)
}

fn http_client(addr: SocketAddr) -> Client {
    Client::connect_http_timeout(addr, Duration::from_secs(30)).expect("connect http")
}

fn tcp_client(addr: SocketAddr) -> Client {
    Client::connect_timeout(addr, Duration::from_secs(30)).expect("connect tcp")
}

fn ok(reply: anyhow::Result<nvfp4_faar::serve::client::Reply>) -> Completion {
    reply.expect("transport").expect("unexpected protocol error")
}

fn err_code(reply: anyhow::Result<nvfp4_faar::serve::client::Reply>) -> String {
    reply.expect("transport").expect_err("expected a protocol error").code
}

/// Interleaved HTTP and TCP clients on ONE auto-sniffing listener:
/// identical requests (including seeded sampling) must produce
/// token-identical completions on both transports.
#[test]
fn serve_http_and_tcp_parity_on_one_listener() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        max_batch: 4,
        transport: Transport::Auto,
        ..ServeOptions::default()
    };

    std::thread::scope(|s| {
        let http = s.spawn(move || {
            let mut cl = http_client(addr);
            let greedy = ok(cl.request(&ClientRequest::tokens(vec![2, 7]).max_tokens(5)));
            assert_eq!(cl.last_status(), Some(200));
            let sampled = ok(cl.request(
                &ClientRequest::tokens(vec![3, 1]).max_tokens(6).sampled(0.8, 42).top_k(8),
            ));
            (greedy.tokens, sampled.tokens)
        });
        let tcp = s.spawn(move || {
            let mut cl = tcp_client(addr);
            let greedy = ok(cl.request(&ClientRequest::tokens(vec![2, 7]).max_tokens(5)));
            let sampled = ok(cl.request(
                &ClientRequest::tokens(vec![3, 1]).max_tokens(6).sampled(0.8, 42).top_k(8),
            ));
            (greedy.tokens, sampled.tokens)
        });
        serve_on(&b, listener, Some(2), opts).unwrap();
        let (h_greedy, h_sampled) = http.join().unwrap();
        let (t_greedy, t_sampled) = tcp.join().unwrap();

        assert_eq!(h_greedy, t_greedy, "greedy decode differs across transports");
        assert_eq!(h_sampled, t_sampled, "seeded sampling differs across transports");
        assert_eq!(h_greedy, generate_greedy(&b, &[2, 7], 5).unwrap());
        let params = GenParams { temperature: 0.8, seed: 42, top_k: 8, ..GenParams::default() };
        assert_eq!(h_sampled, generate(&b, &[3, 1], 6, params).unwrap());
    });
}

/// An SSE stream and a JSONL stream for the same request deliver the
/// same frames in the same order, and both concatenate to the
/// non-streaming completion.
#[test]
fn serve_sse_stream_matches_jsonl_stream() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { transport: Transport::Auto, ..ServeOptions::default() };
    let req = ClientRequest::tokens(vec![4, 9]).max_tokens(6);

    std::thread::scope(|s| {
        let req_h = req.clone();
        let http = s.spawn(move || {
            // streaming HTTP clients are one-shot: the server closes
            // the connection after the stream's terminal event
            let mut cl = http_client(addr);
            let (frames, reply) = cl.request_stream(&req_h).expect("sse stream");
            assert_eq!(cl.last_status(), Some(200));
            (frames, reply.expect("terminal completion"))
        });
        let req_t = req.clone();
        let tcp = s.spawn(move || {
            let mut cl = tcp_client(addr);
            let (frames, reply) = cl.request_stream(&req_t).expect("jsonl stream");
            let plain = ok(cl.request(&req_t));
            (frames, reply.expect("terminal completion"), plain)
        });
        serve_on(&b, listener, Some(2), opts).unwrap();
        let (h_frames, h_final) = http.join().unwrap();
        let (t_frames, t_final, plain) = tcp.join().unwrap();

        assert_eq!(h_frames, t_frames, "SSE frames differ from JSONL frames");
        // latencies legitimately differ across transports; the decode must not
        assert_eq!(h_final.tokens, t_final.tokens, "terminal tokens differ across transports");
        assert_eq!(h_final.text, t_final.text, "terminal text differs across transports");
        for (i, f) in h_frames.iter().enumerate() {
            assert_eq!(f.index, i, "SSE frames out of order");
        }
        let streamed: Vec<i32> = h_frames.iter().map(|f| f.token).collect();
        assert_eq!(streamed, plain.tokens, "stream does not concatenate to the completion");
        assert_eq!(h_final.tokens, plain.tokens);
    });
}

/// Protocol rejections over HTTP carry both the structured error code
/// (same as JSONL) and the documented status: 400 for request errors,
/// 413 for oversized bodies — and the connection stays usable after a
/// 400 (keep-alive) while 413 closes it.
#[test]
fn serve_http_maps_errors_to_statuses() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        transport: Transport::Http,
        max_line_bytes: 512,
        ..ServeOptions::default()
    };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = http_client(addr);
            let mut out = Vec::new();
            for (body, expect_status) in [
                ("{not json", 400),
                (r#"{"tokens":"nope"}"#, 400),
                (r#"{"tokens":[5000]}"#, 400),
                (r#"{"tokens":[]}"#, 400),
            ] {
                cl.send_raw(body).expect("send");
                let code = err_code(cl.read_reply());
                assert_eq!(cl.last_status(), Some(expect_status), "status for {body:?}");
                out.push(code);
            }
            // the connection survived four rejections: keep-alive
            let survivor = ok(cl.request(&ClientRequest::tokens(vec![1]).max_tokens(2)));
            assert_eq!(cl.last_status(), Some(200));
            // an oversized declared body is refused up front (413) and
            // the connection closes
            cl.send_raw(&format!("{{\"prompt\":\"{}\"}}", "a".repeat(600))).expect("send");
            let over = err_code(cl.read_reply());
            assert_eq!(cl.last_status(), Some(413));
            (out, survivor.tokens, over)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        let (codes, survivor, over) = cl.join().unwrap();
        assert_eq!(codes, ["bad_json", "bad_request", "bad_token", "empty_prompt"]);
        assert_eq!(survivor, generate_greedy(&b, &[1], 2).unwrap());
        assert_eq!(over, "oversized");
    });
}

/// On a multi-model listener, an unknown `"model"` name maps to HTTP
/// 404 with the structured `unknown_model` code, the connection stays
/// usable (keep-alive), and a hosted name on the same connection still
/// decodes normally.
#[test]
fn serve_http_unknown_model_maps_to_404() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        transport: Transport::Http,
        models: vec!["alpha".into()],
        ..ServeOptions::default()
    };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = http_client(addr);
            cl.send(&ClientRequest::tokens(vec![2]).max_tokens(3).model("nope")).expect("send");
            let code = err_code(cl.read_reply());
            assert_eq!(cl.last_status(), Some(404), "unknown_model must map to 404");
            let req = ClientRequest::tokens(vec![2]).max_tokens(3).model("alpha");
            let named = ok(cl.request(&req));
            assert_eq!(cl.last_status(), Some(200));
            (code, named.tokens)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        let (code, tokens) = cl.join().unwrap();
        assert_eq!(code, "unknown_model");
        assert_eq!(tokens, generate_greedy(&b, &[2], 3).unwrap());
    });
}

/// Writes raw HTTP and returns the replies' status codes, one per
/// response head, until the server closes the connection.
fn raw_http_statuses(addr: SocketAddr, payload: &str) -> Vec<u16> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    stream.write_all(payload.as_bytes()).expect("write");
    stream.flush().expect("flush");
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut reader = BufReader::new(stream);
    let mut statuses = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).expect("read") == 0 {
            return statuses;
        }
        if let Some(rest) = line.strip_prefix("HTTP/1.1 ") {
            statuses
                .push(rest.split_whitespace().next().unwrap().parse().expect("status code"));
        }
    }
}

/// Routing-level rejections: wrong method (405), wrong path (404),
/// and a POST without content-length (411) — the first two keep the
/// connection alive, 411 closes it.
#[test]
fn serve_http_routing_statuses() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { transport: Transport::Http, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            raw_http_statuses(
                addr,
                "GET /v1/generate HTTP/1.1\r\ncontent-length: 0\r\n\r\n\
                 POST /nope HTTP/1.1\r\ncontent-length: 2\r\n\r\n{}\
                 POST /v1/generate HTTP/1.1\r\n\r\n",
            )
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        assert_eq!(cl.join().unwrap(), [405, 404, 411]);
    });
}

/// Two POSTs pipelined back-to-back on one connection are answered in
/// order with both completions correct.
#[test]
fn serve_http_pipelined_requests_answered_in_order() {
    let b = backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions { transport: Transport::Http, ..ServeOptions::default() };

    std::thread::scope(|s| {
        let cl = s.spawn(move || {
            let mut cl = http_client(addr);
            cl.send(&ClientRequest::tokens(vec![1]).max_tokens(3)).expect("send 1");
            cl.send(&ClientRequest::tokens(vec![2]).max_tokens(4)).expect("send 2");
            (ok(cl.read_reply()).tokens, ok(cl.read_reply()).tokens)
        });
        serve_on(&b, listener, Some(1), opts).unwrap();
        let (first, second) = cl.join().unwrap();
        assert_eq!(first, generate_greedy(&b, &[1], 3).unwrap());
        assert_eq!(second, generate_greedy(&b, &[2], 4).unwrap());
    });
}

fn native_backend() -> NativeBackend {
    let manifest = native_manifest("nano").expect("nano preset");
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    let mut opts = NativeOptions { use_cache: true, ..NativeOptions::default() };
    if let Ok(name) = std::env::var("FAAR_TEST_KV_FORMAT") {
        opts.kv_format = KvFormat::parse(&name)
            .unwrap_or_else(|| panic!("unknown FAAR_TEST_KV_FORMAT '{name}'"));
    }
    NativeBackend::new(model, opts)
}

/// An HTTP client that starts an SSE stream and vanishes mid-stream
/// must not leak its KV pages: the writer's broken pipe cancels the
/// request and the scheduler releases the slot.
#[test]
fn serve_http_mid_stream_disconnect_frees_kv_pages() {
    let backend = native_backend();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        max_batch: 4,
        transport: Transport::Auto,
        ..ServeOptions::default()
    };

    let stats = std::thread::scope(|s| {
        let backend = &backend;
        s.spawn(move || {
            // start a long SSE stream and vanish without draining it
            let mut cl = http_client(addr);
            cl.send(&ClientRequest::tokens(vec![3]).max_tokens(48).streaming())
                .expect("send");
            std::thread::sleep(Duration::from_millis(50));
            cl.shutdown();
        });
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let mut cl = tcp_client(addr);
            ok(cl.request(&ClientRequest::tokens(vec![4, 5]).max_tokens(4)));
        });
        serve_on(backend, listener, Some(2), opts).unwrap()
    });
    assert!(stats.completed >= 1);
    assert_eq!(
        backend.kv_outstanding(),
        0,
        "mid-stream HTTP disconnect left KV pages outstanding"
    );
}
