//! Byte-level fuzzing of the wire-protocol frame decoders.
//!
//! Three layers, all deterministic (seeded xoshiro256**, no external
//! fuzzing deps, runs offline as plain `#[test]`s):
//!
//! 1. **Raw bytes, pure decoders** — arbitrary byte soup through both
//!    [`LineDecoder`] and [`IncrementalDecoder`]: no panics, every
//!    event well-formed, and the event stream is invariant under how
//!    the bytes are chunked (the contract `feed` documents).
//! 2. **Structure-aware mutants, differential** — valid requests
//!    mutated structurally (flips, splices, truncations, JSON-token
//!    inserts), kept newline-free so both codecs see the same framing,
//!    then decoded by both and compared as *request outcomes*: codec
//!    events composed with [`parse_request`], which is the level at
//!    which the two codecs promise to agree.
//! 3. **Live scheduler** — the same byte soup fired at a real served
//!    socket; a local decoder replay predicts the exact reply sequence
//!    (count, error codes, and completion tokens), so the server must
//!    answer every frame, never wedge, and never panic.
//!
//! The `*_deep` variants re-run the same logic at many times the
//! iteration count; they are `#[ignore]` so CI stays bounded while a
//! manual `cargo test -- --ignored` digs longer.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use nvfp4_faar::data::Tokenizer;
use nvfp4_faar::serve::codec::{decoder_for, CodecLimits, DecodeEvent};
use nvfp4_faar::serve::{
    generate_greedy, parse_request, serve_on, CodecKind, ServeOptions, SyntheticBackend,
};
use nvfp4_faar::util::json::Json;
use nvfp4_faar::util::rng::Rng;

const VOCAB: usize = 96;
const SEQ_LEN: usize = 16;
const CODECS: [CodecKind; 2] = [CodecKind::Line, CodecKind::Incremental];

/// Fixed request corpus the mutator starts from: every protocol
/// feature (both prompt forms, params, escapes, multi-byte UTF-8),
/// plus inputs that are already invalid in interesting ways.
const SEEDS: &[&str] = &[
    r#"{"tokens":[1,2,3],"max_tokens":4}"#,
    r#"{"prompt":"héllo wörld","max_tokens":3}"#,
    r#"{"prompt":"héllo \" wörld \\ end","max_tokens":2}"#,
    r#"{"tokens":[5],"max_tokens":2,"params":{"temperature":0.5,"seed":7}}"#,
    r#"{"tokens":[],"max_tokens":2}"#,
    r#"{"tokens":[1],"max_tokens":1,"stream":false}"#,
    r#"  {"a":1}  trailing"#,
    r#"{"a":1}{"b":2}"#,
    "plain text, not JSON at all",
    r#"{"unclosed":"string"#,
];

/// Runs `bytes` through a fresh decoder of `kind`, split at
/// rng-chosen boundaries, with a final `finish` as EOF.
fn run_decoder(
    kind: CodecKind,
    limits: CodecLimits,
    bytes: &[u8],
    rng: &mut Rng,
) -> Vec<DecodeEvent> {
    let mut dec = decoder_for(kind, limits);
    let mut out = Vec::new();
    let mut at = 0;
    while at < bytes.len() {
        let n = 1 + rng.below(bytes.len() - at);
        dec.feed(&bytes[at..at + n], &mut out);
        at += n;
    }
    dec.finish(&mut out);
    out
}

/// A request outcome: what the server would ultimately do with one
/// frame. This — not the raw event — is the level where the two codecs
/// are specified to agree (the incremental scanner front-loads checks
/// the line codec leaves to the parser).
#[derive(Debug, PartialEq)]
enum Outcome {
    Accept { prompt: Vec<i32>, max_tokens: usize, stream: bool },
    Reject(&'static str),
}

fn outcomes(events: &[DecodeEvent], tok: &Tokenizer, opts: &ServeOptions) -> Vec<Outcome> {
    events
        .iter()
        .map(|ev| match ev {
            DecodeEvent::Reject(e) => Outcome::Reject(e.code),
            DecodeEvent::Frame(text) => match parse_request(text, tok, VOCAB, opts) {
                Ok(r) => Outcome::Accept {
                    prompt: r.prompt,
                    max_tokens: r.max_tokens,
                    stream: r.stream,
                },
                Err(e) => Outcome::Reject(e.code),
            },
        })
        .collect()
}

fn assert_events_well_formed(events: &[DecodeEvent], what: &str) {
    for ev in events {
        match ev {
            DecodeEvent::Frame(text) => {
                // frames are trimmed of JSON whitespace only (space,
                // tab, CR, LF): anything else is the parser's call
                let ws = |c: char| matches!(c, ' ' | '\t' | '\r' | '\n');
                assert!(!text.is_empty(), "{what}: empty frame emitted");
                assert_eq!(text.trim_matches(ws), text, "{what}: untrimmed frame emitted");
            }
            DecodeEvent::Reject(e) => {
                assert!(
                    matches!(e.code, "bad_json" | "oversized"),
                    "{what}: unknown codec-level error code {:?}",
                    e.code
                );
                assert!(!e.message.is_empty(), "{what}: empty error message");
            }
        }
    }
}

/// Arbitrary bytes with a bias toward protocol-shaped content, so the
/// soup actually reaches deep decoder states instead of bouncing off
/// the first byte.
fn garbage(rng: &mut Rng, len: usize, allow_newline: bool) -> Vec<u8> {
    const TOKENS: &[&[u8]] = &[
        b"{", b"}", b"[", b"]", b":", b",", b"\"", b"\\", b"\\\"", b"\\u00e", b"true",
        b"null", b"-1e9", b"0.5", b"\"tokens\"", b"\"prompt\"", b"\"max_tokens\"",
        b"\xc3\xa9", b"\xe2\x82\xac", b"\xf0\x9f\x98\x80", b"\xc3", b"\xed\xa0\x80",
        b"\xff", b"\x00", b" ", b"\r",
    ];
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        match rng.below(8) {
            0 => out.push(rng.next_u64() as u8),
            1 if allow_newline => out.push(b'\n'),
            _ => out.extend_from_slice(TOKENS[rng.below(TOKENS.len())]),
        }
    }
    out.truncate(len);
    if !allow_newline {
        for b in &mut out {
            if *b == b'\n' {
                *b = b'\x0b';
            }
        }
    }
    out
}

/// One structural mutation of `buf`, possibly splicing from a seed.
fn mutate(buf: &mut Vec<u8>, rng: &mut Rng) {
    if buf.is_empty() {
        buf.extend_from_slice(SEEDS[rng.below(SEEDS.len())].as_bytes());
        return;
    }
    match rng.below(7) {
        0 => {
            let i = rng.below(buf.len());
            buf[i] ^= rng.next_u64() as u8;
        }
        1 => {
            let i = rng.below(buf.len() + 1);
            buf.insert(i, rng.next_u64() as u8);
        }
        2 => {
            let i = rng.below(buf.len());
            let n = (1 + rng.below(4)).min(buf.len() - i);
            buf.drain(i..i + n);
        }
        3 => {
            let i = rng.below(buf.len());
            let n = (1 + rng.below(8)).min(buf.len() - i);
            let dup: Vec<u8> = buf[i..i + n].to_vec();
            buf.splice(i..i, dup);
        }
        4 => buf.truncate(rng.below(buf.len() + 1)),
        5 => {
            let other = SEEDS[rng.below(SEEDS.len())].as_bytes();
            let n = (1 + rng.below(other.len())).min(other.len());
            let i = rng.below(buf.len() + 1);
            let piece: Vec<u8> = other[..n].to_vec();
            buf.splice(i..i, piece);
        }
        _ => {
            let i = rng.below(buf.len() + 1);
            let n = 1 + rng.below(12);
            let extra = garbage(rng, n, false);
            buf.splice(i..i, extra);
        }
    }
    // keep mutants far below the 64 KiB default frame bound: length
    // limits are covered by dedicated tests, and past the bound the
    // codecs intentionally differ in *which* error they pick first
    buf.truncate(4096);
}

fn fuzz_raw_bytes(rounds: usize) {
    let mut rng = Rng::new(0xF4A2_0001);
    let limits =
        CodecLimits { max_frame_bytes: 96, max_depth: 8, max_string_bytes: 32 };
    for round in 0..rounds {
        let len = 1 + rng.below(300);
        let bytes = garbage(&mut rng, len, true);
        for kind in CODECS {
            let a = run_decoder(kind, limits, &bytes, &mut rng);
            let b = run_decoder(kind, limits, &bytes, &mut rng);
            let mut one = decoder_for(kind, limits);
            let mut c = Vec::new();
            for &byte in &bytes {
                one.feed(&[byte], &mut c);
            }
            one.finish(&mut c);
            assert_eq!(a, b, "{kind:?} round {round}: events depend on chunking");
            assert_eq!(a, c, "{kind:?} round {round}: byte-at-a-time diverged");
            assert_events_well_formed(&a, &format!("{kind:?} round {round}"));
        }
    }
}

/// Arbitrary byte soup: no panics, chunk-invariant, well-formed events.
#[test]
fn fuzz_raw_bytes_decoders_never_panic() {
    fuzz_raw_bytes(150);
}

/// Long-haul version of the raw-bytes fuzz (`cargo test -- --ignored`).
#[test]
#[ignore = "deep fuzz; run explicitly"]
fn fuzz_raw_bytes_deep() {
    fuzz_raw_bytes(20_000);
}

fn fuzz_mutants(rounds: usize) {
    let mut rng = Rng::new(0xF4A2_0002);
    let tok = Tokenizer::new(VOCAB);
    let opts = ServeOptions::default();
    let limits = CodecLimits::from_options(&opts);
    // one always-on regression input: nesting just past the parser
    // bound, which the scanner rejects early and the parser late
    let deep = format!("{}1{}", "[".repeat(70), "]".repeat(70));
    for round in 0..rounds {
        let mut bytes = if round == 0 {
            deep.clone().into_bytes()
        } else {
            SEEDS[rng.below(SEEDS.len())].as_bytes().to_vec()
        };
        for _ in 0..1 + rng.below(4) {
            if round > 0 {
                mutate(&mut bytes, &mut rng);
            }
        }
        // single-line framing for both codecs: the incremental codec's
        // multi-line documents are deliberately out of scope here
        for b in &mut bytes {
            if *b == b'\n' {
                *b = b'\x0b';
            }
        }
        bytes.push(b'\n');
        let line = run_decoder(CodecKind::Line, limits, &bytes, &mut rng);
        let incr = run_decoder(CodecKind::Incremental, limits, &bytes, &mut rng);
        assert_events_well_formed(&line, &format!("line round {round}"));
        assert_events_well_formed(&incr, &format!("incremental round {round}"));
        let lo = outcomes(&line, &tok, &opts);
        let io = outcomes(&incr, &tok, &opts);
        assert_eq!(
            lo,
            io,
            "round {round}: codecs disagree on {:?}",
            String::from_utf8_lossy(&bytes)
        );
    }
}

/// Structure-aware mutants: both codecs reach the same accept/reject
/// decision (and the same parsed request) for every single-line input.
#[test]
fn fuzz_mutants_codecs_agree() {
    fuzz_mutants(400);
}

/// Long-haul version of the differential mutant fuzz.
#[test]
#[ignore = "deep fuzz; run explicitly"]
fn fuzz_mutants_deep() {
    fuzz_mutants(25_000);
}

/// Replies the server must produce for `bytes`, predicted by replaying
/// the same decoder locally. `None` tokens = an error reply.
fn predict(
    kind: CodecKind,
    opts: &ServeOptions,
    b: &SyntheticBackend,
    bytes: &[u8],
    rng: &mut Rng,
) -> Vec<(Option<Vec<i32>>, Option<&'static str>)> {
    let tok = Tokenizer::new(VOCAB);
    let events = run_decoder(kind, CodecLimits::from_options(opts), bytes, rng);
    outcomes(&events, &tok, opts)
        .into_iter()
        .map(|o| match o {
            Outcome::Accept { prompt, max_tokens, stream } => {
                // default params are greedy; nothing in this byte
                // stream requests streaming, so one reply per frame
                assert!(!stream, "fuzz stream must not request streaming");
                (Some(generate_greedy(b, &prompt, max_tokens).unwrap()), None)
            }
            Outcome::Reject(code) => (None, Some(code)),
        })
        .collect()
}

fn fire_bytes(addr: SocketAddr, bytes: &[u8], rng: &mut Rng) -> Vec<Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    let mut w = stream.try_clone().expect("clone");
    let mut r = BufReader::new(stream);
    let mut at = 0;
    while at < bytes.len() {
        let n = 1 + rng.below(bytes.len() - at);
        w.write_all(&bytes[at..at + n]).expect("write");
        at += n;
    }
    w.flush().expect("flush");
    w.shutdown(Shutdown::Write).expect("shutdown");
    let mut replies = Vec::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line).expect("read") == 0 {
            return replies;
        }
        replies.push(Json::parse(&line).expect("reply must be JSON"));
    }
}

fn fuzz_live(rounds_per_codec: usize) {
    let mut rng = Rng::new(0xF4A2_0003);
    let b = SyntheticBackend::new(VOCAB, SEQ_LEN, 1234);
    for kind in CODECS {
        for round in 0..rounds_per_codec {
            let opts = ServeOptions {
                codec: kind,
                max_tokens_cap: 8,
                ..ServeOptions::default()
            };
            // a guaranteed-clean request first (the decoder is at its
            // start state), then garbage, then more valid requests the
            // garbage may or may not have glued into its own frames —
            // the local replay decides which, so any answer the server
            // gives that differs from the replay is a failure
            let mut bytes = format!("{{\"tokens\":[{}],\"max_tokens\":3}}\n", round % VOCAB)
                .into_bytes();
            for i in 0..6 {
                let len = rng.below(160);
                bytes.extend_from_slice(&garbage(&mut rng, len, true));
                if i % 2 == 0 {
                    bytes.push(b'\n');
                    bytes.extend_from_slice(
                        format!("{{\"tokens\":[{},7],\"max_tokens\":2}}\n", (round + i) % VOCAB)
                            .as_bytes(),
                    );
                }
            }
            let expected = predict(kind, &opts, &b, &bytes, &mut rng);

            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let replies = std::thread::scope(|s| {
                let bytes = &bytes;
                let mut rng = rng.fork(round as u64);
                let cl = s.spawn(move || fire_bytes(addr, bytes, &mut rng));
                serve_on(&b, listener, Some(1), opts).unwrap();
                cl.join().unwrap()
            });

            assert_eq!(
                replies.len(),
                expected.len(),
                "{kind:?} round {round}: reply count != predicted frame count"
            );
            for (i, (reply, (tokens, code))) in replies.iter().zip(&expected).enumerate() {
                match (tokens, code) {
                    (Some(tokens), None) => {
                        let got: Vec<i32> = reply
                            .req("tokens")
                            .expect("completion reply")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|t| t.as_f64().unwrap() as i32)
                            .collect();
                        assert_eq!(&got, tokens, "{kind:?} round {round} reply {i}");
                    }
                    (None, Some(code)) => {
                        let got = reply
                            .req("error")
                            .expect("error reply")
                            .req("code")
                            .unwrap()
                            .as_str()
                            .unwrap()
                            .to_string();
                        assert_eq!(&got, code, "{kind:?} round {round} reply {i}");
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}

/// Garbage against a live scheduler: every frame answered, every
/// answer predicted by an offline replay, orderly EOF — never a wedge.
#[test]
fn fuzz_live_scheduler_survives_garbage() {
    fuzz_live(6);
}

/// Long-haul version of the live-scheduler fuzz.
#[test]
#[ignore = "deep fuzz; run explicitly"]
fn fuzz_live_deep() {
    fuzz_live(120);
}
