//! Property tests for the generation API v2 samplers: seeded
//! determinism, top-k / top-p support restriction, temperature → 0
//! convergence to greedy, repetition penalty respecting the mask, and
//! the batched-equals-sequential invariant under sampling. Pure rust,
//! no artifacts — runs everywhere.

use nvfp4_faar::serve::batch::{decode_step, generate, DecodeSlot};
use nvfp4_faar::serve::{argmax, GenParams, Sampler, SyntheticBackend};
use nvfp4_faar::util::prop::{check, check_msg};
use nvfp4_faar::util::rng::Rng;

const VOCAB: usize = 40;

fn logits_row(rng: &mut Rng) -> Vec<f32> {
    // continuous values: exact ties have measure ~0, so argmax-based
    // reference checks are well-defined
    (0..VOCAB).map(|_| rng.normal_f32(0.0, 2.0)).collect()
}

fn random_params(rng: &mut Rng) -> GenParams {
    GenParams {
        temperature: rng.range_f64(0.05, 2.5) as f32,
        top_k: if rng.bernoulli(0.5) { 1 + rng.below(VOCAB) } else { 0 },
        top_p: if rng.bernoulli(0.5) { rng.range_f64(0.1, 1.0) as f32 } else { 1.0 },
        repetition_penalty: if rng.bernoulli(0.5) { rng.range_f64(0.5, 2.0) as f32 } else { 1.0 },
        seed: rng.next_u64(),
        ..GenParams::default()
    }
}

/// The CTRL repetition-penalty rule, reimplemented from the spec as the
/// test oracle (DESIGN.md §10).
fn penalized(logits: &[f32], history: &[i32], penalty: f32) -> Vec<f32> {
    logits
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            if history.contains(&(i as i32)) {
                if v > 0.0 {
                    v / penalty
                } else {
                    v * penalty
                }
            } else {
                v
            }
        })
        .collect()
}

#[test]
fn prop_seeded_sampling_is_deterministic() {
    check_msg(
        "sampler_seeded_determinism",
        60,
        |rng| {
            let params = random_params(rng);
            let rows: Vec<Vec<f32>> = (0..8).map(|_| logits_row(rng)).collect();
            (params, rows)
        },
        |(params, rows)| {
            let mut a = Sampler::new(params.clone());
            let mut b = Sampler::new(params.clone());
            for row in rows {
                let (x, y) = (a.select(row, &[3, 5]), b.select(row, &[3, 5]));
                if x != y {
                    return Err(format!("same seed diverged: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_top_k_restricts_support() {
    check_msg(
        "sampler_top_k_support",
        80,
        |rng| {
            let k = 1 + rng.below(8);
            let params = GenParams {
                temperature: rng.range_f64(0.2, 3.0) as f32,
                top_k: k,
                seed: rng.next_u64(),
                ..GenParams::default()
            };
            (params, logits_row(rng))
        },
        |(params, row)| {
            let mut s = Sampler::new(params.clone());
            for _ in 0..16 {
                let pick = s.select(row, &[]);
                // strictly-greater count < k  ⇔  pick is among the k highest
                let above = row.iter().filter(|&&v| v > row[pick]).count();
                if above >= params.top_k {
                    return Err(format!(
                        "picked {pick} (logit {}, {above} above) outside top-{}",
                        row[pick], params.top_k
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_top_p_restricts_support_to_the_nucleus() {
    check_msg(
        "sampler_top_p_support",
        80,
        |rng| {
            let params = GenParams {
                temperature: rng.range_f64(0.3, 2.0) as f32,
                top_p: rng.range_f64(0.1, 0.95) as f32,
                seed: rng.next_u64(),
                ..GenParams::default()
            };
            (params, logits_row(rng))
        },
        |(params, row)| {
            // nucleus membership: the cumulative probability of tokens
            // strictly more likely than the pick must be < top_p (else
            // the nucleus was already full before reaching the pick)
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> = row
                .iter()
                .map(|&v| (((v - m) as f64) / params.temperature as f64).exp())
                .collect();
            let total: f64 = weights.iter().sum();
            let mut s = Sampler::new(params.clone());
            for _ in 0..16 {
                let pick = s.select(row, &[]);
                let mass_above: f64 = row
                    .iter()
                    .zip(&weights)
                    .filter(|&(&v, _)| v > row[pick])
                    .map(|(_, &w)| w / total)
                    .sum();
                if mass_above >= params.top_p as f64 {
                    return Err(format!(
                        "picked {pick} with {mass_above:.3} probability mass above it \
                         (top_p {})",
                        params.top_p
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tiny_temperature_converges_to_greedy() {
    check_msg(
        "sampler_temperature_to_zero_is_greedy",
        80,
        |rng| {
            // tiny enough that even a near-tie (gap ~1e-4) gives the
            // runner-up a vanishing win probability — the property is
            // about the limit, not about moderate temperatures
            let t = [1e-5f32, 1e-6, 1e-7][rng.below(3)];
            (t, rng.next_u64(), logits_row(rng))
        },
        |(t, seed, row)| {
            let mut s = Sampler::new(GenParams {
                temperature: *t,
                seed: *seed,
                ..GenParams::default()
            });
            let pick = s.select(row, &[]);
            let best = argmax(row);
            // compare logits, not indices, so an exact tie can't flake
            if row[pick] != row[best] {
                return Err(format!(
                    "temperature {t}: picked logit {} but greedy logit is {}",
                    row[pick], row[best]
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_repetition_penalty_never_escapes_the_mask() {
    check_msg(
        "sampler_penalty_respects_top_k_mask",
        80,
        |rng| {
            let k = 1 + rng.below(6);
            let params = GenParams {
                temperature: rng.range_f64(0.3, 2.0) as f32,
                top_k: k,
                repetition_penalty: rng.range_f64(1.1, 3.0) as f32,
                seed: rng.next_u64(),
                ..GenParams::default()
            };
            let history: Vec<i32> = (0..6).map(|_| rng.below(VOCAB) as i32).collect();
            (params, history, logits_row(rng))
        },
        |(params, history, row)| {
            // the penalty reshapes logits BEFORE the top-k mask, so the
            // selection support is the top-k of the *penalized* row —
            // ids the penalty pushed out of the top-k are unreachable
            let shaped = penalized(row, history, params.repetition_penalty);
            let mut s = Sampler::new(params.clone());
            for _ in 0..16 {
                let pick = s.select(row, history);
                let above = shaped.iter().filter(|&&v| v > shaped[pick]).count();
                if above >= params.top_k {
                    return Err(format!(
                        "picked {pick}, masked out of the penalized top-{}",
                        params.top_k
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_penalty_discourages_repeats() {
    // not a support property but the economic one: with a strong penalty
    // and temperature sampling, repeated ids are picked less often than
    // without the penalty (statistical, fixed seeds — deterministic)
    let row: Vec<f32> = (0..VOCAB).map(|i| if i == 7 { 2.0 } else { 0.0 }).collect();
    let history = vec![7i32];
    let count_hits = |penalty: f32| -> usize {
        let mut s = Sampler::new(GenParams {
            temperature: 1.0,
            repetition_penalty: penalty,
            seed: 99,
            ..GenParams::default()
        });
        (0..400).filter(|_| s.select(&row, &history) == 7).count()
    };
    let unpenalized = count_hits(1.0);
    let with_penalty = count_hits(3.0);
    assert!(
        with_penalty < unpenalized,
        "penalty 3.0 picked the repeated id {with_penalty} times vs {unpenalized} without"
    );
}

#[test]
fn prop_sampled_batched_decode_matches_sequential() {
    check_msg(
        "sampled_batched_equals_sequential",
        12,
        |rng| {
            let backend_seed = rng.next_u64();
            let reqs: Vec<(Vec<i32>, usize, GenParams)> = (0..4)
                .map(|_| {
                    let plen = 1 + rng.below(4);
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| rng.below(VOCAB) as i32).collect();
                    (prompt, 4 + rng.below(8), random_params(rng))
                })
                .collect();
            (backend_seed, reqs)
        },
        |(backend_seed, reqs)| {
            let b = SyntheticBackend::new(VOCAB, 8, *backend_seed);
            let sequential: Vec<Vec<i32>> = reqs
                .iter()
                .map(|(p, n, params)| generate(&b, p, *n, params.clone()).unwrap())
                .collect();
            let mut slots: Vec<DecodeSlot> = reqs
                .iter()
                .map(|(p, n, params)| {
                    DecodeSlot::with_params(p, *n, 8, params.clone()).unwrap()
                })
                .collect();
            while slots.iter().any(|s| !s.done()) {
                decode_step(&b, &mut slots).unwrap();
            }
            for (i, (slot, expect)) in slots.iter().zip(&sequential).enumerate() {
                if &slot.out != expect {
                    return Err(format!(
                        "request {i} diverged: batched {:?} vs sequential {expect:?}",
                        slot.out
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_generate_respects_stop_tokens() {
    check(
        "generate_stop_tokens_never_emitted",
        20,
        |rng| {
            let stop: Vec<i32> = (0..3).map(|_| rng.below(VOCAB) as i32).collect();
            (rng.next_u64(), stop, random_params(rng))
        },
        |(seed, stop, base)| {
            let b = SyntheticBackend::new(VOCAB, 8, *seed);
            let params = GenParams { stop_tokens: stop.clone(), ..base.clone() };
            let out = generate(&b, &[1, 2], 24, params).unwrap();
            out.iter().all(|t| !stop.contains(t))
        },
    );
}
