//! Property-based tests of speculative decoding over the NATIVE backend:
//! a real draft model (same nano preset, different init seed, so it
//! agrees with the target often but not always) proposes tokens, the
//! target verifies them in one multi-row pass, and the emitted stream
//! must be BIT-IDENTICAL to plain sequential decode — greedy and seeded
//! sampling alike. Rejected drafts must roll both KV caches back
//! cleanly: no pages outstanding after release, even under pool
//! pressure that forces the mid-verify fallback path.

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::{
    native_manifest, quantize_store, KvFormat, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::{generate, generate_greedy, spec_generate, GenParams, SpecDecoder};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::prop::check_msg;

const VOCAB: usize = 256; // nano preset vocab

/// Build a nano-preset native backend from `seed`. CI reruns this suite
/// with `FAAR_TEST_KV_FORMAT=e4m3` so the draft-verify rollback path is
/// exercised in the quantized KV format too (spec==plain parity holds
/// per backend regardless of format: both paths read the same cache).
fn nano_backend(seed: u64, mut opts: NativeOptions) -> NativeBackend {
    let manifest = native_manifest("nano").expect("nano preset");
    let fp = ParamStore::init(&manifest, seed);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    if let Ok(name) = std::env::var("FAAR_TEST_KV_FORMAT") {
        opts.kv_format = KvFormat::parse(&name)
            .unwrap_or_else(|| panic!("unknown FAAR_TEST_KV_FORMAT '{name}'"));
    }
    NativeBackend::new(model, opts)
}

/// Target seed 42, draft seed 43 — two real models over the same vocab,
/// so acceptance is partial: some proposals match, some are rejected,
/// and both branches of the accept loop run.
fn divergent_spec(k: usize, opts: NativeOptions) -> SpecDecoder<NativeBackend> {
    SpecDecoder::new(nano_backend(43, opts), k)
}

fn no_leaks(target: &NativeBackend, spec: &SpecDecoder<NativeBackend>) -> Result<(), String> {
    if target.kv_outstanding() != 0 {
        return Err(format!("target leaked {} KV pages", target.kv_outstanding()));
    }
    if spec.draft.kv_outstanding() != 0 {
        return Err(format!("draft leaked {} KV pages", spec.draft.kv_outstanding()));
    }
    if target.cached_slots() != 0 || spec.draft.cached_slots() != 0 {
        return Err("slot cache entries leaked".into());
    }
    Ok(())
}

/// The tentpole invariant on the real model: greedy speculative decode
/// emits bit-for-bit the plain greedy stream, for every speculation
/// depth, and every verify round leaves no KV state behind on either
/// model once the slot releases.
#[test]
fn prop_spec_greedy_bit_identical_to_plain_decode() {
    let target = nano_backend(42, NativeOptions::default());
    check_msg(
        "spec_greedy_parity",
        8,
        |rng| {
            let prompt: Vec<i32> =
                (0..1 + rng.below(6)).map(|_| rng.below(VOCAB) as i32).collect();
            let max_tokens = 2 + rng.below(12);
            let k = 1 + rng.below(8);
            (prompt, max_tokens, k)
        },
        |(prompt, max_tokens, k)| {
            let spec = divergent_spec(*k, NativeOptions::default());
            let expect =
                generate_greedy(&target, prompt, *max_tokens).map_err(|e| e.to_string())?;
            let (got, stats) =
                spec_generate(&target, &spec, prompt, *max_tokens, GenParams::default())
                    .map_err(|e| e.to_string())?;
            if got != expect {
                return Err(format!("k={k}: spec {got:?} != plain {expect:?}"));
            }
            if stats.rounds == 0 || stats.accepted > stats.drafted {
                return Err(format!("implausible counters: {stats:?}"));
            }
            no_leaks(&target, &spec)
        },
    );
    // a draft that IS the target accepts everything: same seed both sides
    let perfect = SpecDecoder::new(nano_backend(42, NativeOptions::default()), 4);
    let (got, stats) =
        spec_generate(&target, &perfect, &[7, 3], 12, GenParams::default()).expect("spec decode");
    assert_eq!(got, generate_greedy(&target, &[7, 3], 12).unwrap());
    assert_eq!(stats.accepted, stats.drafted, "identical draft should never be rejected");
    assert!(stats.drafted > 0);
}

/// Seeded sampling through the verify path reproduces plain sampled
/// decode exactly: the sampler consumes one RNG draw per EMITTED token,
/// so the stream of draws — and therefore every sampled token — is
/// independent of how many proposals each verify round carried.
#[test]
fn prop_spec_seeded_sampling_bit_identical_to_plain_decode() {
    let target = nano_backend(42, NativeOptions::default());
    check_msg(
        "spec_sampling_parity",
        6,
        |rng| {
            let prompt: Vec<i32> =
                (0..1 + rng.below(5)).map(|_| rng.below(VOCAB) as i32).collect();
            let max_tokens = 2 + rng.below(10);
            let k = 1 + rng.below(6);
            let seed = rng.next_u64();
            (prompt, max_tokens, k, seed)
        },
        |(prompt, max_tokens, k, seed)| {
            let params = GenParams {
                temperature: 0.9,
                top_k: 24,
                top_p: 0.95,
                seed: *seed,
                ..GenParams::default()
            };
            let spec = divergent_spec(*k, NativeOptions::default());
            let expect = generate(&target, prompt, *max_tokens, params.clone())
                .map_err(|e| e.to_string())?;
            let (got, _) = spec_generate(&target, &spec, prompt, *max_tokens, params)
                .map_err(|e| e.to_string())?;
            if got != expect {
                return Err(format!("k={k} seed={seed:#x}: sampled spec diverged"));
            }
            no_leaks(&target, &spec)
        },
    );
}

/// KV hygiene under pool pressure: with page_tokens=1 and a pool cap
/// just past the sequence length, verify passes near the cap cannot
/// reserve their multi-row budget and must take the KvExhausted
/// fallback (truncate the dangling reservation, decode one plain row).
/// Decode still completes bit-identically and drains both pools.
#[test]
fn prop_spec_rejected_drafts_release_kv_under_pressure() {
    check_msg(
        "spec_kv_pressure",
        6,
        |rng| {
            let prompt: Vec<i32> =
                (0..1 + rng.below(4)).map(|_| rng.below(VOCAB) as i32).collect();
            let max_tokens = 3 + rng.below(8);
            let k = 2 + rng.below(6);
            (prompt, max_tokens, k)
        },
        |(prompt, max_tokens, k)| {
            // cap leaves room for the sequence plus at most ONE extra
            // page, so a k>=2 verify reserve near the end must fail
            let cap = prompt.len() + *max_tokens + 1;
            let tight =
                NativeOptions { page_tokens: 1, max_pages: cap, ..NativeOptions::default() };
            let target = nano_backend(42, tight);
            let spec = divergent_spec(*k, tight);
            let expect =
                generate_greedy(&target, prompt, *max_tokens).map_err(|e| e.to_string())?;
            let (got, _) =
                spec_generate(&target, &spec, prompt, *max_tokens, GenParams::default())
                    .map_err(|e| e.to_string())?;
            if got != expect {
                return Err(format!("k={k}: spec diverged under pool pressure"));
            }
            no_leaks(&target, &spec)
        },
    );
}

/// Uncached parity: with `use_cache: false` on both models there is no
/// KV state to roll back at all (verify recomputes full windows), and
/// the emitted stream still matches the cached spec path and the plain
/// uncached path.
#[test]
fn prop_spec_uncached_matches_cached_and_plain() {
    let no_cache = NativeOptions { use_cache: false, ..NativeOptions::default() };
    let target = nano_backend(42, no_cache);
    let spec = divergent_spec(4, no_cache);
    let cached_target = nano_backend(42, NativeOptions::default());
    let cached_spec = divergent_spec(4, NativeOptions::default());
    for (prompt, n) in [(vec![5, 9, 2], 10usize), (vec![200], 8), (vec![17, 4], 14)] {
        let plain = generate_greedy(&target, &prompt, n).unwrap();
        let (uncached, _) =
            spec_generate(&target, &spec, &prompt, n, GenParams::default()).unwrap();
        let (cached, _) =
            spec_generate(&cached_target, &cached_spec, &prompt, n, GenParams::default())
                .unwrap();
        assert_eq!(uncached, plain, "uncached spec diverged for {prompt:?}");
        assert_eq!(cached, plain, "cached spec diverged for {prompt:?}");
    }
    assert_eq!(cached_target.kv_outstanding(), 0);
    assert_eq!(cached_spec.draft.kv_outstanding(), 0);
}
