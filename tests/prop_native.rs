//! Property-based tests over the native inference stack: the paged KV
//! cache (round-trips, page reuse, capacity), the fused dequant-GEMM
//! kernels against their dense reference, and decode parity between the
//! cached, uncached, batched, and sequential native paths.

use nvfp4_faar::formats::codec::{codec_for, rtn_decisions, FormatKind};
use nvfp4_faar::formats::e4m3;
use nvfp4_faar::infer::kernels::{decode_nibbles, kernel_path, KernelPath, Linear};
use nvfp4_faar::infer::kv::{KvFormat, KvLayout, KvPool, KvSeq};
use nvfp4_faar::infer::{
    native_manifest, quantize_store, NativeBackend, NativeModel, NativeOptions,
};
use nvfp4_faar::serve::batch::{decode_step, DecodeSlot};
use nvfp4_faar::serve::{generate_greedy, StepBackend};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::prop::{check_msg, gen};

// ---------------------------------------------------------------------------
// KV cache properties

#[test]
fn prop_kv_append_read_roundtrip() {
    check_msg(
        "kv_roundtrip",
        40,
        |rng| {
            let layers = 1 + rng.below(3);
            let d = 4 * (1 + rng.below(4));
            let page_tokens = 1 + rng.below(5);
            let tokens = 1 + rng.below(20);
            (layers, d, page_tokens, tokens, rng.next_u64())
        },
        |&(layers, d, page_tokens, tokens, seed)| {
            let layout =
                KvLayout { n_layers: layers, d_model: d, page_tokens, format: KvFormat::F32 };
            let mut pool = KvPool::unbounded(layout);
            let mut seq = KvSeq::new(layout);
            // write a distinct recognizable pattern per (token, layer)
            for t in 0..tokens {
                seq.push(&mut pool).map_err(|e| e.to_string())?;
                for l in 0..layers {
                    let (k, v) = seq.kv_mut(t, l);
                    for (i, x) in k.iter_mut().enumerate() {
                        *x = (seed % 97) as f32 + (t * 1000 + l * 100 + i) as f32;
                    }
                    for (i, x) in v.iter_mut().enumerate() {
                        *x = -((t * 1000 + l * 100 + i) as f32);
                    }
                }
            }
            if seq.len() != tokens {
                return Err(format!("len {} != {tokens}", seq.len()));
            }
            let expect_pages = tokens.div_ceil(page_tokens);
            if seq.n_pages() != expect_pages || pool.outstanding() != expect_pages {
                return Err(format!(
                    "pages {} / outstanding {} != {expect_pages}",
                    seq.n_pages(),
                    pool.outstanding()
                ));
            }
            // read back every entry, including across page boundaries
            for t in 0..tokens {
                for l in 0..layers {
                    let k = seq.k(t, l);
                    let v = seq.v(t, l);
                    for i in 0..d {
                        let want_k = (seed % 97) as f32 + (t * 1000 + l * 100 + i) as f32;
                        if k[i] != want_k {
                            return Err(format!("k[{t}][{l}][{i}] = {} != {want_k}", k[i]));
                        }
                        if v[i] != -((t * 1000 + l * 100 + i) as f32) {
                            return Err(format!("v[{t}][{l}][{i}] corrupted"));
                        }
                    }
                }
            }
            seq.clear(&mut pool);
            if pool.outstanding() != 0 || pool.free_pages() != expect_pages {
                return Err("clear did not return every page".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_page_reuse_after_free() {
    check_msg(
        "kv_page_reuse",
        30,
        |rng| (1 + rng.below(4), 1 + rng.below(6)),
        |&(page_tokens, rounds)| {
            let layout =
                KvLayout { n_layers: 2, d_model: 8, page_tokens, format: KvFormat::F32 };
            let mut pool = KvPool::new(layout, 8);
            let mut high_water = 0;
            for _ in 0..rounds {
                let mut seq = KvSeq::new(layout);
                for _ in 0..page_tokens * 3 {
                    seq.push(&mut pool).map_err(|e| e.to_string())?;
                }
                high_water = high_water.max(pool.outstanding());
                seq.clear(&mut pool);
            }
            // repeated fill/free cycles never allocate past one round's
            // footprint: freed pages are reused, not abandoned
            if high_water != 3 {
                return Err(format!("expected 3 pages per round, saw {high_water}"));
            }
            if pool.outstanding() != 0 {
                return Err("pages left outstanding".into());
            }
            if pool.free_pages() != 3 {
                return Err(format!("free list holds {} pages, expected 3", pool.free_pages()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_capacity_rejection() {
    check_msg(
        "kv_capacity",
        30,
        |rng| (1 + rng.below(3), 1 + rng.below(4)),
        |&(page_tokens, max_pages)| {
            let layout =
                KvLayout { n_layers: 1, d_model: 4, page_tokens, format: KvFormat::F32 };
            let mut pool = KvPool::new(layout, max_pages);
            let mut seq = KvSeq::new(layout);
            // exactly max_pages * page_tokens pushes fit
            for _ in 0..max_pages * page_tokens {
                seq.push(&mut pool).map_err(|e| e.to_string())?;
            }
            let err = match seq.push(&mut pool) {
                Err(e) => e,
                Ok(()) => return Err("push past capacity succeeded".into()),
            };
            if err.downcast_ref::<nvfp4_faar::infer::kv::KvExhausted>().is_none() {
                return Err(format!("wrong rejection error: {err}"));
            }
            // rejection is non-destructive
            if seq.len() != max_pages * page_tokens {
                return Err("failed push mutated the sequence".into());
            }
            seq.clear(&mut pool);
            Ok(())
        },
    );
}

#[test]
fn prop_kv_reserve_equals_pushes_and_is_atomic() {
    check_msg(
        "kv_reserve",
        30,
        |rng| {
            let page_tokens = 1 + rng.below(5);
            let pre = rng.below(7);
            let extra = 1 + rng.below(12);
            let max_pages = 1 + rng.below(6);
            (page_tokens, pre, extra, max_pages)
        },
        |&(page_tokens, pre, extra, max_pages)| {
            let layout =
                KvLayout { n_layers: 2, d_model: 8, page_tokens, format: KvFormat::F32 };
            // reserve(extra) after `pre` pushes leaves the same geometry
            // as pre + extra pushes
            let mut pool = KvPool::unbounded(layout);
            let mut a = KvSeq::new(layout);
            let mut b = KvSeq::new(layout);
            for _ in 0..pre {
                a.push(&mut pool).map_err(|e| e.to_string())?;
                b.push(&mut pool).map_err(|e| e.to_string())?;
            }
            a.reserve(&mut pool, extra).map_err(|e| e.to_string())?;
            for _ in 0..extra {
                b.push(&mut pool).map_err(|e| e.to_string())?;
            }
            if (a.len(), a.n_pages()) != (b.len(), b.n_pages()) {
                return Err(format!(
                    "reserve geometry ({}, {}) != push geometry ({}, {})",
                    a.len(),
                    a.n_pages(),
                    b.len(),
                    b.n_pages()
                ));
            }
            a.clear(&mut pool);
            b.clear(&mut pool);

            // atomicity: a reserve that cannot fully fit takes nothing
            let mut small = KvPool::new(layout, max_pages);
            let mut c = KvSeq::new(layout);
            let fits = max_pages * page_tokens;
            c.reserve(&mut small, fits).map_err(|e| e.to_string())?;
            let before = (c.len(), c.n_pages(), small.outstanding());
            if c.reserve(&mut small, page_tokens).is_ok() {
                return Err("reserve past the pool cap succeeded".into());
            }
            if before != (c.len(), c.n_pages(), small.outstanding()) {
                return Err("failed reserve mutated the sequence or pool".into());
            }
            c.clear(&mut small);
            if small.outstanding() != 0 {
                return Err("pages leaked after clear".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_shared_pages_freed_exactly_once() {
    // refcounted sharing: two sequences share a full-page prefix and grow
    // private tails; every physical page returns to the pool exactly once,
    // whichever owner drops last
    check_msg(
        "kv_shared_free",
        30,
        |rng| {
            let page_tokens = 1 + rng.below(4);
            let shared_pages = 1 + rng.below(3);
            let a_extra = rng.below(2 * page_tokens + 1);
            let b_extra = rng.below(2 * page_tokens + 1);
            (page_tokens, shared_pages, a_extra, b_extra)
        },
        |&(page_tokens, shared_pages, a_extra, b_extra)| {
            let layout =
                KvLayout { n_layers: 2, d_model: 8, page_tokens, format: KvFormat::F32 };
            let mut pool = KvPool::unbounded(layout);
            // build the shared full-page prefix in A
            let mut a = KvSeq::new(layout);
            for _ in 0..shared_pages * page_tokens {
                a.push(&mut pool).map_err(|e| e.to_string())?;
            }
            // B attaches every one of A's pages, then both grow privately
            let mut b = KvSeq::new(layout);
            for i in 0..shared_pages {
                b.attach(a.page_handle(i));
            }
            if b.len() != a.len() {
                return Err(format!("attach length {} != {}", b.len(), a.len()));
            }
            for i in 0..shared_pages {
                if a.page_refs(i) < 2 {
                    return Err(format!("page {i} not shared: {} refs", a.page_refs(i)));
                }
            }
            for _ in 0..a_extra {
                a.push(&mut pool).map_err(|e| e.to_string())?;
            }
            for _ in 0..b_extra {
                b.push(&mut pool).map_err(|e| e.to_string())?;
            }
            // outstanding counts physical pages: shared prefix once, plus
            // each private tail
            let physical = shared_pages
                + a_extra.div_ceil(page_tokens)
                + b_extra.div_ceil(page_tokens);
            if pool.outstanding() != physical {
                return Err(format!("outstanding {} != physical {physical}", pool.outstanding()));
            }
            // dropping one owner keeps the shared pages alive...
            a.clear(&mut pool);
            let still = shared_pages + b_extra.div_ceil(page_tokens);
            if pool.outstanding() != still {
                return Err(format!(
                    "clearing one owner left {} pages, expected {still}",
                    pool.outstanding()
                ));
            }
            // ...and the last owner frees each page exactly once
            b.clear(&mut pool);
            if pool.outstanding() != 0 {
                return Err(format!("{} pages leaked", pool.outstanding()));
            }
            if pool.free_pages() != physical {
                return Err(format!(
                    "free list holds {} pages, expected {physical} (double free?)",
                    pool.free_pages()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prefix_trie_lookup_returns_longest_published_prefix() {
    use nvfp4_faar::infer::PrefixCache;
    use std::sync::Arc;
    check_msg(
        "prefix_trie",
        30,
        |rng| {
            let page_tokens = 1 + rng.below(4);
            let pages = 1 + rng.below(4);
            let tokens: Vec<i32> =
                (0..pages * page_tokens).map(|_| rng.below(16) as i32).collect();
            let probe_pages = rng.below(pages + 1);
            (page_tokens, pages, tokens, probe_pages)
        },
        |(page_tokens, pages, tokens, probe_pages)| {
            let (page_tokens, pages, probe_pages) = (*page_tokens, *pages, *probe_pages);
            let layout =
                KvLayout { n_layers: 1, d_model: 4, page_tokens, format: KvFormat::F32 };
            let mut pool = KvPool::unbounded(layout);
            let mut seq = KvSeq::new(layout);
            for _ in 0..pages * page_tokens {
                seq.push(&mut pool).map_err(|e| e.to_string())?;
            }
            let handles: Vec<_> = (0..pages).map(|i| seq.page_handle(i)).collect();
            let mut trie = PrefixCache::new(page_tokens);
            trie.publish(tokens, &handles);
            if trie.len() != pages {
                return Err(format!("trie holds {} pages, expected {pages}", trie.len()));
            }
            // a probe sharing exactly probe_pages full pages (diverging
            // right after — 99 is outside the generated token range)
            let mut probe: Vec<i32> = tokens[..probe_pages * page_tokens].to_vec();
            probe.push(99);
            let got = trie.lookup(&probe);
            if got.len() != probe_pages {
                return Err(format!("lookup gave {} pages, expected {probe_pages}", got.len()));
            }
            for (i, h) in got.iter().enumerate() {
                if !Arc::ptr_eq(h, &handles[i]) {
                    return Err(format!("lookup page {i} is not the published page"));
                }
            }
            // every handle funnels back through the pool exactly once
            for h in got {
                pool.release(h);
            }
            seq.clear(&mut pool);
            for h in handles {
                pool.release(h);
            }
            trie.clear(&mut pool);
            if pool.outstanding() != 0 {
                return Err(format!("{} pages leaked", pool.outstanding()));
            }
            if pool.free_pages() != pages {
                return Err(format!(
                    "free list holds {} pages, expected {pages} (double free?)",
                    pool.free_pages()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Fused kernel vs dense reference

#[test]
fn prop_fused_matvec_matches_dense_reference() {
    for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
        let codec = codec_for(kind);
        check_msg(
            &format!("fused_matvec_{}", codec.name()),
            20,
            |rng| {
                let w = gen::f32_heavy(rng, 64 * 16);
                let x = gen::f32_normal(rng, 64, 1.0);
                (w, x)
            },
            |(wv, x)| {
                let w = Tensor::new(wv.clone(), vec![64, 16]);
                let p = codec.prepare(&w);
                let q = codec.encode(&w, &p, &rtn_decisions(&p));
                let deq = q.dequantize().map_err(|e| e.to_string())?;
                let lin = Linear::from(q);
                let mut y = vec![0.0f32; 16];
                let mut scratch = Vec::new();
                lin.matvec(0, x, &mut y, &mut scratch, 1).map_err(|e| e.to_string())?;
                for col in 0..16 {
                    let mut want = 0.0f32;
                    for row in 0..64 {
                        want += x[row] * deq.data[row * 16 + col];
                    }
                    let tol = 1e-3 * want.abs().max(1e-2);
                    if (y[col] - want).abs() > tol {
                        return Err(format!(
                            "{}: col {col}: fused {} vs dense {want}",
                            codec.name(),
                            y[col]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_matmul_rows_bitwise_equal_matvec() {
    // the multi-row fused GEMM tentpole invariant, as a property: for
    // every format, random M (around and past the register tile), and
    // both the scalar and the column-parallel path, each output row of
    // matmul is BITWISE the matvec of its input row. "big" cases use a
    // [1, 128, 128] stack with m >= 16 so m*k*n crosses PAR_MACS and
    // workers > 1 genuinely takes the parallel branch.
    for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
        let codec = codec_for(kind);
        check_msg(
            &format!("matmul_rows_{}", codec.name()),
            12,
            |rng| {
                let big = rng.below(2) == 1;
                let (lead, k, n, m) = if big {
                    (1usize, 128usize, 128usize, 16 + rng.below(8))
                } else {
                    (2, 64, 32, 1 + rng.below(20))
                };
                let w = gen::f32_heavy(rng, lead * k * n);
                let x = gen::f32_normal(rng, m * k, 1.0);
                let workers = 1 + rng.below(4);
                (w, x, lead, k, n, m, workers)
            },
            |(wv, x, lead, k, n, m, workers)| {
                let (lead, k, n, m, workers) = (*lead, *k, *n, *m, *workers);
                let w = Tensor::new(wv.clone(), vec![lead, k, n]);
                let p = codec.prepare(&w);
                let lin = Linear::from(codec.encode(&w, &p, &rtn_decisions(&p)));
                let mut scratch = Vec::new();
                for l in 0..lead {
                    let mut ym = vec![0.0f32; m * n];
                    lin.matmul(l, x, m, &mut ym, &mut scratch, workers)
                        .map_err(|e| e.to_string())?;
                    for mi in 0..m {
                        let mut yv = vec![0.0f32; n];
                        lin.matvec(l, &x[mi * k..(mi + 1) * k], &mut yv, &mut scratch, 1)
                            .map_err(|e| e.to_string())?;
                        if ym[mi * n..(mi + 1) * n] != yv[..] {
                            return Err(format!(
                                "{}: l={l} m={m} workers={workers} row {mi} != matvec",
                                codec.name()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_simd_decode_bitwise_equals_scalar() {
    // the SIMD tentpole invariant as a property: for every format and
    // ragged (non-multiple-of-32) code-row lengths, the dispatched
    // vector nibble decode produces bit-identical f32s to the scalar LUT
    // reference — including code 8, whose element value is -0.0 (the
    // sign bit must survive the vector lookup)
    let path = kernel_path();
    for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
        let codec = codec_for(kind);
        let tables = kind.decode_tables();
        check_msg(
            &format!("simd_decode_{}", codec.name()),
            20,
            |rng| {
                let w = gen::f32_heavy(rng, 64 * 34);
                let row = rng.below(64);
                // trailing bytes to drop: exercises every tail length the
                // scalar cleanup loop can see, odd counts included
                let cut = rng.below(17);
                (w, row, cut)
            },
            |(wv, row, cut)| {
                let w = Tensor::new(wv.clone(), vec![64, 34]);
                let p = codec.prepare(&w);
                let q = codec.encode(&w, &p, &rtn_decisions(&p));
                let dec = q.block_decode_cached(&tables).map_err(|e| e.to_string())?;
                let bytes = dec.code_row(0, *row);
                let bytes = &bytes[..bytes.len() - cut];
                let n = 2 * bytes.len();
                let mut scalar = vec![0.0f32; n];
                let mut simd = vec![0.0f32; n];
                decode_nibbles(KernelPath::Scalar, dec.elem_table(), bytes, &mut scalar);
                decode_nibbles(path, dec.elem_table(), bytes, &mut simd);
                for i in 0..n {
                    if scalar[i].to_bits() != simd[i].to_bits() {
                        return Err(format!(
                            "{}: {path:?} elem {i}/{n}: {} != scalar {}",
                            codec.name(),
                            simd[i],
                            scalar[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_e4m3_kv_store_read_is_codec_roundtrip() {
    // the quantized KV cache adds no error beyond the e4m3 codec itself:
    // every row read back is exactly roundtrip(clamp(x)) elementwise
    check_msg(
        "e4m3_kv_roundtrip",
        30,
        |rng| {
            let layers = 1 + rng.below(3);
            let d = 4 * (1 + rng.below(4));
            let page_tokens = 1 + rng.below(5);
            let tokens = 1 + rng.below(16);
            let rows = gen::f32_normal(rng, tokens * layers * 2 * d, 3.0);
            (layers, d, page_tokens, tokens, rows)
        },
        |(layers, d, page_tokens, tokens, rows)| {
            let (layers, d, page_tokens, tokens) = (*layers, *d, *page_tokens, *tokens);
            let layout =
                KvLayout { n_layers: layers, d_model: d, page_tokens, format: KvFormat::E4m3 };
            let mut pool = KvPool::unbounded(layout);
            let mut seq = KvSeq::new(layout);
            for t in 0..tokens {
                seq.push(&mut pool).map_err(|e| e.to_string())?;
                for l in 0..layers {
                    let base = (t * layers + l) * 2 * d;
                    seq.store_kv(t, l, &rows[base..base + d], &rows[base + d..base + 2 * d]);
                }
            }
            let mut buf = vec![0.0f32; d];
            for t in 0..tokens {
                for l in 0..layers {
                    let base = (t * layers + l) * 2 * d;
                    for (which, off) in [("k", 0usize), ("v", d)] {
                        let got: Vec<f32> = if off == 0 {
                            seq.k_row(t, l, &mut buf).to_vec()
                        } else {
                            seq.v_row(t, l, &mut buf).to_vec()
                        };
                        for i in 0..d {
                            let x = rows[base + off + i];
                            let want =
                                e4m3::roundtrip(x.clamp(-e4m3::E4M3_MAX, e4m3::E4M3_MAX));
                            if got[i].to_bits() != want.to_bits() {
                                return Err(format!(
                                    "{which}[{t}][{l}][{i}]: {} != roundtrip({x}) = {want}",
                                    got[i]
                                ));
                            }
                        }
                    }
                }
            }
            seq.clear(&mut pool);
            if pool.outstanding() != 0 {
                return Err("pages leaked".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prefill_bitwise_equals_token_by_token() {
    // random prompts through the batched prefill path vs the
    // token-by-token reference: logits must be bit-identical
    let manifest = native_manifest("nano").expect("preset");
    let fp = ParamStore::init(&manifest, 17);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    check_msg(
        "prefill_parity",
        10,
        |rng| {
            let t = 1 + rng.below(64);
            let prompt: Vec<i32> = (0..t).map(|_| rng.below(256) as i32).collect();
            prompt
        },
        |prompt| {
            let reference = model.logits_window(prompt).map_err(|e| e.to_string())?;
            let fast = model.prefill(prompt).map_err(|e| e.to_string())?;
            if fast != reference {
                return Err(format!("prefill diverged at T={}", prompt.len()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Decode parity across every native path

fn nano_backend(use_cache: bool, seed: u64) -> NativeBackend {
    let manifest = native_manifest("nano").expect("preset");
    let fp = ParamStore::init(&manifest, seed);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    NativeBackend::new(model, NativeOptions { use_cache, ..NativeOptions::default() })
}

#[test]
fn prop_native_prefix_cache_bit_identical_and_drains() {
    let plain = nano_backend(false, 42);
    // one shared cached backend across cases: the trie persists, so later
    // cases exercise warm lookups as well as cold publishes
    let manifest = native_manifest("nano").expect("preset");
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    let cached = NativeBackend::new(
        model,
        NativeOptions {
            use_cache: true,
            prefix_cache: true,
            page_tokens: 4,
            ..NativeOptions::default()
        },
    );
    check_msg(
        "prefix_cache_parity",
        6,
        |rng| {
            let base: Vec<i32> = (0..8).map(|_| rng.below(256) as i32).collect();
            let suffixes: Vec<Vec<i32>> = (0..2)
                .map(|_| (0..1 + rng.below(4)).map(|_| rng.below(256) as i32).collect())
                .collect();
            let max_tokens = 3 + rng.below(5);
            (base, suffixes, max_tokens)
        },
        |(base, suffixes, max_tokens)| {
            let n = *max_tokens;
            for suffix in suffixes {
                let mut prompt = base.clone();
                prompt.extend_from_slice(suffix);
                let expect = generate_greedy(&plain, &prompt, n).map_err(|e| e.to_string())?;
                let got = generate_greedy(&cached, &prompt, n).map_err(|e| e.to_string())?;
                if got != expect {
                    return Err(format!("prefix-cached decode diverged for {prompt:?}"));
                }
            }
            // all slots drained: only trie-held pages stay outstanding
            let stats =
                cached.prefix_stats().ok_or_else(|| "prefix stats missing".to_string())?;
            if cached.kv_outstanding() != stats.stored_pages {
                return Err(format!(
                    "outstanding {} != trie pages {}",
                    cached.kv_outstanding(),
                    stats.stored_pages
                ));
            }
            Ok(())
        },
    );
    let stats = cached.prefix_stats().expect("prefix stats");
    assert!(stats.lookups > 0, "prefix cache never consulted");
    assert!(stats.hits > 0, "shared-prefix prompts never hit the trie");
    cached.clear_prefix_cache();
    assert_eq!(cached.kv_outstanding(), 0, "KV pages leaked after trie clear");
}

#[test]
fn prop_native_cached_batched_sequential_all_agree() {
    let cached = nano_backend(true, 42);
    let plain = nano_backend(false, 42);
    check_msg(
        "native_decode_parity",
        6,
        |rng| {
            let n_prompts = 2 + rng.below(3);
            let prompts: Vec<Vec<i32>> = (0..n_prompts)
                .map(|_| (0..1 + rng.below(5)).map(|_| rng.below(256) as i32).collect())
                .collect();
            let max_tokens = 4 + rng.below(8);
            (prompts, max_tokens)
        },
        |(prompts, max_tokens)| {
            let n = *max_tokens;
            // sequential, KV-cached
            let seq_cached: Vec<Vec<i32>> = prompts
                .iter()
                .map(|p| generate_greedy(&cached, p, n))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            // sequential, uncached
            for (p, expect) in prompts.iter().zip(&seq_cached) {
                let got = generate_greedy(&plain, p, n).map_err(|e| e.to_string())?;
                if &got != expect {
                    return Err(format!("uncached diverged for {p:?}"));
                }
            }
            // batched, KV-cached
            let mut slots: Vec<DecodeSlot> = prompts
                .iter()
                .map(|p| DecodeSlot::new(p, n, 64))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            while slots.iter().any(|s| !s.done()) {
                decode_step(&cached, &mut slots).map_err(|e| e.to_string())?;
            }
            for (slot, expect) in slots.iter().zip(&seq_cached) {
                if &slot.out != expect {
                    return Err("batched diverged from sequential".into());
                }
                cached.release(slot);
            }
            if cached.kv_outstanding() != 0 {
                return Err(format!("{} KV pages leaked", cached.kv_outstanding()));
            }
            Ok(())
        },
    );
}
