//! Runtime integration: artifact loading, execution, validation, and the
//! cross-language numerics parity checks (rust codec vs the AOT graphs
//! lowered from ref.py). Needs `make artifacts` (nano) and a real XLA
//! backend — each test skips with a notice when they are absent so
//! tier-1 stays green in artifact-less environments.

use std::path::Path;

use nvfp4_faar::formats::nvfp4;
use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping: artifacts/nano missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    // the `xla` dependency may be the vendored stub: probe one compile
    // and skip (rather than panic mid-test) when the backend is absent
    if let Err(e) = rt.executable("lm_fwd") {
        eprintln!("skipping: XLA backend unavailable ({e})");
        return None;
    }
    Some(rt)
}

fn rand_t(shape: &[usize], seed: u64, std: f32) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 0.0, std);
    t
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn manifest_loads_and_validates() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.config().name, "nano");
    assert_eq!(rt.manifest.qlinears.len(), 7);
    assert_eq!(rt.manifest.qshapes().len(), 3);
    assert!(rt.manifest.artifact("stage2_step").is_ok());
    assert!(rt.manifest.artifact("bogus").is_err());
}

#[test]
fn exec_validates_shapes_and_dtypes() {
    let Some(rt) = runtime() else { return };
    let d = rt.config().d_model;
    let l = rt.config().n_layers;
    // wrong arg count
    assert!(rt.exec("prepare_64x64", &[]).is_err());
    // wrong shape
    let bad = Value::F32(Tensor::zeros(&[l, d, d + 1]));
    assert!(rt.exec("prepare_64x64", &[bad]).is_err());
    // wrong dtype
    let bad = Value::I32(vec![0; l * d * d], vec![l, d, d]);
    assert!(rt.exec("prepare_64x64", &[bad]).is_err());
    // correct
    let ok = Value::F32(rand_t(&[l, d, d], 1, 0.05));
    let out = rt.exec("prepare_64x64", &[ok]).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn rust_prepare_matches_aot_prepare() {
    // Cross-language parity: rust codec (formats::nvfp4) vs the jax graph
    // lowered from ref.quant_prepare, on the same weights.
    //
    // XLA's algebraic simplifier folds the divisions (`/6/s_g`, `/2688`)
    // into reciprocal multiplies, shifting results by ≤1 f32 ulp; at an
    // exact E4M3 round-to-nearest-even tie that flips the block scale by
    // one mantissa step (12.5%). So the contract is semantic, not
    // bit-exact: every scale within one E4M3 step, the vast majority of
    // elements identical, intervals always valid.
    let Some(rt) = runtime() else { return };
    let d = rt.config().d_model;
    let l = rt.config().n_layers;
    for seed in [1u64, 2, 3, 4, 5] {
        let w = rand_t(&[l, d, d], seed, 0.05);
        let p_rust = nvfp4::prepare(&w);
        let out = rt.exec("prepare_64x64", &[Value::F32(w.clone())]).unwrap();
        let lower = out[0].as_tensor().unwrap();
        let upper = out[1].as_tensor().unwrap();
        let scale = out[2].as_tensor().unwrap();
        let v_init = out[3].as_tensor().unwrap();

        let n = w.numel();
        let mut node_mismatch = 0usize;
        for i in 0..n {
            let rel = (p_rust.scale.data[i] - scale.data[i]).abs()
                / scale.data[i].max(1e-30);
            assert!(
                rel <= 0.13,
                "seed {seed} i={i}: scale off by more than one E4M3 step \
                 ({} vs {})",
                p_rust.scale.data[i],
                scale.data[i]
            );
            if p_rust.lower.data[i] != lower.data[i]
                || p_rust.upper.data[i] != upper.data[i]
            {
                // legitimate when the scales differ OR when wt sits on a
                // node boundary (the graph computes |w|/s with a folded
                // reciprocal, ±1 ulp)
                node_mismatch += 1;
            } else if rel < 1e-7 {
                // identical scale + identical interval → v_init must agree
                let dv = (p_rust.v_init.data[i] - v_init.data[i]).abs();
                assert!(dv < 2e-4, "seed {seed} i={i}: v_init diff {dv}");
            }
            // interval invariants on the AOT side
            assert!(lower.data[i] <= upper.data[i]);
            assert!((0.0..=1.0).contains(&v_init.data[i]));
        }
        assert!(
            node_mismatch * 100 < n,
            "seed {seed}: {node_mismatch}/{n} interval mismatches (>1%)"
        );
    }
}

#[test]
fn rust_rtn_matches_aot_rtn_kernel() {
    // Same semantic-parity contract as prepare (see above): XLA's folded
    // reciprocals shift w̃ by ±1 ulp, flipping rare boundary elements to
    // the adjacent node. Require: <1% of elements differ, and every
    // difference is at most one interval step.
    let Some(rt) = runtime() else { return };
    let d = rt.config().d_model;
    let w = rand_t(&[d, d], 7, 0.05);
    let out = rt.exec("kernel_rtn", &[Value::F32(w.clone())]).unwrap();
    let q_aot = out[0].as_tensor().unwrap();
    let p = nvfp4::prepare(&w);
    let q_rust = nvfp4::rtn_quant(&w, &p);
    let mut mismatch = 0usize;
    for i in 0..w.numel() {
        let d_i = (q_aot.data[i] - q_rust.data[i]).abs();
        if d_i > 1e-7 {
            mismatch += 1;
            let step = (p.upper.data[i] - p.lower.data[i] + 0.5) * p.scale.data[i] * 1.3;
            assert!(d_i <= step.max(1e-6), "i={i}: diff {d_i} beyond one grid step");
        }
    }
    assert!(
        mismatch * 100 < w.numel(),
        "{mismatch}/{} rtn elements differ (>1%)",
        w.numel()
    );
}

#[test]
fn pallas_kernel_matches_jnp_kernel() {
    let Some(rt) = runtime() else { return };
    let d = rt.config().d_model;
    let w = rand_t(&[d, d], 9, 0.05);
    let p = nvfp4::prepare(&w);
    let args = vec![
        Value::F32(w),
        Value::F32(p.lower),
        Value::F32(p.upper),
        Value::F32(p.scale),
        Value::F32(p.v_init),
        Value::scalar_f32(17.0),
    ];
    let a = rt.exec("kernel_softquant", &args).unwrap();
    let b = rt.exec("kernel_softquant_jnp", &args).unwrap();
    let diff = max_abs_diff(&a[0].as_tensor().unwrap().data, &b[0].as_tensor().unwrap().data);
    assert!(diff < 2e-6, "pallas/jnp parity: max diff {diff}");
}

#[test]
fn lm_fwd_runs_and_nll_reasonable() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.config().clone();
    let params = ParamStore::init(&rt.manifest, 42);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> =
        (0..cfg.eval_batch * (cfg.seq_len + 1)).map(|_| rng.below(cfg.vocab) as i32).collect();
    let mut args = params.values();
    args.push(Value::I32(toks, vec![cfg.eval_batch, cfg.seq_len + 1]));
    let out = rt.exec("lm_fwd", &args).unwrap();
    let nll = out[0].as_tensor().unwrap();
    assert_eq!(nll.shape, vec![cfg.eval_batch, cfg.seq_len]);
    // untrained model on uniform tokens: NLL ≈ ln(vocab)
    let mean: f32 = nll.data.iter().sum::<f32>() / nll.numel() as f32;
    let expect = (cfg.vocab as f32).ln();
    assert!(
        (mean - expect).abs() < 0.5,
        "untrained NLL {mean} should be ~ln(vocab)={expect}"
    );
    let hid = out[1].as_tensor().unwrap();
    assert_eq!(hid.shape, vec![cfg.eval_batch, cfg.seq_len, cfg.d_model]);
}

#[test]
fn executable_cache_reuses() {
    let Some(rt) = runtime() else { return };
    let a = rt.executable("lm_fwd").unwrap();
    let b = rt.executable("lm_fwd").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn exec_counts_tracked() {
    let Some(rt) = runtime() else { return };
    let d = rt.config().d_model;
    let l = rt.config().n_layers;
    let w = Value::F32(rand_t(&[l, d, d], 3, 0.05));
    rt.exec("prepare_64x64", &[w.clone()]).unwrap();
    rt.exec("prepare_64x64", &[w]).unwrap();
    assert_eq!(rt.exec_counts()["prepare_64x64"], 2);
}
