"""L2 — optimization step graphs (lowered once, looped from rust).

Each step function takes and returns *flat* tensor lists in the canonical
manifest order, with optimizer state carried through the graph so the rust
driver never touches Adam math: it just re-feeds outputs as inputs
(device-resident via execute_b — see rust/src/runtime/).

  pretrain_step — AdamW + global-norm clip on all 12 weight tensors
  stage1_step   — FAAR layer-wise rounding (paper eq. 5): Adam on V only,
                  V clipped to [0,1] after the update, Pallas soft-quant
                  on the hot path
  stage2_step   — 2FA global alignment (paper eq. 6): KL(logits) +
                  MSE(last hidden) + rounding regularizer, Adam on the 7
                  stacked V tensors
"""

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelConfig, weight_specs
from .kernels import ref, nvfp4

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(p, g, m, v, step, lr, wd=0.0):
    """One Adam(W) update. `step` is a 1-based f32 scalar (bias correction
    uses exp/log so it stays a traced value)."""
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    bc1 = 1.0 - jnp.exp(step * jnp.log(ADAM_B1))
    bc2 = 1.0 - jnp.exp(step * jnp.log(ADAM_B2))
    mh = m2 / bc1
    vh = v2 / bc2
    p2 = p - lr * (mh / (jnp.sqrt(vh) + ADAM_EPS) + wd * p)
    return p2, m2, v2


def global_norm_clip(grads, max_norm=1.0):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / gn)
    return [g * scale for g in grads], gn


# ---------------------------------------------------------------------------

def pretrain_step(cfg: ModelConfig, weights, ms, vs, tokens, step, lr):
    """One AdamW LM step. tokens: [B, T+1] (context + shifted targets)."""
    specs = weight_specs(cfg)
    names = [s[0] for s in specs]
    wd_flags = {s[0]: s[4] for s in specs}

    def loss_fn(ws):
        params = dict(zip(names, ws))
        logits, _, _ = model.fwd(cfg, params, tokens[:, :-1])
        nll = model.nll_from_logits(logits, tokens[:, 1:])
        return jnp.mean(nll)

    loss, grads = jax.value_and_grad(loss_fn)(list(weights))
    grads, _ = global_norm_clip(grads, 1.0)

    new_w, new_m, new_v = [], [], []
    for name, p, g, m, v in zip(names, weights, grads, ms, vs):
        wd = 0.01 if wd_flags[name] else 0.0
        p2, m2, v2 = adam_update(p, g, m, v, step, lr, wd)
        new_w.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (*new_w, *new_m, *new_v, loss)


# ---------------------------------------------------------------------------

def stage1_step(x, w, lower, upper, scale, v, m, madam, step, beta, lr,
                lam_round, act_quant=True, use_pallas=True):
    """FAAR Stage 1 (paper eq. 5) on a single [K, N] linear.

    x      [R, K]  fp input activations captured from the frozen model
    w      [K, N]  fp weights (only sign(w) enters the quantized branch)
    lower/upper/scale/v [K, N]
    m, madam       Adam first/second moments for v
    Returns (v', m', madam', loss).
    """
    w_sign = jnp.sign(w)
    y_fp = x @ w
    xq = model.act_fake_quant(x) if act_quant else x

    def loss_fn(vv):
        wq = nvfp4.softquant(w_sign, lower, upper, scale, vv, beta,
                             use_pallas=use_pallas)
        mse = jnp.mean(jnp.square(y_fp - xq @ wq))
        return mse + lam_round * ref.round_loss(vv)

    loss, g = jax.value_and_grad(loss_fn)(v)
    v2, m2, a2 = adam_update(v, g, m, madam, step, lr)
    v2 = jnp.clip(v2, 0.0, 1.0)  # paper §3.5: clip after every update
    return v2, m2, a2, loss


# ---------------------------------------------------------------------------

def stage2_step(cfg: ModelConfig, weights, qstate, tokens, step, beta, lr,
                lam_kl, lam_round, tau, act_quant=True):
    """2FA Stage 2 (paper eq. 6): global alignment of the assembled NVFP4
    model against the frozen fp model.

    qstate: dict qname -> (lower, upper, scale, v, m, madam), all stacked
    [L, K, N]. Returns flat (v' x7, m' x7, madam' x7, loss, kl, mse).
    """
    names = [s[0] for s in weight_specs(cfg)]
    params = dict(zip(names, weights))

    logits_fp, h_fp, _ = model.fwd(cfg, params, tokens)
    p_fp = jax.nn.softmax(logits_fp / tau, axis=-1)
    logp_fp = jax.nn.log_softmax(logits_fp / tau, axis=-1)

    qnames = model.QNAMES

    def loss_fn(vlist):
        qtensors = {}
        rl = 0.0
        for name, vv in zip(qnames, vlist):
            lo, up, sc, _, _, _ = qstate[name]
            qtensors[name] = (lo, up, sc, vv)
            rl = rl + ref.round_loss(vv)
        qparams = model.soft_quant_params(params, qtensors, beta,
                                          use_pallas=False)
        logits_q, h_q, _ = model.fwd(cfg, qparams, tokens, act_quant=act_quant)
        logp_q = jax.nn.log_softmax(logits_q / tau, axis=-1)
        kl = jnp.mean(jnp.sum(p_fp * (logp_fp - logp_q), axis=-1))
        mse = jnp.mean(jnp.square(h_fp - h_q))
        loss = lam_kl * kl + mse + lam_round * rl
        return loss, (kl, mse)

    vlist = [qstate[n][3] for n in qnames]
    (loss, (kl, mse)), grads = jax.value_and_grad(loss_fn, has_aux=True)(vlist)

    new_v, new_m, new_a = [], [], []
    for name, vv, g in zip(qnames, vlist, grads):
        _, _, _, _, m, a = qstate[name]
        v2, m2, a2 = adam_update(vv, g, m, a, step, lr)
        new_v.append(jnp.clip(v2, 0.0, 1.0))
        new_m.append(m2)
        new_a.append(a2)
    return (*new_v, *new_m, *new_a, loss, kl, mse)
