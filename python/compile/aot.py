"""AOT export: lower every L2 graph ONCE to HLO *text* + manifest.json.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --config tiny --out ../artifacts

The manifest records, for every artifact, the exact positional input order
and output order (name/shape/dtype) so the rust runtime can marshal
literals without guessing. It also records the weight layout + init spec
so rust can initialize the model deterministically.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, steps
from .configs import CONFIGS, ModelConfig, weight_specs, QLINEARS, CAPTURE_NAMES, qlinear_shapes
from .kernels import ref, nvfp4

F32, I32 = "f32", "i32"

# micro-batch sizes lowered for the serving scheduler (rust falls back to
# per-request execution for sizes without a lowered artifact)
SERVE_BATCH_SIZES = (4, 16)


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32 if dtype == F32 else jnp.int32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Exporter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out = os.path.join(out_dir, cfg.name)
        os.makedirs(self.out, exist_ok=True)
        self.manifest = {
            "config": cfg.to_dict(),
            "weights": [
                {"name": n, "shape": list(s), "init": init, "quantized": q, "wd": wd}
                for n, s, init, q, wd in weight_specs(cfg)
            ],
            "qlinears": [
                {"name": n, "capture": c,
                 "k": getattr(cfg, ka), "n": getattr(cfg, na)}
                for n, c, ka, na in QLINEARS
            ],
            "captures": CAPTURE_NAMES,
            "artifacts": {},
        }

    def emit(self, name, fn, inputs, output_names):
        """Lower fn(*inputs) and record the artifact. inputs is a list of
        (name, shape, dtype)."""
        in_specs = [spec(s, d) for _, s, d in inputs]
        # keep_unused: the rust runtime always passes every manifest input;
        # without it jax prunes unused params (e.g. lm_head in lm_capture)
        # and PJRT rejects the arg count.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        assert len(output_names) == len(out_avals), \
            f"{name}: {len(output_names)} names vs {len(out_avals)} outputs"
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
            "outputs": [
                {"name": n, "shape": list(a.shape),
                 "dtype": I32 if jnp.issubdtype(a.dtype, jnp.integer) else F32}
                for n, a in zip(output_names, out_avals)
            ],
        }
        print(f"  [{self.cfg.name}] {name}: {len(text)//1024} KiB, "
              f"{len(inputs)} in / {len(out_avals)} out")

    def finish(self):
        path = os.path.join(self.out, "manifest.json")
        blob = json.dumps(self.manifest, indent=1)
        self.manifest["sha256"] = hashlib.sha256(blob.encode()).hexdigest()
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  [{self.cfg.name}] manifest.json written")


def weight_inputs(cfg, prefix=""):
    return [(f"{prefix}{n}", list(s), F32) for n, s, *_ in weight_specs(cfg)]


def export_config(cfg: ModelConfig, out_dir: str):
    ex = Exporter(cfg, out_dir)
    nW = len(weight_specs(cfg))
    names = [s[0] for s in weight_specs(cfg)]
    B, T = cfg.train_batch, cfg.seq_len
    BE, B2 = cfg.eval_batch, cfg.stage2_batch
    d = cfg.d_model

    # ---- pretraining step -------------------------------------------------
    def pretrain_fn(*flat):
        w, m, v = flat[:nW], flat[nW:2 * nW], flat[2 * nW:3 * nW]
        tokens, step, lr = flat[3 * nW], flat[3 * nW + 1], flat[3 * nW + 2]
        return steps.pretrain_step(cfg, w, m, v, tokens, step, lr)

    ex.emit(
        "pretrain_step", pretrain_fn,
        weight_inputs(cfg)
        + [(f"m.{n}", list(s), F32) for n, s, *_ in weight_specs(cfg)]
        + [(f"v.{n}", list(s), F32) for n, s, *_ in weight_specs(cfg)]
        + [("tokens", [B, T + 1], I32), ("step", [], F32), ("lr", [], F32)],
        [f"w.{n}" for n in names] + [f"m.{n}" for n in names]
        + [f"v.{n}" for n in names] + ["loss"],
    )

    # ---- eval forwards ----------------------------------------------------
    def fwd_fn(act_quant):
        def f(*flat):
            params = dict(zip(names, flat[:nW]))
            tokens = flat[nW]
            logits, hid, _ = model.fwd(cfg, params, tokens[:, :-1],
                                       act_quant=act_quant)
            nll = model.nll_from_logits(logits, tokens[:, 1:])
            return nll, hid
        return f

    eval_inputs = weight_inputs(cfg) + [("tokens", [BE, T + 1], I32)]
    ex.emit("lm_fwd", fwd_fn(False), eval_inputs, ["nll", "last_hidden"])
    ex.emit("lm_fwd_aq", fwd_fn(True), eval_inputs, ["nll", "last_hidden"])

    # ---- serve: last-position logits (W4A4 path) --------------------------
    def logits_pos_fn(*flat):
        params = dict(zip(names, flat[:nW]))
        tokens, pos = flat[nW], flat[nW + 1]
        logits, _, _ = model.fwd(cfg, params, tokens, act_quant=True)
        return (jnp.take(logits[0], pos, axis=0),)

    ex.emit("lm_logits_pos_aq", logits_pos_fn,
            weight_inputs(cfg) + [("tokens", [1, T], I32), ("pos", [], I32)],
            ["logits"])

    # batched serve variants: the scheduler's micro-batch sizes. Each row
    # decodes independently (per-request position), so batched output is
    # bit-identical to B single-request calls — the invariant the serving
    # engine's continuous batching relies on.
    for b in SERVE_BATCH_SIZES:
        def logits_pos_batch_fn(*flat):
            params = dict(zip(names, flat[:nW]))
            tokens, pos = flat[nW], flat[nW + 1]
            logits, _, _ = model.fwd(cfg, params, tokens, act_quant=True)
            rows = jnp.take_along_axis(logits, pos[:, None, None], axis=1)
            return (rows[:, 0, :],)

        ex.emit(f"lm_logits_pos_aq_b{b}", logits_pos_batch_fn,
                weight_inputs(cfg) + [("tokens", [b, T], I32), ("pos", [b], I32)],
                ["logits"])

    # ---- calibration capture ----------------------------------------------
    def capture_fn(*flat):
        params = dict(zip(names, flat[:nW]))
        tokens = flat[nW]
        _, _, caps = model.fwd(cfg, params, tokens, capture=True)
        return tuple(caps[c] for c in CAPTURE_NAMES)

    ex.emit("lm_capture", capture_fn,
            weight_inputs(cfg) + [("tokens", [BE, T], I32)],
            list(CAPTURE_NAMES))

    # ---- quant prepare + stage-1, one per distinct linear shape -----------
    L = cfg.n_layers
    R = cfg.stage1_rows
    for (k, n) in qlinear_shapes(cfg):
        ex.emit(f"prepare_{k}x{n}",
                lambda w: ref.quant_prepare(w),
                [("w", [L, k, n], F32)],
                ["lower", "upper", "scale", "v_init"])

        def s1_fn(x, w, lo, up, sc, v, m, a, step, beta, lr, lam):
            return steps.stage1_step(x, w, lo, up, sc, v, m, a, step, beta,
                                     lr, lam, act_quant=True, use_pallas=True)

        ex.emit(f"stage1_step_{k}x{n}", s1_fn,
                [("x", [R, k], F32), ("w", [k, n], F32),
                 ("lower", [k, n], F32), ("upper", [k, n], F32),
                 ("scale", [k, n], F32), ("v", [k, n], F32),
                 ("m", [k, n], F32), ("a", [k, n], F32),
                 ("step", [], F32), ("beta", [], F32),
                 ("lr", [], F32), ("lam_round", [], F32)],
                ["v", "m", "a", "loss"])

    # ---- stage-2 global alignment ------------------------------------------
    qnames = model.QNAMES
    qshapes = {q["name"]: (q["k"], q["n"]) for q in ex.manifest["qlinears"]}

    def stage2_fn(*flat):
        w = flat[:nW]
        i = nW
        qstate = {}
        for qn in qnames:
            k, n = qshapes[qn]
            qstate[qn] = tuple(flat[i:i + 6])
            i += 6
        tokens, step, beta, lr, lam_kl, lam_round, tau = flat[i:i + 7]
        return steps.stage2_step(cfg, w, qstate, tokens, step, beta, lr,
                                 lam_kl, lam_round, tau, act_quant=True)

    s2_inputs = weight_inputs(cfg)
    for qn in qnames:
        k, n = qshapes[qn]
        for part in ["lower", "upper", "scale", "v", "m", "a"]:
            s2_inputs.append((f"{part}.{qn}", [L, k, n], F32))
    s2_inputs += [("tokens", [B2, T], I32), ("step", [], F32),
                  ("beta", [], F32), ("lr", [], F32), ("lam_kl", [], F32),
                  ("lam_round", [], F32), ("tau", [], F32)]
    ex.emit("stage2_step", stage2_fn, s2_inputs,
            [f"v.{qn}" for qn in qnames] + [f"m.{qn}" for qn in qnames]
            + [f"a.{qn}" for qn in qnames] + ["loss", "kl", "mse"])

    # ---- kernel parity/bench artifacts (pallas vs jnp, same math) ---------
    def kernel_sq(pallas):
        def f(w, lo, up, sc, v, beta):
            return (nvfp4.softquant(jnp.sign(w), lo, up, sc, v, beta,
                                    use_pallas=pallas),)
        return f

    kin = [("w", [d, d], F32), ("lower", [d, d], F32), ("upper", [d, d], F32),
           ("scale", [d, d], F32), ("v", [d, d], F32), ("beta", [], F32)]
    ex.emit("kernel_softquant", kernel_sq(True), kin, ["wq"])
    ex.emit("kernel_softquant_jnp", kernel_sq(False), kin, ["wq"])

    def kernel_rtn(pallas):
        def f(w):
            sc, _ = ref.nvfp4_weight_scales(w)
            return (nvfp4.rtn(w, sc, use_pallas=pallas),)
        return f

    ex.emit("kernel_rtn", kernel_rtn(True), [("w", [d, d], F32)], ["wq"])
    ex.emit("kernel_rtn_jnp", kernel_rtn(False), [("w", [d, d], F32)], ["wq"])

    ex.finish()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="all",
                    help="preset name or 'all' (nano,tiny,small)")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    cfgs = ["nano", "tiny", "small"] if args.config == "all" else args.config.split(",")
    for c in cfgs:
        print(f"exporting config '{c}' -> {args.out}/{c}/")
        export_config(CONFIGS[c], args.out)


if __name__ == "__main__":
    main()
