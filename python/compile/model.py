"""L2 — the JAX compute graphs (Llama-style decoder) that get AOT-lowered.

All per-layer weights are stacked on a leading L axis and the decoder body
is a single ``lax.scan``, so every artifact has a short, fixed parameter
list (12 weight tensors — see configs.weight_specs) regardless of depth.

Three forward variants share one implementation:
  * plain           — BF16-stand-in (f32) reference model
  * act_quant=True  — W4A4: every quantized linear's input is dynamically
                      RTN-fake-quantized (STE backward)
  * qweights given  — quantized model: the 7 linear weight stacks are
                      replaced by FAAR soft-quant (or hard/dequantized
                      weights fed directly by rust)

Python never runs at inference time: rust feeds weights (original or
dequantized-hard) into these graphs through PJRT.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, weight_specs, QLINEARS, CAPTURE_NAMES
from .kernels import ref


# ---------------------------------------------------------------------------
# Activation fake-quant with straight-through estimator. Stage-2 gradients
# must flow *through* later layers' activation quantizers to reach earlier
# layers' rounding variables.

@jax.custom_vjp
def act_fake_quant(x):
    return ref.rtn_fake_quant_act(x)


def _afq_fwd(x):
    return ref.rtn_fake_quant_act(x), None


def _afq_bwd(_, g):
    return (g,)


act_fake_quant.defvjp(_afq_fwd, _afq_bwd)


# ---------------------------------------------------------------------------
# Parameter plumbing

def params_to_dict(cfg: ModelConfig, flat):
    specs = weight_specs(cfg)
    assert len(flat) == len(specs), f"{len(flat)} != {len(specs)}"
    return {name: t for (name, *_), t in zip(specs, flat)}


def param_shapes(cfg: ModelConfig):
    return [(name, shape) for name, shape, *_ in weight_specs(cfg)]


# ---------------------------------------------------------------------------
# Building blocks

def rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(cfg: ModelConfig, seq_len: int):
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)                      # [T, hd/2]
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin):
    # x: [B, T, H, hd]
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _linear(x, w, act_quant):
    if act_quant:
        x = act_fake_quant(x)
    return x @ w


def _layer(cfg: ModelConfig, carry, lw, cos, sin, act_quant):
    """One decoder block. lw = dict of this layer's (un-stacked) weights.
    Returns (new_hidden, captures) where captures are the 4 linear-input
    tensors (pre-act-quant, i.e. what calibration sees)."""
    x = carry
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    attn_in = rmsnorm(x, lw["attn_norm"])
    q = _linear(attn_in, lw["wq"], act_quant).reshape(b, t, h, hd)
    k = _linear(attn_in, lw["wk"], act_quant).reshape(b, t, h, hd)
    v = _linear(attn_in, lw["wv"], act_quant).reshape(b, t, h, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
    attn_o_in = attn
    x = x + _linear(attn_o_in, lw["wo"], act_quant)

    mlp_in = rmsnorm(x, lw["mlp_norm"])
    g = _linear(mlp_in, lw["w_gate"], act_quant)
    u = _linear(mlp_in, lw["w_up"], act_quant)
    mlp_down_in = jax.nn.silu(g) * u
    x = x + _linear(mlp_down_in, lw["w_down"], act_quant)

    captures = {
        "attn_in": attn_in,
        "attn_o_in": attn_o_in,
        "mlp_in": mlp_in,
        "mlp_down_in": mlp_down_in,
    }
    return x, captures


_LAYER_KEYS = ["attn_norm", "wq", "wk", "wv", "wo",
               "mlp_norm", "w_gate", "w_up", "w_down"]


def fwd(cfg: ModelConfig, params, tokens, act_quant=False, capture=False):
    """Decoder forward.

    tokens: [B, T] int32. Returns (logits [B,T,V], last_hidden [B,T,d],
    captures dict of [L,B,T,*] or None).
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens]
    cos, sin = rope_tables(cfg, t)

    stacked = {k: params[f"layers.{k}"] for k in _LAYER_KEYS}

    def body(carry, lw):
        y, caps = _layer(cfg, carry, lw, cos, sin, act_quant)
        return y, (caps if capture else 0)

    x, caps = jax.lax.scan(body, x, stacked)
    x = rmsnorm(x, params["out_norm"])
    logits = x @ params["lm_head"]
    return logits, x, (caps if capture else None)


def nll_from_logits(logits, targets):
    """Per-position negative log-likelihood. logits [B,T,V], targets [B,T]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Quantized-parameter assembly (used by stage-2 and by kernels parity)

QNAMES = sorted({q[0] for q in QLINEARS}, key=[q[0] for q in QLINEARS].index)


def soft_quant_params(params, qtensors, beta, use_pallas=False):
    """Replace each quantized weight stack with its FAAR soft-quant.

    qtensors: dict name -> (lower, upper, scale, v); sign comes from the
    original weights (paper: quantize magnitude, preserve sign).
    """
    from .kernels import nvfp4
    out = dict(params)
    for name in QNAMES:
        lo, up, sc, v = qtensors[name]
        w_sign = jnp.sign(params[name])
        out[name] = nvfp4.softquant(w_sign, lo, up, sc, v, beta, use_pallas=use_pallas)
    return out


__all__ = [
    "fwd", "nll_from_logits", "params_to_dict", "param_shapes", "rmsnorm",
    "soft_quant_params", "act_fake_quant", "QNAMES", "CAPTURE_NAMES",
]
