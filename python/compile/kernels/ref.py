"""Pure-jnp reference ("oracle") for all NVFP4 / FAAR numerics.

Every Pallas kernel in this package is checked against these functions by
pytest at build time, and the rust codec (rust/src/formats/) is checked
against the AOT-exported `quant_prepare` / `kernel_rtn` artifacts, which
are lowered from these exact functions. This file therefore pins the
bit-level semantics of the whole system:

  * NVFP4 node set N = {0, ±0.5, ±1, ±1.5, ±2, ±3, ±4, ±6} (FP4 E2M1)
  * block-16 scales along the contraction axis, stored as FP8 E4M3
    relative to a per-tensor FP32 global scale (scale-of-scales)
  * RTN tie-break: exact midpoints round DOWN (toward the lower node).
    This is deliberately simpler than E2M1 round-half-even and is applied
    identically in python and rust (DESIGN.md §7).
  * FindInterval on the normalized magnitude w̃ = |w| / s, clamped to
    [0, 6]:  lower = max{n ∈ N : n ≤ w̃},  upper = min{n ∈ N : n ≥ w̃}.
"""

import jax
import jax.numpy as jnp

# Positive NVFP4 (E2M1) nodes.
NODES = (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0)
FP4_MAX = 6.0
E4M3_MAX = 448.0
BLOCK = 16


def lower_node(wt):
    """Largest NVFP4 node <= wt (wt >= 0)."""
    return jnp.where(wt >= 6.0, 6.0,
           jnp.where(wt >= 4.0, 4.0,
           jnp.where(wt >= 3.0, 3.0,
           jnp.where(wt >= 2.0, 2.0,
           jnp.where(wt >= 1.5, 1.5,
           jnp.where(wt >= 1.0, 1.0,
           jnp.where(wt >= 0.5, 0.5, 0.0)))))))


def upper_node(wt):
    """Smallest NVFP4 node >= wt (wt in [0, 6])."""
    return jnp.where(wt <= 0.0, 0.0,
           jnp.where(wt <= 0.5, 0.5,
           jnp.where(wt <= 1.0, 1.0,
           jnp.where(wt <= 1.5, 1.5,
           jnp.where(wt <= 2.0, 2.0,
           jnp.where(wt <= 3.0, 3.0,
           jnp.where(wt <= 4.0, 4.0, 6.0)))))))


def e4m3_roundtrip(x):
    """f32 -> FP8 E4M3 -> f32 (round-to-nearest-even; inputs are
    guaranteed <= 448 by construction of the global scale)."""
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def nvfp4_weight_scales(w):
    """Two-level NVFP4 scales for a weight tensor w[..., K, N].

    Blocks of 16 run along K (the contraction axis), one scale per
    (block, output-column) pair — matching NVFP4 GEMM layout. The global
    scale is per *tensor*; for stacked [L, K, N] weights each layer slice
    is its own tensor (amax over the trailing two axes).

    Returns the elementwise effective scale (E4M3 quantization error
    included — this is what deployable NVFP4 hardware sees) broadcast to
    w's shape, and the global scale (shape [..., 1, 1]).
    """
    *lead, k, n = w.shape
    assert k % BLOCK == 0, f"K={k} not a multiple of {BLOCK}"
    wb = jnp.abs(w).reshape(*lead, k // BLOCK, BLOCK, n)
    amax_blk = jnp.max(wb, axis=-2, keepdims=True)                # [..., K/B, 1, N]
    amax_tot = jnp.max(jnp.abs(w), axis=(-1, -2), keepdims=True)  # [..., 1, 1]
    s_global = jnp.maximum(amax_tot / (FP4_MAX * E4M3_MAX), 1e-30)
    s_g = s_global.reshape(*lead, 1, 1, 1)
    s_eff = e4m3_roundtrip(amax_blk / FP4_MAX / s_g) * s_g        # [..., K/B, 1, N]
    s_eff = jnp.broadcast_to(s_eff, wb.shape).reshape(w.shape)
    return s_eff, s_global


def act_scales(x):
    """Dynamic activation scales: blocks of 16 along the LAST axis
    (feature dim), per-tensor global scale — same two-level scheme."""
    *lead, f = x.shape
    assert f % BLOCK == 0, f"F={f} not a multiple of {BLOCK}"
    xb = jnp.abs(x).reshape(*lead, f // BLOCK, BLOCK)
    amax_blk = jnp.max(xb, axis=-1, keepdims=True)
    amax_tot = jnp.max(jnp.abs(x))
    s_global = jnp.maximum(amax_tot / (FP4_MAX * E4M3_MAX), 1e-30)
    s_eff = e4m3_roundtrip(amax_blk / FP4_MAX / s_global) * s_global
    s_eff = jnp.broadcast_to(s_eff, xb.shape).reshape(x.shape)
    return s_eff


def find_interval(w, scale):
    """Normalized magnitude + enclosing NVFP4 nodes.

    Returns (lower, upper, wt) with wt = clip(|w|/scale, 0, 6);
    zero-scale (all-zero block) elements get wt = 0.
    """
    wt = jnp.where(scale > 0, jnp.abs(w) / jnp.maximum(scale, 1e-30), 0.0)
    wt = jnp.clip(wt, 0.0, FP4_MAX)
    return lower_node(wt), upper_node(wt), wt


def v_init(wt, lower, upper):
    """Relative position of wt inside its interval (paper eq. 4);
    degenerate (zero-width) intervals get 0.5."""
    width = upper - lower
    return jnp.where(width > 0, (wt - lower) / jnp.maximum(width, 1e-30), 0.5)


def rtn_round(wt, lower, upper):
    """Round-to-nearest on the non-uniform grid; ties -> lower."""
    return jnp.where(wt - lower > upper - wt, upper, lower)


def rtn_quant(w, scale):
    """RTN fake-quant given precomputed elementwise scales."""
    lo, up, wt = find_interval(w, scale)
    return jnp.sign(w) * rtn_round(wt, lo, up) * scale


def rtn_fake_quant_weights(w):
    """Full RTN weight fake-quant (scales computed internally)."""
    s, _ = nvfp4_weight_scales(w)
    return rtn_quant(w, s)


def rtn_fake_quant_act(x):
    """Full RTN activation fake-quant (dynamic per-token-block scales)."""
    return rtn_quant(x, act_scales(x))


def soft_round(v, beta):
    """Temperature-scaled sigmoid h_beta(v) (paper eq. 3)."""
    return jax.nn.sigmoid(beta * (v - 0.5))


def soft_quant(w_sign, lower, upper, scale, v, beta):
    """FAAR continuous relaxation (paper eq. 2):
    w_q = sign(w) * [lower + h_beta(v) * (upper - lower)] * scale.
    The local interval width (upper - lower) scales each v's gradient —
    the format-aware part."""
    h = soft_round(v, beta)
    return w_sign * (lower + h * (upper - lower)) * scale


def soft_quant_grad_v(w_sign, lower, upper, scale, v, beta, g):
    """Analytic d(loss)/dv given upstream gradient g on w_q — used as the
    custom VJP of the Pallas forward kernel."""
    h = soft_round(v, beta)
    return g * w_sign * scale * (upper - lower) * beta * h * (1.0 - h)


def harden(v):
    """Deterministic hardening (paper eq. 7): v >= 0.5 -> upper."""
    return (v >= 0.5).astype(jnp.float32)


def hard_quant(w_sign, lower, upper, scale, v):
    """Final NVFP4 weights after hardening (paper step 26)."""
    return w_sign * (lower + harden(v) * (upper - lower)) * scale


def round_loss(v):
    """Rounding regularizer (paper eq. 5, second term):
    mean_i (1 - (2 v_i - 1)^2) — pushes v toward {0, 1}."""
    return jnp.mean(1.0 - jnp.square(2.0 * v - 1.0))


def quant_prepare(w):
    """Everything rust's stage-1 driver needs, from the raw weights:
    (lower, upper, scale, v_init), all elementwise with w's shape."""
    scale, _ = nvfp4_weight_scales(w)
    lo, up, wt = find_interval(w, scale)
    return lo, up, scale, v_init(wt, lo, up)
