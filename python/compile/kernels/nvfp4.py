"""L1 Pallas kernels — the paper's compute hot-spot.

Two kernels, both elementwise over weight tiles:

  * ``softquant_pallas``  — FAAR continuous relaxation (paper eq. 2/3):
      FindInterval is precomputed (lower/upper/scale tensors); the kernel
      evaluates the temperature sigmoid and the format-aware interpolation.
      Wrapped in ``jax.custom_vjp`` with an analytic backward kernel so the
      stage-1/stage-2 graphs can differentiate through it.
  * ``rtn_pallas``        — RTN fake-quant on the NVFP4 grid, including
      the FindInterval where-chain (used by the baseline path and as the
      rust-codec parity artifact).

Hardware adaptation (DESIGN.md §3): the paper targets NVFP4 tensor cores
on Blackwell. On a TPU-shaped target this work is VPU-elementwise ahead of
an MXU matmul; we express the HBM↔VMEM schedule with a BlockSpec grid of
(row_tile × lane_tile) blocks. ``interpret=True`` everywhere — real-TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute
(see /opt/xla-example/README.md); the TPU cost model is estimated
analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.

# Default VMEM tile. 128 lanes matches the TPU lane width; 128 rows keeps
# the block-16 scale groups (along K = rows) aligned within a tile.
TILE = 128


def _pick_tile(n: int, target: int = TILE) -> int:
    """Largest divisor of n that is <= target (shapes here are multiples
    of 16, so this is always >= 16 for our configs)."""
    if n <= target:
        return n
    for t in range(target, 0, -1):
        if n % t == 0:
            return t
    return n


def _as2d(x):
    """Elementwise kernels: collapse leading axes onto rows."""
    return x.reshape(-1, x.shape[-1])


def _tiled_specs(shape, n_tensors):
    m, n = shape
    bm, bn = _pick_tile(m), _pick_tile(n)
    grid = (m // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    # beta rides along as a (1,1) block mapped to the origin for every tile.
    beta_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return grid, [spec] * n_tensors, beta_spec, spec


# ---------------------------------------------------------------------------
# FAAR soft-quant forward + backward kernels


def _softquant_kernel(w_sign_ref, lo_ref, up_ref, scale_ref, v_ref, beta_ref, o_ref):
    beta = beta_ref[0, 0]
    h = jax.nn.sigmoid(beta * (v_ref[...] - 0.5))
    o_ref[...] = w_sign_ref[...] * (lo_ref[...] + h * (up_ref[...] - lo_ref[...])) * scale_ref[...]


def _softquant_bwd_kernel(w_sign_ref, lo_ref, up_ref, scale_ref, v_ref, beta_ref, g_ref, o_ref):
    beta = beta_ref[0, 0]
    h = jax.nn.sigmoid(beta * (v_ref[...] - 0.5))
    width = up_ref[...] - lo_ref[...]
    o_ref[...] = g_ref[...] * w_sign_ref[...] * scale_ref[...] * width * beta * h * (1.0 - h)


def _softquant_fwd_call(w_sign, lo, up, scale, v, beta):
    shape2d = _as2d(w_sign).shape
    grid, specs, beta_spec, out_spec = _tiled_specs(shape2d, 5)
    out = pl.pallas_call(
        _softquant_kernel,
        grid=grid,
        in_specs=specs + [beta_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape2d, jnp.float32),
        interpret=INTERPRET,
    )(_as2d(w_sign), _as2d(lo), _as2d(up), _as2d(scale), _as2d(v),
      jnp.reshape(beta, (1, 1)).astype(jnp.float32))
    return out.reshape(w_sign.shape)


def _softquant_bwd_call(w_sign, lo, up, scale, v, beta, g):
    shape2d = _as2d(w_sign).shape
    grid, specs, beta_spec, out_spec = _tiled_specs(shape2d, 5)
    dv = pl.pallas_call(
        _softquant_bwd_kernel,
        grid=grid,
        in_specs=specs + [beta_spec, out_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape2d, jnp.float32),
        interpret=INTERPRET,
    )(_as2d(w_sign), _as2d(lo), _as2d(up), _as2d(scale), _as2d(v),
      jnp.reshape(beta, (1, 1)).astype(jnp.float32), _as2d(g))
    return dv.reshape(v.shape)


@jax.custom_vjp
def softquant_pallas(w_sign, lower, upper, scale, v, beta):
    """FAAR soft-quant (Pallas forward). Differentiable w.r.t. v only —
    exactly what the 2FA optimization needs (V is the only trainable)."""
    return _softquant_fwd_call(w_sign, lower, upper, scale, v, beta)


def _sq_fwd(w_sign, lower, upper, scale, v, beta):
    out = _softquant_fwd_call(w_sign, lower, upper, scale, v, beta)
    return out, (w_sign, lower, upper, scale, v, beta)


def _sq_bwd(res, g):
    w_sign, lower, upper, scale, v, beta = res
    dv = _softquant_bwd_call(w_sign, lower, upper, scale, v, beta, g)
    zeros = (jnp.zeros_like(w_sign), jnp.zeros_like(lower),
             jnp.zeros_like(upper), jnp.zeros_like(scale))
    return (*zeros, dv, jnp.zeros_like(jnp.asarray(beta, jnp.float32)))


softquant_pallas.defvjp(_sq_fwd, _sq_bwd)


# ---------------------------------------------------------------------------
# RTN fake-quant kernel (FindInterval where-chain inside the kernel)


def _rtn_kernel(w_ref, scale_ref, o_ref):
    w = w_ref[...]
    s = scale_ref[...]
    wt = jnp.where(s > 0, jnp.abs(w) / jnp.maximum(s, 1e-30), 0.0)
    wt = jnp.clip(wt, 0.0, 6.0)
    lo = jnp.where(wt >= 6.0, 6.0,
         jnp.where(wt >= 4.0, 4.0,
         jnp.where(wt >= 3.0, 3.0,
         jnp.where(wt >= 2.0, 2.0,
         jnp.where(wt >= 1.5, 1.5,
         jnp.where(wt >= 1.0, 1.0,
         jnp.where(wt >= 0.5, 0.5, 0.0)))))))
    up = jnp.where(wt <= 0.0, 0.0,
         jnp.where(wt <= 0.5, 0.5,
         jnp.where(wt <= 1.0, 1.0,
         jnp.where(wt <= 1.5, 1.5,
         jnp.where(wt <= 2.0, 2.0,
         jnp.where(wt <= 3.0, 3.0,
         jnp.where(wt <= 4.0, 4.0, 6.0)))))))
    q = jnp.where(wt - lo > up - wt, up, lo)
    o_ref[...] = jnp.sign(w) * q * s


def rtn_pallas(w, scale):
    """RTN fake-quant on the NVFP4 grid (Pallas), given elementwise scales."""
    shape2d = _as2d(w).shape
    m, n = shape2d
    bm, bn = _pick_tile(m), _pick_tile(n)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out = pl.pallas_call(
        _rtn_kernel,
        grid=(m // bm, n // bn),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape2d, jnp.float32),
        interpret=INTERPRET,
    )(_as2d(w), _as2d(scale))
    return out.reshape(w.shape)


# ---------------------------------------------------------------------------
# Dispatch used by the L2 graphs. Stage-1 (the hot path the paper profiles)
# uses the Pallas kernels; the full-model stage-2 graph uses the jnp path
# (identical math, pytest-enforced) to keep the 7-way stacked lowering lean.

from . import ref  # noqa: E402


def softquant(w_sign, lower, upper, scale, v, beta, use_pallas=False):
    if use_pallas:
        return softquant_pallas(w_sign, lower, upper, scale, v, beta)
    return ref.soft_quant(w_sign, lower, upper, scale, v, beta)


def rtn(w, scale, use_pallas=False):
    if use_pallas:
        return rtn_pallas(w, scale)
    return ref.rtn_quant(w, scale)
