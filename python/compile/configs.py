"""Model / pipeline configuration presets.

Single source of truth for shapes shared between the build-time python
layer (L1 kernels + L2 graphs) and the runtime rust layer (L3). The rust
side never imports this module — everything it needs is serialized into
``artifacts/<cfg>/manifest.json`` by ``aot.py``.

Presets (see DESIGN.md §4):
  nano  — unit/integration tests
  tiny  — "Llama3-1B" stand-in for the paper's main tables
  small — "Qwen3-1.7B" stand-in
  med   — optional scale check
"""

from dataclasses import dataclass, field, asdict


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    # NVFP4 block size along the contraction axis (the format fixes 16).
    block: int = 16
    # pipeline shapes (all graphs are shape-specialized at AOT time)
    train_batch: int = 8
    eval_batch: int = 8
    stage1_rows: int = 512
    stage2_batch: int = 8
    # mlp hidden (SwiGLU): ~8/3 * d rounded up to a multiple of 32 so that
    # NVFP4 16-element blocks tile it exactly.
    mlp_hidden: int = 0

    def __post_init__(self):
        if self.mlp_hidden == 0:
            object.__setattr__(self, "mlp_hidden", _round_up(self.d_model * 8 // 3, 32))
        assert self.d_model % self.n_heads == 0
        assert (self.d_model // self.n_heads) % 2 == 0, "rope needs even head_dim"
        assert self.d_model % self.block == 0
        assert self.mlp_hidden % self.block == 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


CONFIGS = {
    "nano": ModelConfig(
        name="nano", vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=64,
        train_batch=4, eval_batch=4, stage1_rows=128, stage2_batch=4,
    ),
    "tiny": ModelConfig(
        name="tiny", vocab=512, d_model=128, n_layers=4, n_heads=4, seq_len=128,
    ),
    "small": ModelConfig(
        name="small", vocab=1024, d_model=192, n_layers=6, n_heads=6, seq_len=128,
    ),
    "med": ModelConfig(
        name="med", vocab=4096, d_model=384, n_layers=8, n_heads=8, seq_len=256,
    ),
}


# Canonical weight layout. Per-layer tensors are stacked on a leading L
# axis so the whole forward is a single lax.scan and the artifact parameter
# list stays short. `quantized` tensors are the NVFP4 targets; everything
# else stays high-precision (standard PTQ practice, see DESIGN.md §4).
#
# init kinds: "normal:<std>", "normal_scaled:<std>" (std / sqrt(2 L)), "ones".
def weight_specs(cfg: ModelConfig):
    L, d, h, v = cfg.n_layers, cfg.d_model, cfg.mlp_hidden, cfg.vocab
    return [
        # (name, shape, init, quantized, weight_decay)
        ("tok_emb",          (v, d),    "normal:0.02",        False, True),
        ("layers.attn_norm", (L, d),    "ones",               False, False),
        ("layers.wq",        (L, d, d), "normal:0.02",        True,  True),
        ("layers.wk",        (L, d, d), "normal:0.02",        True,  True),
        ("layers.wv",        (L, d, d), "normal:0.02",        True,  True),
        ("layers.wo",        (L, d, d), "normal_scaled:0.02", True,  True),
        ("layers.mlp_norm",  (L, d),    "ones",               False, False),
        ("layers.w_gate",    (L, d, h), "normal:0.02",        True,  True),
        ("layers.w_up",      (L, d, h), "normal:0.02",        True,  True),
        ("layers.w_down",    (L, h, d), "normal_scaled:0.02", True,  True),
        ("out_norm",         (d,),      "ones",               False, False),
        ("lm_head",          (d, v),    "normal:0.02",        False, True),
    ]


WEIGHT_NAMES = [s[0] for s in weight_specs(CONFIGS["nano"])]

# The 7 quantized linears, each mapped to the activation-capture tensor
# that feeds it (4 distinct capture points per layer — see model.fwd).
QLINEARS = [
    # (weight name, capture name, in-dim attr, out-dim attr)
    ("layers.wq",     "attn_in",    "d_model",    "d_model"),
    ("layers.wk",     "attn_in",    "d_model",    "d_model"),
    ("layers.wv",     "attn_in",    "d_model",    "d_model"),
    ("layers.wo",     "attn_o_in",  "d_model",    "d_model"),
    ("layers.w_gate", "mlp_in",     "d_model",    "mlp_hidden"),
    ("layers.w_up",   "mlp_in",     "d_model",    "mlp_hidden"),
    ("layers.w_down", "mlp_down_in","mlp_hidden", "d_model"),
]

CAPTURE_NAMES = ["attn_in", "attn_o_in", "mlp_in", "mlp_down_in"]


def qlinear_shapes(cfg: ModelConfig):
    """Distinct (in, out) shapes among quantized linears → one stage-1 /
    prepare artifact per shape."""
    shapes = []
    for _, _, a_in, a_out in QLINEARS:
        s = (getattr(cfg, a_in), getattr(cfg, a_out))
        if s not in shapes:
            shapes.append(s)
    return shapes
