"""Optimization-step graph tests: losses go down, invariants hold."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model, steps
from compile.configs import CONFIGS, weight_specs
from compile.kernels import ref
from tests.test_model import init_params, toks

CFG = CONFIGS["nano"]


def test_adam_update_moves_against_gradient():
    p = jnp.zeros(4)
    g = jnp.asarray([1.0, -1.0, 0.5, 0.0])
    p2, m2, v2 = steps.adam_update(p, g, jnp.zeros(4), jnp.zeros(4),
                                   step=1.0, lr=1e-2)
    p2 = np.asarray(p2)
    assert p2[0] < 0 and p2[1] > 0 and p2[2] < 0 and p2[3] == 0


def test_adam_bias_correction_first_step():
    """At step 1 with zero state the update is ~lr * sign(g)."""
    g = jnp.asarray([0.3, -0.7])
    p2, _, _ = steps.adam_update(jnp.zeros(2), g, jnp.zeros(2), jnp.zeros(2),
                                 step=1.0, lr=1e-3)
    np.testing.assert_allclose(np.abs(np.asarray(p2)), 1e-3, rtol=1e-3)


def test_global_norm_clip():
    gs = [jnp.asarray([3.0]), jnp.asarray([4.0])]
    clipped, gn = steps.global_norm_clip(gs, max_norm=1.0)
    assert float(gn) == pytest.approx(5.0)
    total = np.sqrt(sum(float(jnp.sum(g ** 2)) for g in clipped))
    assert total == pytest.approx(1.0, rel=1e-5)
    # under the cap: untouched
    same, _ = steps.global_norm_clip(gs, max_norm=100.0)
    np.testing.assert_allclose(np.asarray(same[0]), 3.0)


def test_pretrain_step_reduces_loss():
    params = init_params(CFG, seed=0)
    names = [s[0] for s in weight_specs(CFG)]
    w = [params[n] for n in names]
    m = [jnp.zeros_like(t) for t in w]
    v = [jnp.zeros_like(t) for t in w]
    tokens = toks(4, CFG.seq_len + 1, seed=2)
    losses = []
    for i in range(8):
        out = steps.pretrain_step(CFG, w, m, v, tokens,
                                  jnp.float32(i + 1), jnp.float32(3e-3))
        nW = len(w)
        w, m, v = list(out[:nW]), list(out[nW:2 * nW]), list(out[2 * nW:3 * nW])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.1, losses


def stage1_inputs(seed=0):
    rng = np.random.default_rng(seed)
    k, n, r = 64, 32, 128
    x = jnp.asarray(rng.normal(0, 1, (r, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.05, (k, n)).astype(np.float32))
    lo, up, sc, vi = ref.quant_prepare(w)
    return x, w, lo, up, sc, vi


def test_stage1_step_improves_over_vinit():
    x, w, lo, up, sc, vi = stage1_inputs()
    v = vi
    m = jnp.zeros_like(v)
    a = jnp.zeros_like(v)
    losses = []
    for i in range(30):
        v, m, a, loss = steps.stage1_step(
            x, w, lo, up, sc, v, m, a,
            jnp.float32(i + 1), jnp.float32(8.0), jnp.float32(5e-3),
            jnp.float32(0.0),  # pure MSE: must go down
            act_quant=True, use_pallas=False)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.98, (losses[0], losses[-1])


def test_stage1_v_stays_clipped():
    x, w, lo, up, sc, vi = stage1_inputs(seed=3)
    v, m, a = vi, jnp.zeros_like(vi), jnp.zeros_like(vi)
    for i in range(5):
        v, m, a, _ = steps.stage1_step(
            x, w, lo, up, sc, v, m, a,
            jnp.float32(i + 1), jnp.float32(10.0), jnp.float32(0.5),  # huge lr
            jnp.float32(0.01), act_quant=False, use_pallas=False)
    v = np.asarray(v)
    assert v.min() >= 0.0 and v.max() <= 1.0


def test_stage1_round_loss_pushes_binary():
    """With ONLY the regularizer active, v drifts toward {0,1}."""
    x, w, lo, up, sc, vi = stage1_inputs(seed=5)
    v = jnp.clip(vi, 0.05, 0.95)
    m, a = jnp.zeros_like(v), jnp.zeros_like(v)
    before = float(ref.round_loss(v))
    for i in range(20):
        v, m, a, _ = steps.stage1_step(
            0.0 * x, w, lo, up, sc, v, m, a,   # zero inputs → MSE grad = 0
            jnp.float32(i + 1), jnp.float32(8.0), jnp.float32(1e-2),
            jnp.float32(1.0), act_quant=False, use_pallas=False)
    after = float(ref.round_loss(v))
    assert after < before


def make_qstate(params):
    qstate = {}
    for name in model.QNAMES:
        lo, up, sc, vi = ref.quant_prepare(params[name])
        qstate[name] = (lo, up, sc, vi, jnp.zeros_like(vi), jnp.zeros_like(vi))
    return qstate


def test_stage2_step_outputs_and_improvement():
    params = init_params(CFG, seed=1)
    names = [s[0] for s in weight_specs(CFG)]
    w = [params[n] for n in names]
    qstate = make_qstate(params)
    tokens = toks(2, 32, seed=4)
    first_loss, last_loss = None, None
    for i in range(10):
        out = steps.stage2_step(CFG, w, qstate, tokens,
                                jnp.float32(i + 1), jnp.float32(8.0),
                                jnp.float32(3e-3), jnp.float32(1.0),
                                jnp.float32(0.0), jnp.float32(2.0))
        nq = len(model.QNAMES)
        vs, ms, as_ = out[:nq], out[nq:2 * nq], out[2 * nq:3 * nq]
        loss, kl, mse = (float(x) for x in out[3 * nq:])
        for j, name in enumerate(model.QNAMES):
            lo, up, sc, _, _, _ = qstate[name]
            qstate[name] = (lo, up, sc, vs[j], ms[j], as_[j])
        if first_loss is None:
            first_loss = loss
        last_loss = loss
        assert kl >= -1e-5 and mse >= 0
    assert last_loss < first_loss, (first_loss, last_loss)


def test_stage2_v_clipped():
    params = init_params(CFG, seed=2)
    names = [s[0] for s in weight_specs(CFG)]
    w = [params[n] for n in names]
    qstate = make_qstate(params)
    tokens = toks(2, 32, seed=6)
    out = steps.stage2_step(CFG, w, qstate, tokens,
                            jnp.float32(1.0), jnp.float32(8.0),
                            jnp.float32(0.9),  # huge lr
                            jnp.float32(1.0), jnp.float32(0.01), jnp.float32(2.0))
    for v in out[:len(model.QNAMES)]:
        v = np.asarray(v)
        assert v.min() >= 0.0 and v.max() <= 1.0
