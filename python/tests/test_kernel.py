"""Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Sweeps shapes (including non-tile-aligned row counts handled by the
divisor-based tile picker), betas, and distributions; checks forward
numerics and the custom-VJP backward against jnp autodiff.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref, nvfp4

SHAPES = [(16, 16), (64, 64), (128, 128), (352, 128), (128, 352),
          (2, 64, 64), (4, 16, 32), (48, 80)]
BETAS = [1.0, 5.0, 23.0, 100.0]


def rand(shape, seed, scale=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))


def prep(shape, seed=0):
    w = rand(shape, seed)
    lo, up, sc, vi = ref.quant_prepare(w.reshape(-1, shape[-1]) if len(shape) == 2 else w)
    lo, up, sc, vi = (t.reshape(shape) for t in (lo, up, sc, vi))
    return w, jnp.sign(w), lo, up, sc, vi


@pytest.mark.parametrize("shape", SHAPES)
def test_softquant_forward_matches_ref(shape):
    w, ws, lo, up, sc, vi = prep(shape)
    out_p = nvfp4.softquant_pallas(ws, lo, up, sc, vi, jnp.float32(12.0))
    out_r = ref.soft_quant(ws, lo, up, sc, vi, 12.0)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("beta", BETAS)
def test_softquant_beta_sweep(beta):
    w, ws, lo, up, sc, vi = prep((128, 96), seed=3)
    out_p = nvfp4.softquant_pallas(ws, lo, up, sc, vi, jnp.float32(beta))
    out_r = ref.soft_quant(ws, lo, up, sc, vi, beta)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("shape", [(64, 64), (128, 352), (32, 16)])
def test_softquant_backward_matches_autodiff(shape):
    w, ws, lo, up, sc, vi = prep(shape, seed=11)
    g = rand(shape, 13, scale=1.0)
    beta = jnp.float32(9.0)

    def f_pallas(v):
        return jnp.sum(nvfp4.softquant_pallas(ws, lo, up, sc, v, beta) * g)

    def f_ref(v):
        return jnp.sum(ref.soft_quant(ws, lo, up, sc, v, beta) * g)

    gp = jax.grad(f_pallas)(vi)
    gr = jax.grad(f_ref)(vi)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr),
                               rtol=1e-5, atol=1e-9)


def test_softquant_grad_zero_for_frozen_inputs():
    """custom_vjp must route gradient to v ONLY."""
    w, ws, lo, up, sc, vi = prep((32, 32), seed=5)

    def f(sc_):
        return jnp.sum(nvfp4.softquant_pallas(ws, lo, up, sc_, vi, jnp.float32(5.0)))

    g = jax.grad(f)(sc)
    assert float(jnp.max(jnp.abs(g))) == 0.0


@pytest.mark.parametrize("shape", SHAPES)
def test_rtn_kernel_matches_ref(shape):
    w = rand(shape, seed=21)
    flat = w.reshape(-1, shape[-1]) if len(shape) != 2 else w
    sc, _ = ref.nvfp4_weight_scales(flat)
    sc = sc.reshape(shape)
    out_p = nvfp4.rtn_pallas(w, sc)
    out_r = ref.rtn_quant(w, sc)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-6, atol=1e-8)


def test_rtn_kernel_heavy_tail():
    """Outlier-heavy distribution exercises the sparse end of the grid."""
    rng = np.random.default_rng(31)
    w = rng.standard_t(2, size=(128, 64)).astype(np.float32)
    w = jnp.asarray(w)
    sc, _ = ref.nvfp4_weight_scales(w)
    np.testing.assert_allclose(np.asarray(nvfp4.rtn_pallas(w, sc)),
                               np.asarray(ref.rtn_quant(w, sc)),
                               rtol=1e-6, atol=1e-8)


def test_tile_picker():
    assert nvfp4._pick_tile(64) == 64
    assert nvfp4._pick_tile(128) == 128
    assert nvfp4._pick_tile(256) == 128
    assert nvfp4._pick_tile(352) == 88   # largest divisor <= 128
    assert nvfp4._pick_tile(352) * (352 // nvfp4._pick_tile(352)) == 352


def test_dispatch_flags():
    w, ws, lo, up, sc, vi = prep((32, 32), seed=41)
    a = nvfp4.softquant(ws, lo, up, sc, vi, 7.0, use_pallas=True)
    b = nvfp4.softquant(ws, lo, up, sc, vi, 7.0, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-8)
