"""AOT export self-consistency: manifest ↔ configs ↔ emitted files.

Runs against the artifacts/ tree if present (`make artifacts`); the nano
config is exported into a temp dir otherwise, keeping the test hermetic
(but slower), so `pytest` is meaningful in a fresh checkout too.
"""

import json
import os

import pytest

from compile.configs import CONFIGS, weight_specs, qlinear_shapes

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def nano_dir(tmp_path_factory):
    d = os.path.join(ART, "nano")
    if os.path.isdir(d) and os.path.exists(os.path.join(d, "manifest.json")):
        return d
    out = str(tmp_path_factory.mktemp("artifacts"))
    from compile.aot import export_config
    export_config(CONFIGS["nano"], out)
    return os.path.join(out, "nano")


@pytest.fixture(scope="module")
def manifest(nano_dir):
    with open(os.path.join(nano_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_weights_match_specs(manifest):
    cfg = CONFIGS["nano"]
    specs = weight_specs(cfg)
    assert len(manifest["weights"]) == len(specs)
    for w, (name, shape, init, q, wd) in zip(manifest["weights"], specs):
        assert w["name"] == name
        assert tuple(w["shape"]) == tuple(shape)
        assert w["quantized"] == q


def test_all_artifact_files_exist(manifest, nano_dir):
    for name, a in manifest["artifacts"].items():
        path = os.path.join(nano_dir, a["file"])
        assert os.path.exists(path), f"{name}: missing {a['file']}"
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name}: not HLO text"


def test_stage1_artifacts_cover_all_qlinear_shapes(manifest):
    cfg = CONFIGS["nano"]
    for (k, n) in qlinear_shapes(cfg):
        assert f"stage1_step_{k}x{n}" in manifest["artifacts"]
        assert f"prepare_{k}x{n}" in manifest["artifacts"]


def test_pretrain_step_io_symmetry(manifest):
    a = manifest["artifacts"]["pretrain_step"]
    n_w = len(manifest["weights"])
    assert len(a["inputs"]) == 3 * n_w + 3
    assert len(a["outputs"]) == 3 * n_w + 1
    # weight inputs and outputs carry matching shapes
    for i in range(n_w):
        assert a["inputs"][i]["shape"] == a["outputs"][i]["shape"]


def test_stage2_step_io(manifest):
    a = manifest["artifacts"]["stage2_step"]
    n_w = len(manifest["weights"])
    n_q = len(manifest["qlinears"])
    assert len(a["inputs"]) == n_w + 6 * n_q + 7
    assert len(a["outputs"]) == 3 * n_q + 3
    assert a["outputs"][-3]["name"] == "loss"


def test_eval_fwd_io(manifest):
    cfg = CONFIGS["nano"]
    for name in ["lm_fwd", "lm_fwd_aq"]:
        a = manifest["artifacts"][name]
        assert a["inputs"][-1]["dtype"] == "i32"
        assert a["inputs"][-1]["shape"] == [cfg.eval_batch, cfg.seq_len + 1]
        assert a["outputs"][0]["shape"] == [cfg.eval_batch, cfg.seq_len]
        assert a["outputs"][1]["shape"] == [cfg.eval_batch, cfg.seq_len, cfg.d_model]


def test_capture_covers_all_qlinears(manifest):
    captures = set(manifest["captures"])
    for q in manifest["qlinears"]:
        assert q["capture"] in captures
    a = manifest["artifacts"]["lm_capture"]
    out_names = {o["name"] for o in a["outputs"]}
    assert captures == out_names


def test_qlinear_shapes_match_weights(manifest):
    by_name = {w["name"]: w for w in manifest["weights"]}
    for q in manifest["qlinears"]:
        w = by_name[q["name"]]
        L, k, n = w["shape"]
        assert (q["k"], q["n"]) == (k, n)
        assert k % 16 == 0, "contraction dim must tile into NVFP4 blocks"
