"""L2 model graph tests: shapes, causality, quantized-forward wiring."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS, weight_specs
from compile.kernels import ref

CFG = CONFIGS["nano"]


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape, init, *_ in weight_specs(cfg):
        if init == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = float(init.split(":")[1])
            if init.startswith("normal_scaled"):
                std /= np.sqrt(2.0 * cfg.n_layers)
            params[name] = jnp.asarray(rng.normal(0, std, shape).astype(np.float32))
    return params


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


def toks(b, t, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, (b, t)).astype(np.int32))


def test_fwd_shapes(params):
    tokens = toks(2, 32)
    logits, hid, caps = model.fwd(CFG, params, tokens)
    assert logits.shape == (2, 32, CFG.vocab)
    assert hid.shape == (2, 32, CFG.d_model)
    assert caps is None


def test_fwd_capture_shapes(params):
    tokens = toks(2, 16)
    _, _, caps = model.fwd(CFG, params, tokens, capture=True)
    L, d, h = CFG.n_layers, CFG.d_model, CFG.mlp_hidden
    assert set(caps.keys()) == set(model.CAPTURE_NAMES)
    assert caps["attn_in"].shape == (L, 2, 16, d)
    assert caps["attn_o_in"].shape == (L, 2, 16, d)
    assert caps["mlp_in"].shape == (L, 2, 16, d)
    assert caps["mlp_down_in"].shape == (L, 2, 16, h)


def test_causality(params):
    """Future tokens must not influence past logits."""
    t1 = toks(1, 32, seed=3)
    t2 = jnp.asarray(np.asarray(t1))
    t2 = t2.at[0, 20:].set((t2[0, 20:] + 1) % CFG.vocab)
    l1, _, _ = model.fwd(CFG, params, t1)
    l2, _, _ = model.fwd(CFG, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :20]), np.asarray(l2[0, :20]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 25]), np.asarray(l2[0, 25]))


def test_nll_matches_manual(params):
    tokens = toks(2, 17, seed=5)
    logits, _, _ = model.fwd(CFG, params, tokens[:, :-1])
    nll = model.nll_from_logits(logits, tokens[:, 1:])
    assert nll.shape == (2, 16)
    lp = jax.nn.log_softmax(logits, axis=-1)
    manual = -np.take_along_axis(np.asarray(lp), np.asarray(tokens[:, 1:])[..., None], 2)[..., 0]
    np.testing.assert_allclose(np.asarray(nll), manual, rtol=1e-6)


def test_act_quant_changes_output_but_close(params):
    tokens = toks(2, 32, seed=7)
    l1, _, _ = model.fwd(CFG, params, tokens)
    l2, _, _ = model.fwd(CFG, params, tokens, act_quant=True)
    a, b = np.asarray(l1), np.asarray(l2)
    assert not np.allclose(a, b)
    # ... but it's a fake-quant, not garbage
    cos = (a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9)
    assert cos > 0.95


def test_act_fake_quant_ste():
    x = jnp.asarray(np.random.default_rng(9).normal(0, 1, (8, 32)).astype(np.float32))
    g = jax.grad(lambda x_: jnp.sum(model.act_fake_quant(x_) * 2.0))(x)
    np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones_like(g))


def test_soft_quant_params_replaces_only_qweights(params):
    qtensors = {}
    for name in model.QNAMES:
        w = params[name]
        lo, up, sc, vi = ref.quant_prepare(w)
        qtensors[name] = (lo, up, sc, vi)
    qp = model.soft_quant_params(params, qtensors, beta=20.0)
    for name in model.QNAMES:
        assert not np.allclose(np.asarray(qp[name]), np.asarray(params[name]))
    for name in ["tok_emb", "out_norm", "lm_head", "layers.attn_norm"]:
        np.testing.assert_array_equal(np.asarray(qp[name]), np.asarray(params[name]))


def test_quantized_fwd_close_to_fp(params):
    tokens = toks(2, 32, seed=11)
    qtensors = {n: ref.quant_prepare(params[n]) for n in model.QNAMES}
    qp = model.soft_quant_params(params, qtensors, beta=1e5)
    lfp, hfp, _ = model.fwd(CFG, params, tokens)
    lq, hq, _ = model.fwd(CFG, qp, tokens, act_quant=True)
    a, b = np.asarray(hfp).ravel(), np.asarray(hq).ravel()
    cos = (a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9)
    assert cos > 0.90  # random init; trained models sit much higher


def test_rope_tables():
    cos, sin = model.rope_tables(CFG, 16)
    assert cos.shape == (16, CFG.head_dim // 2)
    np.testing.assert_allclose(np.asarray(cos[0]), 1.0)
    np.testing.assert_allclose(np.asarray(sin[0]), 0.0)
    np.testing.assert_allclose(np.asarray(cos) ** 2 + np.asarray(sin) ** 2, 1.0,
                               rtol=1e-5)


def test_rmsnorm_unit_scale():
    x = jnp.asarray(np.random.default_rng(13).normal(0, 3, (4, 8)).astype(np.float32))
    y = np.asarray(model.rmsnorm(x, jnp.ones(8)))
    np.testing.assert_allclose((y ** 2).mean(-1), 1.0, rtol=1e-3)
