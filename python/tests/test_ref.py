"""Properties of the pure-jnp NVFP4 reference (the numerics oracle).

These tests pin the bit-level semantics the whole system (Pallas kernels,
rust codec, AOT graphs) is checked against.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref

RNG = np.random.default_rng(0)
NODES = np.array(ref.NODES)


def rand_w(shape, scale=0.05, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# node helpers


@pytest.mark.parametrize("wt,lo,up", [
    (0.0, 0.0, 0.0), (0.2, 0.0, 0.5), (0.5, 0.5, 0.5), (0.7, 0.5, 1.0),
    (1.0, 1.0, 1.0), (1.2, 1.0, 1.5), (1.5, 1.5, 1.5), (1.7, 1.5, 2.0),
    (2.0, 2.0, 2.0), (2.5, 2.0, 3.0), (3.0, 3.0, 3.0), (3.5, 3.0, 4.0),
    (4.0, 4.0, 4.0), (5.0, 4.0, 6.0), (6.0, 6.0, 6.0),
])
def test_interval_nodes(wt, lo, up):
    x = jnp.float32(wt)
    assert float(ref.lower_node(x)) == lo
    assert float(ref.upper_node(x)) == up


def test_interval_encloses():
    wt = jnp.asarray(RNG.uniform(0, 6, size=5000).astype(np.float32))
    lo, up = ref.lower_node(wt), ref.upper_node(wt)
    assert np.all(np.asarray(lo) <= np.asarray(wt) + 1e-7)
    assert np.all(np.asarray(up) >= np.asarray(wt) - 1e-7)
    # adjacent nodes: no representable node strictly between lo and up
    for n in NODES:
        inside = (np.asarray(lo) < n) & (n < np.asarray(up))
        assert not inside.any()


def test_rtn_ties_round_down():
    # midpoints of every interval round to the lower node
    mids = (NODES[:-1] + NODES[1:]) / 2
    lo, up = ref.lower_node(jnp.asarray(mids)), ref.upper_node(jnp.asarray(mids))
    q = ref.rtn_round(jnp.asarray(mids), lo, up)
    np.testing.assert_allclose(np.asarray(q), NODES[:-1])


def test_rtn_nearest():
    wt = jnp.asarray(RNG.uniform(0, 6, size=5000).astype(np.float32))
    lo, up = ref.lower_node(wt), ref.upper_node(wt)
    q = np.asarray(ref.rtn_round(wt, lo, up))
    # q is the nearest node (up to tie-break)
    dist_q = np.abs(q - np.asarray(wt))
    for n in NODES:
        assert np.all(dist_q <= np.abs(n - np.asarray(wt)) + 1e-6)


# ---------------------------------------------------------------------------
# scales


def test_weight_scales_shapes_and_range():
    w = rand_w((2, 64, 32))
    s, sg = ref.nvfp4_weight_scales(w)
    assert s.shape == w.shape
    assert sg.shape == (2, 1, 1)
    # every normalized magnitude lands inside the representable range, up
    # to E4M3 rounding of the block scale (<= 2^-3 relative, then clamped
    # to 6 by find_interval)
    wt = np.abs(np.asarray(w)) / np.maximum(np.asarray(s), 1e-30)
    assert wt.max() <= 6.0 * (1 + 2.0 ** -3)


def test_weight_scales_block_structure():
    w = rand_w((32, 8))
    s, _ = ref.nvfp4_weight_scales(w)
    s = np.asarray(s)
    # constant within each 16-block along K, per output column
    assert np.allclose(s[:16], s[0:1])
    assert np.allclose(s[16:], s[16:17])


def test_weight_scales_zero_block():
    w = np.zeros((32, 8), np.float32)
    w[16:, :] = RNG.normal(0, 1, (16, 8))
    s, _ = ref.nvfp4_weight_scales(jnp.asarray(w))
    assert np.all(np.asarray(s)[:16] == 0.0)
    lo, up, wt = ref.find_interval(jnp.asarray(w), s)
    assert np.all(np.asarray(wt)[:16] == 0.0)  # no NaNs from 0/0


def test_e4m3_exact_values():
    # exactly representable E4M3 values roundtrip unchanged
    for v in [1.0, 1.5, 448.0, 0.015625, 2.0 ** -9]:
        assert float(ref.e4m3_roundtrip(jnp.float32(v))) == v
    # 3 bits of mantissa: 1 + 1/8 representable, 1 + 1/16 rounds to even
    assert float(ref.e4m3_roundtrip(jnp.float32(1.125))) == 1.125
    assert float(ref.e4m3_roundtrip(jnp.float32(1.0625))) == 1.0


def test_act_scales_last_axis_blocks():
    x = rand_w((4, 32))
    s = ref.act_scales(x)
    assert s.shape == x.shape
    s = np.asarray(s)
    assert np.allclose(s[:, :16], s[:, 0:1])


# ---------------------------------------------------------------------------
# quantization behaviour


def test_rtn_is_idempotent():
    w = rand_w((64, 16))
    s, _ = ref.nvfp4_weight_scales(w)
    q1 = ref.rtn_quant(w, s)
    q2 = ref.rtn_quant(q1, s)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-6)


def test_rtn_error_bounded_by_half_interval():
    w = rand_w((64, 16))
    s, _ = ref.nvfp4_weight_scales(w)
    lo, up, wt = ref.find_interval(w, s)
    q = ref.rtn_quant(w, s)
    err = np.abs(np.asarray(q) - np.asarray(w))
    half = np.asarray((up - lo) * s) / 2
    # elements pushed past 6 by E4M3 scale rounding saturate: add the
    # clamped-off excess |w| - 6 s to the bound
    excess = np.maximum(np.abs(np.asarray(w)) - 6.0 * np.asarray(s), 0.0)
    assert np.all(err <= half + excess + 1e-6)


def test_soft_quant_limits():
    """beta -> inf turns the sigmoid into hardening at v = 0.5."""
    w = rand_w((32, 16))
    lo, up, sc, vi = ref.quant_prepare(w)
    ws = jnp.sign(w)
    hard = ref.hard_quant(ws, lo, up, sc, vi)
    soft = ref.soft_quant(ws, lo, up, sc, vi, 1e6)
    np.testing.assert_allclose(np.asarray(soft), np.asarray(hard), atol=1e-5)


def test_soft_quant_midpoint():
    """beta-sigmoid at v=0.5 interpolates to the interval middle."""
    w = rand_w((32, 16))
    lo, up, sc, _ = ref.quant_prepare(w)
    v = jnp.full(w.shape, 0.5)
    out = ref.soft_quant(jnp.sign(w), lo, up, sc, v, 10.0)
    mid = np.asarray(jnp.sign(w) * (lo + 0.5 * (up - lo)) * sc)
    np.testing.assert_allclose(np.asarray(out), mid, atol=1e-6)


def test_v_init_in_unit_interval_and_faithful():
    w = rand_w((64, 64))
    lo, up, sc, vi = ref.quant_prepare(w)
    vi = np.asarray(vi)
    assert np.all(vi >= 0) and np.all(vi <= 1)
    # reconstruction with h := v_init (identity interpolation) recovers |w|/s
    lo, up, sc = map(np.asarray, (lo, up, sc))
    wt = np.abs(np.asarray(w)) / np.maximum(sc, 1e-30)
    rec = lo + vi * (up - lo)
    mask = (up - lo) > 0
    np.testing.assert_allclose(rec[mask], np.clip(wt, 0, 6)[mask], atol=1e-4)


def test_harden_threshold():
    v = jnp.asarray([0.0, 0.49, 0.5, 0.51, 1.0])
    np.testing.assert_array_equal(np.asarray(ref.harden(v)), [0, 0, 1, 1, 1])


def test_round_loss_range():
    assert float(ref.round_loss(jnp.asarray([0.0, 1.0]))) == pytest.approx(0.0)
    assert float(ref.round_loss(jnp.asarray([0.5]))) == pytest.approx(1.0)


def test_hard_quant_on_grid():
    """Hardened weights are exactly on the NVFP4 grid: |wq|/s in N."""
    w = rand_w((64, 32))
    lo, up, sc, vi = ref.quant_prepare(w)
    q = np.asarray(ref.hard_quant(jnp.sign(w), lo, up, sc, vi))
    sc_np = np.asarray(sc)
    mask = sc_np > 0
    wt = np.abs(q[mask]) / sc_np[mask]
    dist = np.min(np.abs(wt[:, None] - NODES[None, :]), axis=1)
    assert dist.max() < 1e-4


def test_sign_preserved():
    w = rand_w((64, 32))
    s, _ = ref.nvfp4_weight_scales(w)
    q = np.asarray(ref.rtn_quant(w, s))
    w_np = np.asarray(w)
    nz = q != 0
    assert np.all(np.sign(q[nz]) == np.sign(w_np[nz]))


def test_grad_v_matches_autodiff():
    import jax
    w = rand_w((16, 16))
    lo, up, sc, vi = ref.quant_prepare(w)
    ws = jnp.sign(w)
    beta = 12.0
    g = rand_w((16, 16), scale=1.0, seed=7)

    def f(v):
        return jnp.sum(ref.soft_quant(ws, lo, up, sc, v, beta) * g)

    auto = jax.grad(f)(vi)
    manual = ref.soft_quant_grad_v(ws, lo, up, sc, vi, beta, g)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(manual), rtol=1e-5, atol=1e-8)
