//! Pipeline configuration: every hyperparameter of the FAAR + 2FA run,
//! loadable from a JSON file, overridable from the CLI, and serialized
//! into every results file so experiments are self-describing.
//!
//! Defaults follow DESIGN.md §7 (which pins down everything the paper
//! leaves implicit).

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::{cli::Args, json::Json};

/// β annealing schedule: log-linear from `beta_start` to `beta_end`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BetaSchedule {
    /// β at the start of stage 1
    pub start: f32,
    /// β at the end of stage 1
    pub end: f32,
}

impl BetaSchedule {
    /// β at progress t ∈ [0, 1].
    pub fn at(&self, t: f32) -> f32 {
        let t = t.clamp(0.0, 1.0);
        (self.start.ln() + (self.end.ln() - self.start.ln()) * t).exp()
    }
}

/// Scale-selection method for the NVFP4 block scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleMethod {
    /// amax/6 (the NVFP4 default recipe)
    Standard,
    /// per-block choice between amax→6 and amax→4 by block MSE ("4/6")
    FourSix,
    /// per-block MSE-optimal search over a scale grid (strong baseline)
    Search,
}

impl ScaleMethod {
    /// Parse a scale-method name (`standard|foursix|search`).
    pub fn parse(s: &str) -> Result<ScaleMethod> {
        match s {
            "standard" => Ok(ScaleMethod::Standard),
            "foursix" | "4/6" => Ok(ScaleMethod::FourSix),
            "search" => Ok(ScaleMethod::Search),
            _ => bail!("unknown scale method '{s}' (standard|foursix|search)"),
        }
    }

    /// Canonical name (matches [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            ScaleMethod::Standard => "standard",
            ScaleMethod::FourSix => "foursix",
            ScaleMethod::Search => "search",
        }
    }
}

#[derive(Clone, Debug)]
/// Every hyperparameter of one pipeline run. Field groups follow
/// the pipeline stages; defaults are DESIGN.md §7.
pub struct PipelineConfig {
    /// model preset (must match an artifacts/<name>/ directory)
    pub model: String,
    /// directory holding `artifacts/<model>/`
    pub artifact_root: String,
    /// results/checkpoint output directory
    pub out_dir: String,
    /// global seed (init, data streams, trials)
    pub seed: u64,

    // pretraining
    /// pretraining optimizer steps
    pub pretrain_steps: usize,
    /// pretraining peak learning rate
    pub pretrain_lr: f32,
    /// linear LR warmup steps
    pub pretrain_warmup: usize,

    // calibration
    /// calibration batches captured from the frozen model
    pub calib_batches: usize,

    // FAAR stage 1 (per layer)
    /// FAAR stage-1 steps per layer
    pub stage1_steps: usize,
    /// stage-1 learning rate
    pub stage1_lr: f32,
    /// rounding-regularizer weight λ_round
    pub lam_round: f32,
    /// fraction of steps before λ_round reaches full strength
    pub lam_warmup_frac: f32,
    /// β annealing schedule for the soft-round sigmoid
    pub beta: BetaSchedule,

    // 2FA stage 2 (global alignment)
    /// 2FA stage-2 global-alignment steps
    pub stage2_steps: usize,
    /// stage-2 learning rate
    pub stage2_lr: f32,
    /// stage-2 KL-alignment weight
    pub lam_kl: f32,
    /// stage-2 distillation temperature
    pub tau: f32,

    // quantization options
    /// block-scale selection recipe
    pub scale_method: ScaleMethod,
    /// evaluate with activation quantization (W4A4) — paper setting
    pub act_quant_eval: bool,

    // evaluation
    /// evaluation batches per metric
    pub eval_batches: usize,

    // GPTQ
    /// GPTQ Hessian damping factor
    pub gptq_damp: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            model: "tiny".into(),
            artifact_root: "artifacts".into(),
            out_dir: "results".into(),
            seed: 42,
            pretrain_steps: 400,
            pretrain_lr: 1e-3,
            pretrain_warmup: 40,
            calib_batches: 8,
            stage1_steps: 300,
            stage1_lr: 1e-2,
            lam_round: 1e-3,
            lam_warmup_frac: 0.2,
            beta: BetaSchedule { start: 5.0, end: 50.0 },
            stage2_steps: 1000,
            stage2_lr: 5e-4,
            lam_kl: 1.0,
            tau: 2.0,
            scale_method: ScaleMethod::Standard,
            act_quant_eval: true,
            eval_batches: 16,
            gptq_damp: 0.01,
        }
    }
}

impl PipelineConfig {
    /// Load from JSON file (all keys optional, overriding defaults).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        let mut c = PipelineConfig::default();
        c.apply_json(&v)?;
        Ok(c)
    }

    /// Apply JSON overrides onto this config (unknown keys error).
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        let obj = v.as_obj()?;
        for (k, val) in obj {
            match k.as_str() {
                "model" => self.model = val.as_str()?.to_string(),
                "artifact_root" => self.artifact_root = val.as_str()?.to_string(),
                "out_dir" => self.out_dir = val.as_str()?.to_string(),
                "seed" => self.seed = val.as_f64()? as u64,
                "pretrain_steps" => self.pretrain_steps = val.as_usize()?,
                "pretrain_lr" => self.pretrain_lr = val.as_f64()? as f32,
                "pretrain_warmup" => self.pretrain_warmup = val.as_usize()?,
                "calib_batches" => self.calib_batches = val.as_usize()?,
                "stage1_steps" => self.stage1_steps = val.as_usize()?,
                "stage1_lr" => self.stage1_lr = val.as_f64()? as f32,
                "lam_round" => self.lam_round = val.as_f64()? as f32,
                "lam_warmup_frac" => self.lam_warmup_frac = val.as_f64()? as f32,
                "beta_start" => self.beta.start = val.as_f64()? as f32,
                "beta_end" => self.beta.end = val.as_f64()? as f32,
                "stage2_steps" => self.stage2_steps = val.as_usize()?,
                "stage2_lr" => self.stage2_lr = val.as_f64()? as f32,
                "lam_kl" => self.lam_kl = val.as_f64()? as f32,
                "tau" => self.tau = val.as_f64()? as f32,
                "scale_method" => self.scale_method = ScaleMethod::parse(val.as_str()?)?,
                "act_quant_eval" => self.act_quant_eval = val.as_bool()?,
                "eval_batches" => self.eval_batches = val.as_usize()?,
                "gptq_damp" => self.gptq_damp = val.as_f64()?,
                _ => bail!("unknown config key '{k}'"),
            }
        }
        Ok(())
    }

    /// CLI overrides (--model, --stage1-steps, ... with kebab-case keys).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(p) = args.get("config-file") {
            *self = Self::from_file(Path::new(p))?;
        }
        self.model = args.str_or("model", &self.model);
        self.artifact_root = args.str_or("artifacts", &self.artifact_root);
        self.out_dir = args.str_or("out", &self.out_dir);
        self.seed = args.u64_or("seed", self.seed)?;
        self.pretrain_steps = args.usize_or("pretrain-steps", self.pretrain_steps)?;
        self.pretrain_lr = args.f32_or("pretrain-lr", self.pretrain_lr)?;
        self.calib_batches = args.usize_or("calib-batches", self.calib_batches)?;
        self.stage1_steps = args.usize_or("stage1-steps", self.stage1_steps)?;
        self.stage1_lr = args.f32_or("stage1-lr", self.stage1_lr)?;
        self.lam_round = args.f32_or("lam-round", self.lam_round)?;
        self.beta.start = args.f32_or("beta-start", self.beta.start)?;
        self.beta.end = args.f32_or("beta-end", self.beta.end)?;
        self.stage2_steps = args.usize_or("stage2-steps", self.stage2_steps)?;
        self.stage2_lr = args.f32_or("stage2-lr", self.stage2_lr)?;
        self.lam_kl = args.f32_or("lam-kl", self.lam_kl)?;
        self.tau = args.f32_or("tau", self.tau)?;
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches)?;
        if let Some(s) = args.get("scale-method") {
            self.scale_method = ScaleMethod::parse(s)?;
        }
        Ok(())
    }

    /// Serialize the experiment-relevant fields (results provenance).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.as_str())),
            ("seed", Json::num(self.seed as f64)),
            ("pretrain_steps", Json::num(self.pretrain_steps as f64)),
            ("pretrain_lr", Json::num(self.pretrain_lr as f64)),
            ("calib_batches", Json::num(self.calib_batches as f64)),
            ("stage1_steps", Json::num(self.stage1_steps as f64)),
            ("stage1_lr", Json::num(self.stage1_lr as f64)),
            ("lam_round", Json::num(self.lam_round as f64)),
            ("beta_start", Json::num(self.beta.start as f64)),
            ("beta_end", Json::num(self.beta.end as f64)),
            ("stage2_steps", Json::num(self.stage2_steps as f64)),
            ("stage2_lr", Json::num(self.stage2_lr as f64)),
            ("lam_kl", Json::num(self.lam_kl as f64)),
            ("tau", Json::num(self.tau as f64)),
            ("scale_method", Json::str(self.scale_method.name())),
            ("act_quant_eval", Json::Bool(self.act_quant_eval)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_schedule_endpoints() {
        let b = BetaSchedule { start: 5.0, end: 50.0 };
        assert!((b.at(0.0) - 5.0).abs() < 1e-4);
        assert!((b.at(1.0) - 50.0).abs() < 1e-3);
        let mid = b.at(0.5);
        assert!(mid > 5.0 && mid < 50.0);
        // log-linear midpoint = geometric mean
        assert!((mid - (5.0f32 * 50.0).sqrt()).abs() < 1e-2);
        // clamped
        assert_eq!(b.at(-1.0), b.at(0.0));
        assert_eq!(b.at(2.0), b.at(1.0));
    }

    #[test]
    fn json_roundtrip_overrides() {
        let mut c = PipelineConfig::default();
        let j = Json::parse(r#"{"model":"small","stage1_steps":42,"beta_end":99.0}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.stage1_steps, 42);
        assert_eq!(c.beta.end, 99.0);
        // untouched default
        assert_eq!(c.stage2_steps, 1000);
    }

    #[test]
    fn rejects_unknown_keys() {
        let mut c = PipelineConfig::default();
        let j = Json::parse(r#"{"stage1_stepz": 1}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            "x --model small --stage2-steps 7 --scale-method foursix"
                .split_whitespace()
                .map(String::from),
            &[],
        )
        .unwrap();
        let mut c = PipelineConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.stage2_steps, 7);
        assert_eq!(c.scale_method, ScaleMethod::FourSix);
    }

    #[test]
    fn scale_method_parse() {
        assert_eq!(ScaleMethod::parse("4/6").unwrap(), ScaleMethod::FourSix);
        assert!(ScaleMethod::parse("nope").is_err());
    }
}
