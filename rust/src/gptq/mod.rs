//! GPTQ / MR-GPTQ solvers on the NVFP4 grid (paper baselines, Table 3).
//!
//! GPTQ [Frantar et al. 2022] quantizes a linear layer `y = x W`
//! (`W[K, N]`, K = contraction) one K-row at a time, compensating the
//! not-yet-quantized rows with the inverse Hessian of the layer inputs
//! `H = 2 X^T X`. We implement the classic Cholesky formulation in f64:
//!
//!   1. H ← 2 XᵀX + λ·mean(diag) I   (damping)
//!   2. H⁻¹ via Cholesky; U = chol(H⁻¹)ᵀ  (upper)
//!   3. for each row k: quantize W[k, :] to the fixed per-block NVFP4
//!      grid; propagate err/U[k,k] · U[k, k+1:] into later rows.
//!
//! MR-GPTQ [22] additionally re-optimizes each 16-block's scale (MSE
//! search) on the *error-compensated* weights right before that block's
//! rows are quantized — the "format-aware" GPTQ variant.
//!
//! This module is pure rust (no XLA): calibration activations come from
//! the capture artifact via calib/.

use anyhow::{bail, Result};

use crate::formats::{e2m1, e4m3, nvfp4};
use crate::tensor::Tensor;

/// Accumulated layer-input statistics for one linear: H = 2 XᵀX.
#[derive(Clone, Debug)]
pub struct Hessian {
    /// contraction dimension of the layer
    pub k: usize,
    /// row-major [K, K], f64
    pub h: Vec<f64>,
    /// input rows accumulated so far
    pub n_rows: usize,
}

impl Hessian {
    /// A zeroed accumulator for a `[K, N]` linear.
    pub fn new(k: usize) -> Hessian {
        Hessian { k, h: vec![0.0; k * k], n_rows: 0 }
    }

    /// Accumulate a batch of input rows X[R, K].
    pub fn update(&mut self, x: &Tensor) -> Result<()> {
        let (r, k) = x.mat_dims()?;
        if k != self.k {
            bail!("hessian dim {} != input dim {k}", self.k);
        }
        for row in 0..r {
            let xr = &x.data[row * k..(row + 1) * k];
            for i in 0..k {
                let xi = 2.0 * xr[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h[i * k..(i + 1) * k];
                for j in 0..k {
                    hrow[j] += xi * xr[j] as f64;
                }
            }
        }
        self.n_rows += r;
        Ok(())
    }

    /// Damped copy: H + λ·mean(diag)·I. Dead columns (zero diag) get the
    /// damping term only, which GPTQ treats as "quantize without
    /// compensation" for that coordinate.
    pub fn damped(&self, lambda: f64) -> Vec<f64> {
        let k = self.k;
        let mean_diag =
            (0..k).map(|i| self.h[i * k + i]).sum::<f64>() / k as f64;
        let damp = (lambda * mean_diag).max(1e-12);
        let mut out = self.h.clone();
        for i in 0..k {
            out[i * k + i] += damp;
        }
        out
    }
}

/// Cholesky decomposition (lower L, in place on a copy): A = L Lᵀ.
/// Returns row-major L with zeros above the diagonal.
pub fn cholesky(a: &[f64], k: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; k * k];
    for i in 0..k {
        for j in 0..=i {
            let mut sum = a[i * k + j];
            for p in 0..j {
                sum -= l[i * k + p] * l[j * k + p];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at {i} (sum={sum})");
                }
                l[i * k + i] = sum.sqrt();
            } else {
                l[i * k + j] = sum / l[j * k + j];
            }
        }
    }
    Ok(l)
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &[f64], k: usize) -> Result<Vec<f64>> {
    let l = cholesky(a, k)?;
    // invert lower-triangular L by forward substitution, column by column
    let mut linv = vec![0.0f64; k * k];
    for col in 0..k {
        linv[col * k + col] = 1.0 / l[col * k + col];
        for i in col + 1..k {
            let mut sum = 0.0;
            for p in col..i {
                sum -= l[i * k + p] * linv[p * k + col];
            }
            linv[i * k + col] = sum / l[i * k + i];
        }
    }
    // A⁻¹ = Lᵀ⁻¹ L⁻¹ = (L⁻¹)ᵀ (L⁻¹)
    let mut inv = vec![0.0f64; k * k];
    for i in 0..k {
        for j in 0..k {
            let mut sum = 0.0;
            for p in i.max(j)..k {
                sum += linv[p * k + i] * linv[p * k + j];
            }
            inv[i * k + j] = sum;
        }
    }
    Ok(inv)
}

/// Upper-triangular Cholesky factor of H⁻¹ scaled GPTQ-style:
/// returns U with U = chol(H⁻¹, upper). The classic implementation keeps
/// D = diag(U); the compensation for row k uses U[k, k..] / U[k, k].
fn gptq_factor(h_damped: &[f64], k: usize) -> Result<Vec<f64>> {
    let inv = spd_inverse(h_damped, k)?;
    // upper Cholesky of inv: inv = Uᵀ U  with U upper triangular.
    // chol_lower(P inv P)ᵀ trick avoided; direct algorithm:
    // U[i][j] for i<=j, computed bottom-up is equivalent to
    // L = cholesky(reverse(inv)) reversed. Simpler: cholesky of inv gives
    // lower L1 with inv = L1 L1ᵀ ⇒ U = L1ᵀ is NOT upper-cholesky of inv
    // in the Uᵀ U sense... but GPTQ only needs *some* factorization
    // inv = C Cᵀ with the sequential-elimination property along the
    // quantization order, which L1ᵀ (processing rows in order of L1's
    // columns) provides. We therefore return L1 and index it as
    // U[i][j] := L1[j][i] (j >= i).
    cholesky(&inv, k)
}

/// Options for the GPTQ solve.
#[derive(Clone, Copy, Debug)]
pub struct GptqOptions {
    /// relative Hessian damping (λ · mean diag)
    pub damp: f64,
    /// MR-GPTQ: re-optimize each block's scale on compensated weights
    pub mr_scales: bool,
}

impl Default for GptqOptions {
    fn default() -> Self {
        GptqOptions { damp: 0.01, mr_scales: false }
    }
}

/// Quantize one linear's weights `w[K, N]` with GPTQ error compensation
/// onto the NVFP4 grid defined by `prepared` scales. Returns the
/// dequantized weight tensor (same shape).
pub fn gptq_quantize(
    w: &Tensor,
    hessian: &Hessian,
    scale: &Tensor,
    s_global: &[f32],
    opts: GptqOptions,
) -> Result<Tensor> {
    Ok(gptq_quantize_with_scales(w, hessian, scale, s_global, opts)?.0)
}

/// Like [`gptq_quantize`] but also returns the final effective-scale
/// tensor. MR-GPTQ re-optimizes block scales mid-solve, so callers that
/// pack the result (`formats::codec::encode_nvfp4_on_grid`) need the
/// scales the solution actually sits on.
pub fn gptq_quantize_with_scales(
    w: &Tensor,
    hessian: &Hessian,
    scale: &Tensor,
    s_global: &[f32],
    opts: GptqOptions,
) -> Result<(Tensor, Tensor)> {
    let (k, n) = w.mat_dims()?;
    if w.rank() != 2 {
        bail!("gptq_quantize expects [K, N], got {:?}", w.shape);
    }
    if hessian.k != k {
        bail!("hessian K mismatch");
    }
    let hd = hessian.damped(opts.damp);
    let l1 = gptq_factor(&hd, k)?; // lower cholesky of H^-1
    // U[i][j] := l1[j*k + i] for j >= i (see gptq_factor comment)
    let u = |i: usize, j: usize| l1[j * k + i];

    let mut work = w.data.clone(); // compensated weights, mutated in place
    let mut out = vec![0.0f32; k * n];
    let mut scale_work = scale.data.clone();
    let s_g = s_global[0];

    for row in 0..k {
        // MR-GPTQ: at each block boundary, re-search the block scale on
        // the *current* (compensated) values of the block's rows.
        if opts.mr_scales && row % nvfp4::BLOCK == 0 {
            let kb = row / nvfp4::BLOCK;
            for col in 0..n {
                let mut block = [0.0f32; nvfp4::BLOCK];
                for r in 0..nvfp4::BLOCK {
                    block[r] = work[(kb * nvfp4::BLOCK + r) * n + col];
                }
                let amax = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if amax == 0.0 {
                    continue;
                }
                let mut best = f64::INFINITY;
                let mut best_eff = 0.0f32;
                for cand in [1.0 / 6.0f32, 1.0 / 5.4, 1.0 / 5.0, 1.0 / 4.6, 1.0 / 4.0] {
                    let s_eff = e4m3::roundtrip(amax * cand / s_g) * s_g;
                    let mut mse = 0.0f64;
                    for &x in &block {
                        let wt = (x.abs() / s_eff.max(1e-30)).min(e2m1::FP4_MAX);
                        let q = e2m1::decode(e2m1::encode_rtn(wt)) * s_eff;
                        mse += ((x.abs() - q) as f64).powi(2);
                    }
                    if mse < best {
                        best = mse;
                        best_eff = s_eff;
                    }
                }
                for r in 0..nvfp4::BLOCK {
                    scale_work[(kb * nvfp4::BLOCK + r) * n + col] = best_eff;
                }
            }
        }

        let d = u(row, row);
        for col in 0..n {
            let x = work[row * n + col];
            let s = scale_work[row * n + col];
            let q = if s > 0.0 {
                let wt = (x.abs() / s.max(1e-30)).min(e2m1::FP4_MAX);
                let node = e2m1::decode(e2m1::encode_rtn(wt));
                nvfp4::sign(x) * node * s
            } else {
                0.0
            };
            out[row * n + col] = q;
            // propagate the error into the not-yet-quantized rows
            let err = (x - q) as f64 / d;
            for r2 in row + 1..k {
                work[r2 * n + col] -= (err * u(row, r2)) as f32;
            }
        }
    }
    Ok((Tensor::new(out, w.shape.clone()), Tensor::new(scale_work, w.shape.clone())))
}

/// Convenience: GPTQ over a stacked weight tensor [L, K, N], with one
/// Hessian per layer slice.
pub fn gptq_quantize_stacked(
    w: &Tensor,
    hessians: &[Hessian],
    scale: &Tensor,
    s_global: &[f32],
    opts: GptqOptions,
) -> Result<Tensor> {
    Ok(gptq_quantize_stacked_with_scales(w, hessians, scale, s_global, opts)?.0)
}

/// Stacked GPTQ returning (dequantized weights, final effective scales).
pub fn gptq_quantize_stacked_with_scales(
    w: &Tensor,
    hessians: &[Hessian],
    scale: &Tensor,
    s_global: &[f32],
    opts: GptqOptions,
) -> Result<(Tensor, Tensor)> {
    let lead = w.lead();
    if hessians.len() != lead {
        bail!("{} hessians for {} slices", hessians.len(), lead);
    }
    let mut out = Tensor::zeros(&w.shape);
    let mut scales_out = Tensor::zeros(&w.shape);
    for l in 0..lead {
        let ws = w.index0(l);
        let ss = scale.index0(l);
        let (q, sq) = gptq_quantize_with_scales(&ws, &hessians[l], &ss, &[s_global[l]], opts)?;
        out.set_index0(l, &q);
        scales_out.set_index0(l, &sq);
    }
    Ok((out, scales_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::nvfp4::{prepare, rtn_quant};
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    #[test]
    fn cholesky_known() {
        // A = [[4,2],[2,3]] -> L = [[2,0],[1,sqrt(2)]]
        let l = cholesky(&[4.0, 2.0, 2.0, 3.0], 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 2.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(l[1], 0.0);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        assert!(cholesky(&[1.0, 2.0, 2.0, 1.0], 2).is_err());
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let k = 8;
        let x = rand_t(&[32, k], 1, 1.0);
        let mut h = Hessian::new(k);
        h.update(&x).unwrap();
        let hd = h.damped(0.01);
        let inv = spd_inverse(&hd, k).unwrap();
        // hd * inv ≈ I
        for i in 0..k {
            for j in 0..k {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += hd[i * k + p] * inv[p * k + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((acc - expect).abs() < 1e-8, "({i},{j}): {acc}");
            }
        }
    }

    #[test]
    fn hessian_accumulates() {
        let k = 4;
        let mut h = Hessian::new(k);
        let x = Tensor::new(vec![1.0, 0.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0], vec![2, 4]);
        h.update(&x).unwrap();
        assert_eq!(h.n_rows, 2);
        assert_eq!(h.h[0], 2.0); // 2 * 1 * 1
        assert_eq!(h.h[1 * 4 + 1], 8.0); // 2 * 2 * 2
        assert!(h.update(&Tensor::zeros(&[2, 5])).is_err());
    }

    fn layer_output_mse(x: &Tensor, w: &Tensor, wq: &Tensor) -> f64 {
        let y = x.matmul(w).unwrap();
        let yq = x.matmul(wq).unwrap();
        crate::util::stats::mse(&y.data, &yq.data)
    }

    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        let k = 64;
        let n = 32;
        let w = rand_t(&[k, n], 2, 0.05);
        // correlated inputs (what makes GPTQ shine)
        let base = rand_t(&[256, k], 3, 1.0);
        let mut x = base.clone();
        for r in 0..256 {
            for c in 1..k {
                x.data[r * k + c] = 0.7 * x.data[r * k + c - 1] + 0.3 * base.data[r * k + c];
            }
        }
        let mut h = Hessian::new(k);
        h.update(&x).unwrap();
        let p = prepare(&w);
        let w_rtn = rtn_quant(&w, &p);
        let w_gptq =
            gptq_quantize(&w, &h, &p.scale, &p.s_global, GptqOptions::default()).unwrap();
        let rtn_mse = layer_output_mse(&x, &w, &w_rtn);
        let gptq_mse = layer_output_mse(&x, &w, &w_gptq);
        assert!(
            gptq_mse < rtn_mse * 0.9,
            "gptq {gptq_mse} not clearly better than rtn {rtn_mse}"
        );
    }

    #[test]
    fn mr_gptq_not_worse_than_gptq() {
        let k = 64;
        let n = 16;
        let w = rand_t(&[k, n], 5, 0.05);
        let x = rand_t(&[128, k], 6, 1.0);
        let mut h = Hessian::new(k);
        h.update(&x).unwrap();
        let p = prepare(&w);
        let a = gptq_quantize(&w, &h, &p.scale, &p.s_global, GptqOptions::default()).unwrap();
        let b = gptq_quantize(
            &w,
            &h,
            &p.scale,
            &p.s_global,
            GptqOptions { mr_scales: true, ..Default::default() },
        )
        .unwrap();
        let ma = layer_output_mse(&x, &w, &a);
        let mb = layer_output_mse(&x, &w, &b);
        assert!(mb <= ma * 1.1, "mr-gptq {mb} much worse than gptq {ma}");
    }

    #[test]
    fn gptq_output_on_grid() {
        let k = 32;
        let n = 8;
        let w = rand_t(&[k, n], 7, 0.05);
        let x = rand_t(&[64, k], 8, 1.0);
        let mut h = Hessian::new(k);
        h.update(&x).unwrap();
        let p = prepare(&w);
        let q = gptq_quantize(&w, &h, &p.scale, &p.s_global, GptqOptions::default()).unwrap();
        for i in 0..q.numel() {
            let s = p.scale.data[i];
            if s > 0.0 {
                let wt = q.data[i].abs() / s;
                let nearest = crate::formats::NODES
                    .iter()
                    .map(|&nd| (wt - nd).abs())
                    .fold(f32::INFINITY, f32::min);
                assert!(nearest < 1e-3, "off grid: {wt}");
            }
        }
    }

    #[test]
    fn stacked_solver() {
        let w = rand_t(&[2, 32, 8], 9, 0.05);
        let x0 = rand_t(&[64, 32], 10, 1.0);
        let x1 = rand_t(&[64, 32], 11, 1.0);
        let mut h0 = Hessian::new(32);
        let mut h1 = Hessian::new(32);
        h0.update(&x0).unwrap();
        h1.update(&x1).unwrap();
        let p = prepare(&w);
        let q = gptq_quantize_stacked(&w, &[h0, h1], &p.scale, &p.s_global,
                                      GptqOptions::default())
            .unwrap();
        assert_eq!(q.shape, w.shape);
        assert!(q.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn degenerate_hessian_safe() {
        // all-zero activations: damping keeps it SPD; GPTQ degrades to RTN
        let k = 32;
        let n = 8;
        let w = rand_t(&[k, n], 12, 0.05);
        let h = Hessian::new(k); // never updated
        let p = prepare(&w);
        // zero diag → damped with max(…, 1e-12) floor; must not panic
        let q = gptq_quantize(&w, &h, &p.scale, &p.s_global, GptqOptions::default()).unwrap();
        let rtn = rtn_quant(&w, &p);
        for i in 0..q.numel() {
            assert!((q.data[i] - rtn.data[i]).abs() < 1e-5);
        }
    }
}
