//! Tiny CLI argument parser (offline environment — no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed accessors, defaults, and a usage printer.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
/// Parsed command line: positionals, `--key value` options, and
/// boolean `--flag`s.
pub struct Args {
    /// positional arguments, in order (subcommand first)
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options
    pub options: BTreeMap<String, String>,
    /// boolean flags that were present
    pub flags: Vec<String>,
    known_flags: Vec<&'static str>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&'static str],
    ) -> Result<Args> {
        let mut out = Args { known_flags: flag_names.to_vec(), ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env(flag_names: &[&'static str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    /// True when the boolean flag `name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Option value, or an error naming the missing option.
    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Parsed `usize` option with a default.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    /// Parsed `u64` option with a default.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    /// Parsed `f64` option with a default.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    /// Parsed `f32` option with a default.
    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// The first positional (the subcommand), or an error.
    pub fn subcommand(&self) -> Result<&str> {
        self.positional
            .first()
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("expected a subcommand"))
    }

    /// Error on any option/flag not in `known` (strict subcommands).
    pub fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !self.known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&'static str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse("quantize --config tiny --steps=300 --verbose extra", &["verbose"]);
        assert_eq!(a.subcommand().unwrap(), "quantize");
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["quantize", "extra"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("run", &[]);
        assert_eq!(a.str_or("out", "results"), "results");
        assert!(a.req("config").is_err());
        assert_eq!(a.f64_or("lr", 5e-4).unwrap(), 5e-4);
    }

    #[test]
    fn lists() {
        let a = parse("x --methods rtn,gptq, faar", &[]);
        // note: space after comma splits the shell token; emulate single token
        let b = parse("x --methods=rtn,gptq,faar", &[]);
        assert_eq!(b.list_or("methods", &[]), vec!["rtn", "gptq", "faar"]);
        assert_eq!(a.list_or("missing", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["--config".to_string()], &[]).is_err());
    }

    #[test]
    fn reject_unknown() {
        let a = parse("x --bogus 1", &[]);
        assert!(a.reject_unknown(&["config"]).is_err());
        assert!(a.reject_unknown(&["bogus"]).is_ok());
    }
}
