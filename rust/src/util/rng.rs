//! Deterministic RNG: xoshiro256** seeded via splitmix64.
//!
//! Every stochastic piece of the system (weight init, corpus generation,
//! stochastic rounding trials, property tests) derives from one of these,
//! so every experiment in EXPERIMENTS.md is reproducible from its seed.

#[derive(Clone, Debug)]
/// xoshiro256** generator with a splitmix64-seeded state and a
/// cached Box-Muller normal sample.
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// A generator seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss: None }
    }

    /// Derive an independent stream (e.g. per-layer, per-trial).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(g) = self.gauss.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// N(mean, std) sample as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Fill a buffer with N(mean, std) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], mean: f32, std: f32) {
        for x in buf.iter_mut() {
            *x = self.normal_f32(mean, std);
        }
    }

    /// True with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn categorical_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(21);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
