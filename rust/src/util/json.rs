//! Minimal JSON codec (offline environment — no serde_json).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate pairs
//! are decoded), preserves object key order, and is round-trip stable for
//! everything this project writes (manifests, results, metrics).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// Maximum container nesting depth `Json::parse` accepts.
///
/// The parser is recursive, so unbounded nesting would turn a ~64 KiB
/// request line of `[[[[…` into a stack overflow (an abort, not a
/// catchable error). The serve codec layer enforces the same bound
/// incrementally ([`crate::serve::codec::CodecLimits`]), so both the
/// line codec and the incremental decoder reject at exactly this depth.
pub const MAX_DEPTH: usize = 64;

#[derive(Clone, Debug, PartialEq)]
/// A parsed JSON value (object keys keep their source order).
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as f64).
    Num(f64),
    /// A string (escapes already decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from any iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a number.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors --------------------------------------------------------
    /// Object field lookup (`None` for missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors on a missing key.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// The value as a number, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as a non-negative integer, or an error.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    /// The value as a string, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as a bool, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// The value as an array, or an error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The value as ordered key/value pairs, or an error.
    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// The value as an array of non-negative integers.
    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    /// The value as a key-sorted map (clones; drops duplicate keys).
    pub fn to_map(&self) -> Result<BTreeMap<String, Json>> {
        Ok(self.as_obj()?.iter().cloned().collect())
    }

    // ---- parse ------------------------------------------------------------
    /// Parse one complete JSON document (trailing data is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value(0)?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- serialize ----------------------------------------------------------
    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    it.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(depth),
            b'[' => self.array(depth),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i);
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let h = self.hex4()?;
                            if (0xD800..0xDC00).contains(&h) {
                                // surrogate pair: the low half must follow
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("bad surrogate pair \\u{h:04x}\\u{lo:04x}");
                                }
                                let cp = 0x10000 + ((h - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                out.push(char::from_u32(h).ok_or_else(|| anyhow!("bad \\u escape"))?);
                            }
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character 0x{c:02x} in string"),
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte UTF-8: copy remaining continuation bytes
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    let start = self.i - 1;
                    if start + len > self.b.len() {
                        bail!("unexpected end of JSON inside UTF-8 sequence");
                    }
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("unexpected end of JSON inside \\u escape");
        }
        let bytes = &self.b[self.i..self.i + 4];
        if !bytes.iter().all(|b| b.is_ascii_hexdigit()) {
            bail!("bad \\u escape at byte {}", self.i);
        }
        let s = std::str::from_utf8(bytes)?;
        self.i += 4;
        Ok(u32::from_str_radix(s, 16)?)
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        if depth >= MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH}");
        }
        self.eat(b'[')?;
        let mut items = vec![];
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value(depth + 1)?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        if depth >= MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH}");
        }
        self.eat(b'{')?;
        let mut pairs = vec![];
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value(depth + 1)?;
            pairs.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"tiny","dims":[128,352],"nested":{"ok":true,"x":null},"f":0.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("a", Json::arr([Json::num(1), Json::num(2)])),
            ("b", Json::str("x \"quoted\"")),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode() {
        let v = Json::parse(r#""é€ é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é€ é");
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn truncated_inputs_error_not_panic() {
        // each of these used to slice out of bounds (or underflow) instead
        // of returning Err — the fuzz harness over the serve codec relies
        // on parse never panicking
        assert!(Json::parse(r#""\u12"#).is_err()); // truncated \u escape
        assert!(Json::parse(r#""\u"#).is_err());
        assert!(Json::parse("\"\u{e9}").is_err()); // unterminated after multibyte
        assert!(Json::parse(r#""\uD800"#).is_err()); // high surrogate at end
        assert!(Json::parse(r#""\uD800A""#).is_err()); // bad low surrogate
        assert!(Json::parse(r#""\uDC00""#).is_err()); // lone low surrogate
        assert!(Json::parse(r#""\uZZZZ""#).is_err()); // non-hex digits
        assert!(Json::parse(r#""\u+123""#).is_err()); // sign accepted by from_str_radix
    }

    #[test]
    fn surrogate_pair_decodes() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn depth_limit() {
        let ok = "[".repeat(MAX_DEPTH) + "0" + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        let too_deep = "[".repeat(MAX_DEPTH + 1) + "0" + &"]".repeat(MAX_DEPTH + 1);
        let err = Json::parse(&too_deep).unwrap_err().to_string();
        assert!(err.contains("nesting"), "unexpected error: {err}");
        // a pathological frame of open brackets must error, not blow the stack
        assert!(Json::parse(&"[".repeat(60_000)).is_err());
        assert!(Json::parse(&"{\"a\":".repeat(60_000)).is_err());
    }

    #[test]
    fn control_chars_in_strings_rejected() {
        assert!(Json::parse("\"a\u{1}b\"").is_err());
        assert!(Json::parse("\"a\nb\"").is_err());
        assert!(Json::parse("\"a\tb\"").is_err());
        // escaped forms stay fine, and the writer always escapes them
        assert_eq!(Json::parse(r#""a\nb""#).unwrap().as_str().unwrap(), "a\nb");
        let v = Json::Str("a\u{1}b\n".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn usize_arr() {
        let v = Json::parse("[2, 64, 64]").unwrap();
        assert_eq!(v.usize_arr().unwrap(), vec![2, 64, 64]);
        assert!(Json::parse("[1.5]").unwrap().usize_arr().is_err());
    }
}
