//! Small statistics helpers used by eval/, report/ and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Minimum (`+inf` for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (`-inf` for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Mean squared error between two equal-length vectors.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn cosine_cases() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn mse_cases() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[0.0, 0.0], &[2.0, 2.0]), 4.0);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
