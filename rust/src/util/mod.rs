//! From-scratch infrastructure substrates.
//!
//! The build environment is fully offline with a minimal crate cache, so
//! the usual ecosystem crates (clap, serde_json, rand, rayon, criterion,
//! proptest) are implemented here in the small: a deterministic RNG, a
//! JSON codec, a CLI argument parser, a scoped-thread parallel map, a
//! stats helper, a criterion-style bench harness and a property-testing
//! loop. Each lives in its own module with its own tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threads;

use std::time::Instant;

/// Wall-clock a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple leveled stderr logger (no env_logger in the offline cache).
/// Level comes from `FAAR_LOG` (error|warn|info|debug), default info.
pub fn log_level() -> u8 {
    match std::env::var("FAAR_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    }
}

#[macro_export]
/// Log at info level to stderr (respects `FAAR_LOG`).
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
/// Log at debug level to stderr (visible with `FAAR_LOG=debug`).
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 3 {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
/// Log at warn level to stderr (respects `FAAR_LOG`).
macro_rules! warn {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 {
            eprintln!("[warn] {}", format!($($arg)*));
        }
    };
}
