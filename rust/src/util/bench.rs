//! Criterion-style micro-benchmark harness (offline environment).
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::new("formats");
//! b.bench("e4m3_encode_1M", || { ... });
//! b.finish();
//! ```
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean / p50 / p95 / throughput and writes a JSON record under
//! `results/bench/` so runs can be diffed across optimization iterations
//! (EXPERIMENTS.md §Perf).

use std::time::Instant;

use super::{json::Json, stats};

/// One bench group: collects measurements and writes
/// `results/bench/<group>.json` on [`Self::finish`].
pub struct Bench {
    group: String,
    records: Vec<Json>,
    /// target seconds per measurement
    pub target_time: f64,
    /// number of measurement samples
    pub samples: usize,
}

/// Timing summary for one benchmarked closure.
pub struct Report {
    /// bench name within the group
    pub name: String,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// median seconds per iteration
    pub p50_s: f64,
    /// p95 seconds per iteration
    pub p95_s: f64,
    /// iterations per measurement sample (auto-calibrated)
    pub iters: u64,
}

impl Bench {
    /// A bench group named `group` (FAAR_BENCH_FAST=1 slashes costs).
    pub fn new(group: &str) -> Self {
        // Keep default costs modest; FAAR_BENCH_FAST=1 slashes them for CI.
        let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
        Bench {
            group: group.to_string(),
            records: vec![],
            target_time: if fast { 0.05 } else { 0.5 },
            samples: if fast { 3 } else { 10 },
        }
    }

    /// Benchmark a closure; returns the mean seconds per iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Report {
        // warmup + calibration: find iters such that one sample ~ target_time
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_time / once).ceil() as u64).clamp(1, 1_000_000);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let rep = Report {
            name: name.to_string(),
            mean_s: stats::mean(&times),
            p50_s: stats::percentile(&times, 50.0),
            p95_s: stats::percentile(&times, 95.0),
            iters,
        };
        println!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters x {} samples)",
            format!("{}/{}", self.group, name),
            fmt_time(rep.mean_s),
            fmt_time(rep.p50_s),
            fmt_time(rep.p95_s),
            iters,
            self.samples,
        );
        self.records.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("mean_s", Json::Num(rep.mean_s)),
            ("p50_s", Json::Num(rep.p50_s)),
            ("p95_s", Json::Num(rep.p95_s)),
            ("iters", Json::Num(rep.iters as f64)),
        ]));
        rep
    }

    /// Benchmark with an item count for throughput reporting.
    pub fn bench_n<F: FnMut()>(&mut self, name: &str, n_items: u64, f: F) -> Report {
        let rep = self.bench(name, f);
        let per_sec = n_items as f64 / rep.mean_s;
        println!("{:<44} {:>16.3e} items/s", format!("{}/{} ⤷", self.group, name), per_sec);
        if let Some(Json::Obj(pairs)) = self.records.last_mut() {
            pairs.push(("items_per_s".into(), Json::Num(per_sec)));
        }
        rep
    }

    /// Write the JSON record and print the header.
    pub fn finish(self) {
        let dir = std::path::Path::new("results/bench");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.group));
        let doc = Json::obj(vec![
            ("group", Json::str(self.group.as_str())),
            ("benches", Json::Arr(self.records)),
        ]);
        if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
            eprintln!("[warn] could not write {}: {e}", path.display());
        } else {
            println!("→ wrote {}", path.display());
        }
    }
}

/// Human-readable seconds (`1.5 ms`, `370 ns`, ...).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("FAAR_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let rep = b.bench("noop_loop", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(rep.mean_s > 0.0);
        assert!(rep.iters >= 1);
    }

    #[test]
    fn fmt_times() {
        assert!(fmt_time(2.0).contains("s"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).contains("ns"));
    }
}
