//! Property-based testing helper (offline environment — no proptest).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen` from a deterministic per-name seed. On failure it
//! performs a simple halving shrink over the recorded seed list and
//! reports the seed so the case can be replayed exactly.

use super::rng::Rng;

/// Run a property over `cases` randomly generated inputs.
///
/// Panics (test failure) with the offending seed on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    // stable per-name seed so failures reproduce across runs
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  input: {input:?}"
            );
        }
    }
}

/// Like `check` but the property returns Result with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use super::Rng;

    /// `n` samples from N(0, std).
    pub fn f32_normal(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 0.0, std);
        v
    }

    /// Heavy-tailed samples (student-t-ish via normal ratio) — exercises
    /// the sparse end of the NVFP4 grid.
    pub fn f32_heavy(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let a = rng.normal() as f32;
                let b = (rng.normal() as f32).abs().max(0.3);
                a / b
            })
            .collect()
    }

    /// Finite f32 across magnitudes (log-uniform exponent), with zeros and
    /// exact halves sprinkled in.
    pub fn f32_wide(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.below(10) {
                0 => 0.0,
                1 => {
                    let k = rng.below(13) as i32 - 1; // exact node multiples
                    let node = [0.5f32, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0][rng.below(7)];
                    node * (2.0f32).powi(k)
                }
                _ => {
                    let e = rng.range_f64(-20.0, 10.0);
                    let m = rng.range_f64(1.0, 2.0);
                    let s = if rng.bernoulli(0.5) { -1.0 } else { 1.0 };
                    (s * m * 2f64.powf(e)) as f32
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check("abs_nonneg", 200, |r| r.normal(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always_false' failed")]
    fn fails_with_seed() {
        check("always_false", 10, |r| r.f64(), |_| false);
    }

    #[test]
    fn deterministic_gen() {
        let mut v1 = vec![];
        check("collect1", 5, |r| r.next_u64(), |x| {
            v1.push(*x);
            true
        });
        let mut v2 = vec![];
        check("collect1", 5, |r| r.next_u64(), |x| {
            v2.push(*x);
            true
        });
        assert_eq!(v1, v2);
    }
}
