//! Scoped-thread parallel map (offline environment — no rayon).
//!
//! Used by the pure-rust hot paths (GPTQ column solves across layers,
//! stochastic-rounding trials, corpus sharding). XLA executions stay on
//! the main thread — the PJRT CPU client parallelizes internally.

/// Parallel map over items with a bounded worker count. Preserves order.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return vec![];
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let slots_mx = std::sync::Mutex::new(&mut slots);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let item = { queue.lock().unwrap().pop() };
                match item {
                    Some((i, x)) => {
                        let r = f(x);
                        slots_mx.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker died")).collect()
}

/// Default worker count: available parallelism minus one (leave a core
/// for the coordinator), at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

/// Spawn a detached thread with a name (shows up in panics / debuggers).
/// Used for the serving engine's per-connection reader/writer threads.
pub fn spawn_named<F>(name: String, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name)
        .spawn(f)
        .expect("thread spawn failed")
}

/// Completion barrier for detached threads (crossbeam-style): every clone
/// registers a participant, dropping it deregisters, and [`WaitGroup::wait`]
/// blocks until every other participant is gone. The serving engine hands a
/// clone to each connection thread and waits on shutdown so responses in
/// flight are flushed before `serve` returns.
pub struct WaitGroup {
    inner: std::sync::Arc<WgInner>,
}

struct WgInner {
    count: std::sync::Mutex<usize>,
    cv: std::sync::Condvar,
}

impl Default for WaitGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl WaitGroup {
    /// A group with one participant (the creating handle).
    pub fn new() -> WaitGroup {
        WaitGroup {
            inner: std::sync::Arc::new(WgInner {
                count: std::sync::Mutex::new(1),
                cv: std::sync::Condvar::new(),
            }),
        }
    }

    /// Block until this handle is the only participant left.
    pub fn wait(self) {
        let mut n = self.inner.count.lock().unwrap();
        while *n > 1 {
            n = self.inner.cv.wait(n).unwrap();
        }
    }
}

impl Clone for WaitGroup {
    fn clone(&self) -> WaitGroup {
        *self.inner.count.lock().unwrap() += 1;
        WaitGroup { inner: self.inner.clone() }
    }
}

impl Drop for WaitGroup {
    fn drop(&mut self) {
        let mut n = self.inner.count.lock().unwrap();
        *n -= 1;
        if *n <= 1 {
            self.inner.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(par_map(vec![7], 4, |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        par_map((0..16).collect(), 4, |_: i32| {
            let l = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(l, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) > 1);
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(par_map(vec![1, 2], 64, |x: i32| x), vec![1, 2]);
    }

    #[test]
    fn waitgroup_waits_for_all() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let wg = WaitGroup::new();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let child = wg.clone();
            let done = done.clone();
            spawn_named("wg-test".into(), move || {
                std::thread::sleep(std::time::Duration::from_millis(10));
                done.fetch_add(1, Ordering::SeqCst);
                drop(child);
            });
        }
        wg.wait();
        assert_eq!(done.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn waitgroup_no_children_returns() {
        WaitGroup::new().wait();
    }
}
