//! Zero-shot probe suites (Table 5 analogues).
//!
//! Each probe is a (prompt, candidates, answer) triple scored exactly like
//! the LM Evaluation Harness scores multiple-choice tasks: the model
//! ranks candidate continuations by total (or length-normalized)
//! log-likelihood given the prompt. The generators control difficulty:
//!
//! * `BoolQ`      — 2-way: is the shown continuation process-consistent?
//! * `ArcEasy`    — 4-way, distractors drawn from *unlikely* successors
//! * `ArcChallenge` — 4-way, distractors drawn from mid-probability
//!   successors (much closer to the gold continuation)
//! * `HellaSwag`  — 4-way with multi-token continuations, scored with
//!   length normalization
//!
//! Difficulty ordering (Easy > Challenge) and the BF16 > quantized gap
//! emerge from the same statistics the paper's tasks rely on.

use crate::util::rng::Rng;

use super::corpus::Corpus;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// The four zero-shot probe families (Table 5 analogues).
pub enum TaskKind {
    /// 2-way process-consistency judgment.
    BoolQ,
    /// 4-way with unlikely-successor distractors.
    ArcEasy,
    /// 4-way with near-gold distractors.
    ArcChallenge,
    /// 4-way multi-token continuations, length-normalized.
    HellaSwag,
}

impl TaskKind {
    /// Canonical task name (table row labels).
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::BoolQ => "boolq",
            TaskKind::ArcEasy => "arc-e",
            TaskKind::ArcChallenge => "arc-c",
            TaskKind::HellaSwag => "hellaswag",
        }
    }

    /// Every task, in table order.
    pub fn all() -> [TaskKind; 4] {
        [TaskKind::BoolQ, TaskKind::ArcEasy, TaskKind::ArcChallenge, TaskKind::HellaSwag]
    }

    /// LM-harness-style length normalization (acc_norm) for HellaSwag.
    pub fn length_normalized(&self) -> bool {
        matches!(self, TaskKind::HellaSwag)
    }
}

#[derive(Clone, Debug)]
/// One multiple-choice probe.
pub struct Probe {
    /// conditioning prefix
    pub prompt: Vec<i32>,
    /// candidate continuations
    pub candidates: Vec<Vec<i32>>,
    /// index of the gold candidate
    pub answer: usize,
}

/// A generated probe set for one task.
pub struct TaskSuite {
    /// which task family
    pub kind: TaskKind,
    /// the probes
    pub probes: Vec<Probe>,
}

impl TaskSuite {
    /// Generate a deterministic suite of `n` probes.
    pub fn generate(kind: TaskKind, corpus: &Corpus, n: usize, prompt_len: usize, seed: u64) -> TaskSuite {
        let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0xABCD_EF12));
        let probes = (0..n)
            .map(|i| match kind {
                TaskKind::BoolQ => boolq(corpus, prompt_len, &mut rng, i),
                TaskKind::ArcEasy => arc(corpus, prompt_len, &mut rng, i, true),
                TaskKind::ArcChallenge => arc(corpus, prompt_len, &mut rng, i, false),
                TaskKind::HellaSwag => hellaswag(corpus, prompt_len, &mut rng, i),
            })
            .collect();
        TaskSuite { kind, probes }
    }
}

fn prompt_for(corpus: &Corpus, len: usize, idx: usize, salt: u64) -> Vec<i32> {
    corpus.generate(len, 0xAAAA_0000u64 ^ salt ^ (idx as u64) << 8)
}

fn tail2(prompt: &[i32]) -> (u32, u32) {
    let n = prompt.len();
    assert!(n >= 2, "probes need prompts of at least 2 tokens");
    (prompt[n - 2] as u32, prompt[n - 1] as u32)
}

/// Gold continuation: greedy successors of the prompt tail (order-2).
fn gold_continuation(corpus: &Corpus, prompt: &[i32], len: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    let (mut prev2, mut prev) = tail2(prompt);
    for _ in 0..len {
        let nxt = corpus.argmax_next(prev2, prev);
        out.push(nxt as i32);
        prev2 = prev;
        prev = nxt;
    }
    out
}

/// A continuation of unlikely tokens.
fn bad_continuation(corpus: &Corpus, prompt: &[i32], len: usize, rng: &mut Rng) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    let (mut prev2, mut prev) = tail2(prompt);
    for _ in 0..len {
        let nxt = corpus.unlikely_next(prev2, prev, rng);
        out.push(nxt as i32);
        prev2 = prev;
        prev = nxt;
    }
    out
}

/// A "plausible but wrong" continuation: the 2nd/3rd-ranked successor
/// chain (mid probability — the hard distractor).
fn near_continuation(corpus: &Corpus, prompt: &[i32], len: usize, rank: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    let (mut prev2, mut prev) = tail2(prompt);
    for _ in 0..len {
        let probs = corpus.next_probs(prev2, prev);
        let mut order: Vec<usize> = (0..probs.len()).collect();
        order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let nxt = order[rank.min(order.len() - 1)] as u32;
        out.push(nxt as i32);
        prev2 = prev;
        prev = nxt;
    }
    out
}

fn boolq(corpus: &Corpus, plen: usize, rng: &mut Rng, idx: usize) -> Probe {
    let prompt = prompt_for(corpus, plen, idx, 0xB001);
    let good = gold_continuation(corpus, &prompt, 2);
    let bad = bad_continuation(corpus, &prompt, 2, rng);
    // randomize answer position
    if rng.bernoulli(0.5) {
        Probe { prompt, candidates: vec![good, bad], answer: 0 }
    } else {
        Probe { prompt, candidates: vec![bad, good], answer: 1 }
    }
}

fn arc(corpus: &Corpus, plen: usize, rng: &mut Rng, idx: usize, easy: bool) -> Probe {
    let prompt = prompt_for(corpus, plen, idx, if easy { 0xA8CE } else { 0xA8CC });
    let good = gold_continuation(corpus, &prompt, 3);
    let mut candidates = vec![good];
    for d in 0..3 {
        let distractor = if easy {
            bad_continuation(corpus, &prompt, 3, rng)
        } else {
            near_continuation(corpus, &prompt, 3, d + 1)
        };
        candidates.push(distractor);
    }
    let answer = rng.below(4);
    candidates.swap(0, answer);
    Probe { prompt, candidates, answer }
}

fn hellaswag(corpus: &Corpus, plen: usize, rng: &mut Rng, idx: usize) -> Probe {
    let prompt = prompt_for(corpus, plen, idx, 0x4E11);
    // variable-length continuations: length normalization matters
    let good = gold_continuation(corpus, &prompt, 6);
    let mut candidates = vec![good];
    for d in 0..3 {
        let len = 4 + (d * 2); // 4, 6, 8 — different lengths
        candidates.push(bad_continuation(corpus, &prompt, len, rng));
    }
    let answer = rng.below(4);
    candidates.swap(0, answer);
    Probe { prompt, candidates, answer }
}

/// Exact-process scorer: log-likelihood of a candidate continuation under
/// the *generative process itself* (upper bound on any model). Used by
/// tests to verify the gold answer is actually the most likely.
pub fn process_loglik(corpus: &Corpus, prompt: &[i32], cont: &[i32]) -> f64 {
    let (mut prev2, mut prev) = tail2(prompt);
    let mut ll = 0.0;
    for &t in cont {
        let p = corpus.next_probs(prev2, prev);
        ll += p[t as usize].max(1e-12).ln();
        prev2 = prev;
        prev = t as u32;
    }
    ll
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::by_name("synthwiki", 128).unwrap()
    }

    #[test]
    fn deterministic_suites() {
        let c = corpus();
        let a = TaskSuite::generate(TaskKind::ArcEasy, &c, 10, 16, 1);
        let b = TaskSuite::generate(TaskKind::ArcEasy, &c, 10, 16, 1);
        assert_eq!(a.probes.len(), 10);
        for (p, q) in a.probes.iter().zip(&b.probes) {
            assert_eq!(p.prompt, q.prompt);
            assert_eq!(p.answer, q.answer);
        }
    }

    #[test]
    fn gold_answer_is_process_optimal() {
        let c = corpus();
        for kind in [TaskKind::BoolQ, TaskKind::ArcEasy] {
            let suite = TaskSuite::generate(kind, &c, 30, 16, 2);
            let mut correct = 0;
            for p in &suite.probes {
                let scores: Vec<f64> = p
                    .candidates
                    .iter()
                    .map(|cand| process_loglik(&c, &p.prompt, cand) / cand.len() as f64)
                    .collect();
                let best = scores
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if best == p.answer {
                    correct += 1;
                }
            }
            assert!(
                correct >= 28,
                "{}: process scorer only got {correct}/30",
                kind.name()
            );
        }
    }

    #[test]
    fn challenge_harder_than_easy() {
        // margin between gold and best distractor must be smaller for Arc-C
        let c = corpus();
        let margin = |kind| {
            let suite = TaskSuite::generate(kind, &c, 40, 16, 3);
            let mut total = 0.0;
            for p in &suite.probes {
                let scores: Vec<f64> = p
                    .candidates
                    .iter()
                    .map(|cand| process_loglik(&c, &p.prompt, cand) / cand.len() as f64)
                    .collect();
                let gold = scores[p.answer];
                let best_other = scores
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != p.answer)
                    .map(|(_, &s)| s)
                    .fold(f64::NEG_INFINITY, f64::max);
                total += gold - best_other;
            }
            total / 40.0
        };
        let easy = margin(TaskKind::ArcEasy);
        let hard = margin(TaskKind::ArcChallenge);
        assert!(hard < easy, "challenge margin {hard} not below easy {easy}");
    }

    #[test]
    fn answers_distributed() {
        let c = corpus();
        let suite = TaskSuite::generate(TaskKind::ArcEasy, &c, 60, 16, 4);
        let mut seen = [0usize; 4];
        for p in &suite.probes {
            seen[p.answer] += 1;
        }
        assert!(seen.iter().all(|&s| s > 3), "answer positions skewed: {seen:?}");
    }

    #[test]
    fn hellaswag_lengths_vary() {
        let c = corpus();
        let suite = TaskSuite::generate(TaskKind::HellaSwag, &c, 5, 16, 5);
        for p in &suite.probes {
            let lens: Vec<usize> = p.candidates.iter().map(|c| c.len()).collect();
            let min = *lens.iter().min().unwrap();
            let max = *lens.iter().max().unwrap();
            assert!(max > min, "lengths should differ: {lens:?}");
        }
        assert!(TaskKind::HellaSwag.length_normalized());
        assert!(!TaskKind::ArcEasy.length_normalized());
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        for kind in TaskKind::all() {
            let suite = TaskSuite::generate(kind, &c, 10, 16, 6);
            for p in &suite.probes {
                for &t in p.prompt.iter().chain(p.candidates.iter().flatten()) {
                    assert!((0..128).contains(&t));
                }
            }
        }
    }
}
