//! Word-level tokenizer over generated pseudo-words.
//!
//! Gives the synthetic corpus a text surface so the serving example
//! exposes a real encode → generate → decode API. Pseudo-words are
//! deterministic CV-syllable strings ("ba", "kuto", "miresa", ...), unique
//! per token id; unknown words map to token 0.

use std::collections::HashMap;

const CONSONANTS: [&str; 12] = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t"];
const VOWELS: [&str; 5] = ["a", "e", "i", "o", "u"];

/// Word-level tokenizer over deterministic pseudo-words, one unique
/// word per token id.
pub struct Tokenizer {
    words: Vec<String>,
    index: HashMap<String, i32>,
}

impl Tokenizer {
    /// Build the vocabulary of `vocab` pseudo-words.
    pub fn new(vocab: usize) -> Tokenizer {
        let mut words = Vec::with_capacity(vocab);
        let mut index = HashMap::with_capacity(vocab);
        for id in 0..vocab {
            let w = Self::word_for(id);
            index.insert(w.clone(), id as i32);
            words.push(w);
        }
        Tokenizer { words, index }
    }

    /// Deterministic unique pseudo-word for a token id: base-60 syllables.
    fn word_for(id: usize) -> String {
        let mut s = String::new();
        let mut x = id;
        loop {
            let syl = x % 60;
            s.push_str(CONSONANTS[syl / 5]);
            s.push_str(VOWELS[syl % 5]);
            x /= 60;
            if x == 0 {
                break;
            }
            x -= 1; // bijective numeration: no word is a prefix-collision
        }
        s
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.words.len()
    }

    /// Token ids → space-joined pseudo-words (`?` for out-of-range).
    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| self.words.get(t as usize).map(|s| s.as_str()).unwrap_or("?"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Whitespace-split words → token ids (unknown words map to 0).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| self.index.get(w).copied().unwrap_or(0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_unique() {
        let t = Tokenizer::new(1024);
        let mut seen = std::collections::HashSet::new();
        for w in &t.words {
            assert!(seen.insert(w.clone()), "duplicate word {w}");
        }
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new(512);
        let toks = vec![0, 1, 60, 511, 17];
        let text = t.decode(&toks);
        assert_eq!(t.encode(&text), toks);
    }

    #[test]
    fn unknown_maps_to_zero() {
        let t = Tokenizer::new(64);
        assert_eq!(t.encode("zzzz qqq"), vec![0, 0]);
    }

    #[test]
    fn words_are_pronounceable_cv() {
        let t = Tokenizer::new(256);
        for w in &t.words {
            assert!(w.len() % 2 == 0 && !w.is_empty());
        }
    }
}
