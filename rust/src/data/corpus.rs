//! Zipfian–Markov synthetic corpora.
//!
//! Each corpus is defined by a deterministic generative process over the
//! model's token vocabulary:
//!
//! * a Zipfian unigram prior (natural-language-like frequency skew),
//! * **second-order** sparse transition structure — each `(prev2, prev)`
//!   context has a small preferred-successor set receiving most of the
//!   probability mass. Order 2 matters: a model must actually use
//!   attention (not just the last-token embedding) to reach the floor,
//!   which loads its capacity and makes it quantization-sensitive,
//! * multi-token *motifs* (frequent phrases) injected at random positions
//!   for longer-range structure,
//! * a `noise` knob mixing in uniform sampling.
//!
//! `synthwiki` (noise 0.10) is low-entropy/structured; `synthc4`
//! (noise 0.35) is high-entropy. Both SHARE the transition structure
//! (like WikiText and C4 share English) and differ in noise + sampling
//! streams — mirroring the Wiki-vs-C4 contrast in the paper's tables.

use crate::util::rng::Rng;

const SUCC: usize = 4;
const N_MOTIFS: usize = 24;
const MOTIF_LEN: usize = 5;

#[derive(Clone, Debug)]
/// Generative parameters of one named corpus.
pub struct CorpusSpec {
    /// corpus name (`synthwiki` / `synthc4`)
    pub name: &'static str,
    /// uniform-sampling mix-in fraction
    pub noise: f64,
    /// probability of starting a motif at any position
    pub motif_rate: f64,
    /// structure seed — shared between corpora
    pub seed: u64,
    /// distinct sampling-stream salt per corpus
    pub stream_salt: u64,
}

impl CorpusSpec {
    /// Spec of a named corpus, if known.
    pub fn by_name(name: &str) -> Option<CorpusSpec> {
        match name {
            "synthwiki" => Some(CorpusSpec {
                name: "synthwiki",
                noise: 0.05,
                motif_rate: 0.08,
                seed: 0x5157_1111,
                stream_salt: 0x11,
            }),
            "synthc4" => Some(CorpusSpec {
                name: "synthc4",
                noise: 0.30,
                motif_rate: 0.03,
                seed: 0x5157_1111,
                stream_salt: 0xC4,
            }),
            _ => None,
        }
    }
}

/// A generative corpus over `vocab` tokens with order-2 context.
pub struct Corpus {
    /// the generative parameters
    pub spec: CorpusSpec,
    /// token vocabulary size
    pub vocab: usize,
    /// preferred successors per (prev2, prev) context, [vocab*vocab]
    succ: Vec<[u32; SUCC]>,
    /// unnormalized successor weights (Zipf-ish within the set)
    succ_w: [f64; SUCC],
    /// unigram weights for noise draws
    unigram: Vec<f64>,
    motifs: Vec<[u32; MOTIF_LEN]>,
}

impl Corpus {
    /// Build the corpus structure (successor sets, motifs) for `vocab`.
    pub fn new(spec: CorpusSpec, vocab: usize) -> Corpus {
        assert!(vocab >= 16);
        let mut rng = Rng::new(spec.seed);
        // Zipf unigram: w_i = 1 / (rank_i + 2)
        let mut ranks: Vec<usize> = (0..vocab).collect();
        rng.shuffle(&mut ranks);
        let mut unigram = vec![0.0f64; vocab];
        for (tok, &rank) in ranks.iter().enumerate() {
            unigram[tok] = 1.0 / (rank as f64 + 2.0);
        }
        // order-2 successor sets. Sampling vocab^2 categorical draws from
        // the Zipf prior would be slow for vocab=1024; instead mix a fast
        // hash of the context with a frequency-biased token pool.
        let pool: Vec<u32> = {
            // frequency-biased pool: token i appears ~unigram-proportional
            let mut p = Vec::with_capacity(vocab * 4);
            for (tok, &rank) in ranks.iter().enumerate() {
                let copies = (4 * vocab / (rank + 2)).clamp(1, 64);
                for _ in 0..copies {
                    p.push(tok as u32);
                }
            }
            rng.shuffle(&mut p);
            p
        };
        let mut succ = Vec::with_capacity(vocab * vocab);
        let mut h = spec.seed | 1;
        for _ctx in 0..vocab * vocab {
            let mut s = [0u32; SUCC];
            for slot in s.iter_mut() {
                // splitmix-style hash walk — deterministic, structure-rich
                h = h.wrapping_add(0x9E3779B97F4A7C15);
                let mut z = h;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                z ^= z >> 31;
                *slot = pool[(z as usize) % pool.len()];
            }
            succ.push(s);
        }
        let motifs = (0..N_MOTIFS)
            .map(|_| {
                let mut m = [0u32; MOTIF_LEN];
                for slot in m.iter_mut() {
                    *slot = rng.below(vocab) as u32;
                }
                m
            })
            .collect();
        Corpus {
            spec,
            vocab,
            succ,
            succ_w: [12.0, 2.0, 1.0, 0.5],
            unigram,
            motifs,
        }
    }

    /// Build a named corpus over `vocab` tokens, if known.
    pub fn by_name(name: &str, vocab: usize) -> Option<Corpus> {
        CorpusSpec::by_name(name).map(|s| Corpus::new(s, vocab))
    }

    #[inline]
    fn ctx(&self, prev2: u32, prev: u32) -> usize {
        prev2 as usize * self.vocab + prev as usize
    }

    /// Sample the next token given the two previous ones.
    pub fn next_token(&self, prev2: u32, prev: u32, rng: &mut Rng) -> u32 {
        if rng.bernoulli(self.spec.noise) {
            rng.categorical(&self.unigram) as u32
        } else {
            let set = &self.succ[self.ctx(prev2, prev)];
            set[rng.categorical(&self.succ_w)]
        }
    }

    /// Generate a token stream of length `len` from a stream seed.
    pub fn generate(&self, len: usize, stream_seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(
            self.spec.seed
                ^ self.spec.stream_salt.wrapping_mul(0x517C_C1B7_2722_0A95)
                ^ stream_seed.wrapping_mul(0x9E3779B97F4A7C15),
        );
        let mut out = Vec::with_capacity(len);
        let mut prev2 = rng.below(self.vocab) as u32;
        let mut prev = rng.below(self.vocab) as u32;
        let mut motif: Option<(usize, usize)> = None; // (motif idx, pos)
        while out.len() < len {
            if let Some((mi, pos)) = motif {
                let tok = self.motifs[mi][pos];
                out.push(tok as i32);
                prev2 = prev;
                prev = tok;
                motif = if pos + 1 < MOTIF_LEN { Some((mi, pos + 1)) } else { None };
                continue;
            }
            if rng.bernoulli(self.spec.motif_rate) {
                motif = Some((rng.below(N_MOTIFS), 0));
                continue;
            }
            let tok = self.next_token(prev2, prev, &mut rng);
            out.push(tok as i32);
            prev2 = prev;
            prev = tok;
        }
        out
    }

    /// Conditional distribution p(next | prev2, prev) under the pure
    /// process (ignoring motifs) — used by the probe generators.
    pub fn next_probs(&self, prev2: u32, prev: u32) -> Vec<f64> {
        let mut p = vec![0.0f64; self.vocab];
        let uni_total: f64 = self.unigram.iter().sum();
        for (tok, &w) in self.unigram.iter().enumerate() {
            p[tok] += self.spec.noise * w / uni_total;
        }
        let sw_total: f64 = self.succ_w.iter().sum();
        for (slot, &tok) in self.succ[self.ctx(prev2, prev)].iter().enumerate() {
            p[tok as usize] += (1.0 - self.spec.noise) * self.succ_w[slot] / sw_total;
        }
        p
    }

    /// Most likely successor of a context.
    pub fn argmax_next(&self, prev2: u32, prev: u32) -> u32 {
        self.succ[self.ctx(prev2, prev)][0]
    }

    /// A token that is *unlikely* after the context (for distractors).
    pub fn unlikely_next(&self, prev2: u32, prev: u32, rng: &mut Rng) -> u32 {
        let set = self.succ[self.ctx(prev2, prev)];
        loop {
            let cand = rng.below(self.vocab) as u32;
            if !set.contains(&cand) {
                return cand;
            }
        }
    }

    /// Empirical per-token entropy (bits) of the generative process,
    /// estimated by sampling — documents the corpus difficulty gap.
    pub fn empirical_entropy_bits(&self, samples: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        let (mut prev2, mut prev) = (0u32, 1u32);
        for _ in 0..samples {
            let p = self.next_probs(prev2, prev);
            let h: f64 = p.iter().filter(|&&x| x > 0.0).map(|&x| -x * x.log2()).sum();
            acc += h;
            let nxt = self.next_token(prev2, prev, &mut rng);
            prev2 = prev;
            prev = nxt;
        }
        acc / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let c = Corpus::by_name("synthwiki", 256).unwrap();
        assert_eq!(c.generate(100, 1), c.generate(100, 1));
        assert_ne!(c.generate(100, 1), c.generate(100, 2));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::by_name("synthc4", 128).unwrap();
        for &t in &c.generate(5000, 3) {
            assert!((0..128).contains(&t));
        }
    }

    #[test]
    fn corpora_share_structure() {
        let w = Corpus::by_name("synthwiki", 128).unwrap();
        let c = Corpus::by_name("synthc4", 128).unwrap();
        for ctx in [(0u32, 5u32), (17, 3), (99, 99)] {
            assert_eq!(w.argmax_next(ctx.0, ctx.1), c.argmax_next(ctx.0, ctx.1));
        }
        // but sampling streams differ
        assert_ne!(w.generate(50, 1), c.generate(50, 1));
    }

    #[test]
    fn wiki_lower_entropy_than_c4() {
        let w = Corpus::by_name("synthwiki", 256).unwrap();
        let c = Corpus::by_name("synthc4", 256).unwrap();
        let hw = w.empirical_entropy_bits(2000, 5);
        let hc = c.empirical_entropy_bits(2000, 5);
        assert!(
            hw + 0.5 < hc,
            "synthwiki entropy {hw:.2} not clearly below synthc4 {hc:.2}"
        );
    }

    #[test]
    fn second_order_structure_matters() {
        // the same `prev` with different `prev2` must usually lead to a
        // different preferred successor — this is what forces the model
        // to use attention over both positions
        let c = Corpus::by_name("synthwiki", 256).unwrap();
        let mut differs = 0;
        let n = 200;
        for i in 0..n {
            let prev = (i % 256) as u32;
            let a = c.argmax_next(3, prev);
            let b = c.argmax_next(200, prev);
            if a != b {
                differs += 1;
            }
        }
        assert!(differs > n * 3 / 4, "only {differs}/{n} contexts differ by prev2");
    }

    #[test]
    fn structure_is_learnable() {
        let c = Corpus::by_name("synthwiki", 256).unwrap();
        let mut rng = Rng::new(9);
        let mut hits = 0;
        let n = 5000;
        for i in 0..n {
            let prev2 = (i * 7 % 256) as u32;
            let prev = (i % 256) as u32;
            if c.next_token(prev2, prev, &mut rng) == c.argmax_next(prev2, prev) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!(rate > 0.2, "argmax rate {rate} ~ chance");
    }

    #[test]
    fn next_probs_normalized() {
        let c = Corpus::by_name("synthwiki", 64).unwrap();
        for ctx in [(0u32, 0u32), (5, 9), (63, 1)] {
            let p = c.next_probs(ctx.0, ctx.1);
            let total: f64 = p.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn unlikely_avoids_successors() {
        let c = Corpus::by_name("synthwiki", 64).unwrap();
        let mut rng = Rng::new(11);
        for prev in 0..64u32 {
            let u = c.unlikely_next(7, prev, &mut rng);
            assert!(!c.succ[c.ctx(7, prev)].contains(&u));
        }
    }

    #[test]
    fn unknown_corpus() {
        assert!(Corpus::by_name("wikitext2", 64).is_none());
    }
}
