//! Batch streams over a corpus, with disjoint train / calibration / eval
//! RNG streams so evaluation never sees training data.

use crate::runtime::Value;

use super::corpus::Corpus;

/// Stream role → disjoint seed space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Pretraining stream.
    Train,
    /// Calibration stream.
    Calib,
    /// Evaluation stream.
    Eval,
}

impl Split {
    fn base(self) -> u64 {
        match self {
            Split::Train => 0x1000_0000_0000,
            Split::Calib => 0x2000_0000_0000,
            Split::Eval => 0x3000_0000_0000,
        }
    }
}

/// Deterministic batch producer: batch `i` of a (corpus, split, seed)
/// triple is always the same tokens.
pub struct Batcher<'c> {
    /// the generative corpus
    pub corpus: &'c Corpus,
    /// which disjoint stream
    pub split: Split,
    /// rows per batch
    pub batch: usize,
    /// tokens per row INCLUDING the shifted target (T+1 for training/eval)
    pub row_len: usize,
    /// run seed, mixed into every row's stream
    pub seed: u64,
    next: usize,
}

impl<'c> Batcher<'c> {
    /// A batcher over (corpus, split, seed), starting at batch 0.
    pub fn new(corpus: &'c Corpus, split: Split, batch: usize, row_len: usize, seed: u64) -> Self {
        Batcher { corpus, split, batch, row_len, seed, next: 0 }
    }

    /// The i-th batch as a flat i32 Value of shape [batch, row_len].
    pub fn batch_at(&self, i: usize) -> Value {
        let mut data = Vec::with_capacity(self.batch * self.row_len);
        for r in 0..self.batch {
            let stream = self.split.base()
                ^ self.seed.wrapping_mul(0x9E37_79B9)
                ^ ((i * self.batch + r) as u64).wrapping_mul(0x517C_C1B7_2722_0A95);
            data.extend(self.corpus.generate(self.row_len, stream));
        }
        Value::I32(data, vec![self.batch, self.row_len])
    }

    /// Sequential iteration.
    pub fn next_batch(&mut self) -> Value {
        let b = self.batch_at(self.next);
        self.next += 1;
        b
    }

    /// Rewind sequential iteration to batch 0.
    pub fn reset(&mut self) {
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Corpus;

    fn toks(v: &Value) -> &[i32] {
        match v {
            Value::I32(d, _) => d,
            _ => panic!(),
        }
    }

    #[test]
    fn shapes_and_determinism() {
        let c = Corpus::by_name("synthwiki", 128).unwrap();
        let b = Batcher::new(&c, Split::Train, 4, 65, 42);
        let x = b.batch_at(0);
        assert_eq!(x.shape(), &[4, 65]);
        assert_eq!(toks(&b.batch_at(3)), toks(&b.batch_at(3)));
        assert_ne!(toks(&b.batch_at(3)), toks(&b.batch_at(4)));
    }

    #[test]
    fn splits_disjoint() {
        let c = Corpus::by_name("synthwiki", 128).unwrap();
        let tr = Batcher::new(&c, Split::Train, 2, 33, 1).batch_at(0);
        let ev = Batcher::new(&c, Split::Eval, 2, 33, 1).batch_at(0);
        assert_ne!(toks(&tr), toks(&ev));
    }

    #[test]
    fn sequential_advances() {
        let c = Corpus::by_name("synthc4", 128).unwrap();
        let mut b = Batcher::new(&c, Split::Calib, 2, 17, 7);
        let x0 = b.next_batch();
        let x1 = b.next_batch();
        assert_ne!(toks(&x0), toks(&x1));
        b.reset();
        assert_eq!(toks(&b.next_batch()), toks(&x0));
    }

    #[test]
    fn rows_differ_within_batch() {
        let c = Corpus::by_name("synthwiki", 128).unwrap();
        let b = Batcher::new(&c, Split::Train, 2, 50, 3);
        let x = b.batch_at(0);
        let d = toks(&x);
        assert_ne!(&d[..50], &d[50..]);
    }
}
