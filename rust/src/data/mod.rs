//! Synthetic data substrate.
//!
//! The paper evaluates on WikiText-2 / C4 and four zero-shot suites; none
//! of those are available offline, so we build generative stand-ins with
//! the statistical properties the experiments depend on (DESIGN.md §2):
//!
//! * [`corpus`] — Zipfian–Markov token streams at two entropy levels
//!   (`synthwiki` structured / `synthc4` noisy), deterministic by seed.
//! * [`tokenizer`] — a word-level text codec over pseudo-words, used by
//!   the serving example so the request path looks like a real LM API.
//! * [`batcher`] — train/eval batch streams with disjoint RNG streams.
//! * [`tasks`] — zero-shot probe generators (BoolQ/Arc-E/Arc-C/HellaSwag
//!   analogues) scored by candidate log-likelihood, LM-harness style.

pub mod batcher;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use batcher::Batcher;
pub use corpus::Corpus;
pub use tasks::{Probe, TaskKind, TaskSuite};
pub use tokenizer::Tokenizer;
