//! Evaluation harness: perplexity, last-hidden cosine similarity, and
//! zero-shot probe accuracy — the three metric families of Tables 3/4/5.
//!
//! All metrics run through the `lm_fwd` / `lm_fwd_aq` artifacts, which
//! return per-position NLL (for PPL and likelihood scoring) and the last
//! hidden state (for cosine). Quantized models are evaluated W4A4
//! (activation fake-quant in-graph) unless configured otherwise.

use anyhow::Result;

use crate::data::{batcher::Split, tasks::TaskSuite, Batcher, Corpus};
use crate::runtime::{Runtime, Value};
use crate::train::ParamSource;
use crate::util::stats;

/// Which forward graph to use for a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdMode {
    /// full-precision reference (no activation quant)
    Fp,
    /// W4A4: weights are already fake-quantized tensors; activations are
    /// RTN-quantized inside the graph
    ActQuant,
}

impl FwdMode {
    fn artifact(&self) -> &'static str {
        match self {
            FwdMode::Fp => "lm_fwd",
            FwdMode::ActQuant => "lm_fwd_aq",
        }
    }
}

/// Run one forward batch; returns (nll [B*T], last_hidden flat).
fn fwd_batch(
    rt: &Runtime,
    params: &dyn ParamSource,
    tokens: Value,
    mode: FwdMode,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut args = params.values()?;
    args.push(tokens);
    let out = rt.exec(mode.artifact(), &args)?;
    let nll = out[0].as_tensor()?.data.clone();
    let hid = out[1].as_tensor()?.data.clone();
    Ok((nll, hid))
}

/// Word perplexity over `n_batches` eval batches: exp(mean NLL).
pub fn perplexity(
    rt: &Runtime,
    params: &dyn ParamSource,
    corpus: &Corpus,
    mode: FwdMode,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = rt.config();
    let batcher = Batcher::new(corpus, Split::Eval, cfg.eval_batch, cfg.seq_len + 1, seed);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..n_batches {
        let (nll, _) = fwd_batch(rt, params, batcher.batch_at(b), mode)?;
        total += nll.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.len();
    }
    Ok((total / count as f64).exp())
}

/// Mean cosine similarity between last hidden states of a model and the
/// full-precision reference, over eval batches (Table 4, reported in %).
pub fn hidden_cosine(
    rt: &Runtime,
    fp_params: &dyn ParamSource,
    q_params: &dyn ParamSource,
    corpus: &Corpus,
    q_mode: FwdMode,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = rt.config();
    let batcher = Batcher::new(corpus, Split::Eval, cfg.eval_batch, cfg.seq_len + 1, seed);
    let mut cs = Vec::with_capacity(n_batches);
    for b in 0..n_batches {
        let tokens = batcher.batch_at(b);
        let (_, h_fp) = fwd_batch(rt, fp_params, tokens.clone(), FwdMode::Fp)?;
        let (_, h_q) = fwd_batch(rt, q_params, tokens, q_mode)?;
        cs.push(stats::cosine(&h_fp, &h_q));
    }
    Ok(stats::mean(&cs))
}

/// Zero-shot accuracy on a probe suite (LM-harness scoring).
///
/// Every (probe, candidate) pair becomes one row: prompt ++ candidate,
/// padded to the graph's fixed sequence length; the candidate's
/// log-likelihood is the summed -NLL over its token positions.
pub fn task_accuracy(
    rt: &Runtime,
    params: &dyn ParamSource,
    suite: &TaskSuite,
    mode: FwdMode,
) -> Result<f64> {
    let cfg = rt.config();
    let t_plus1 = cfg.seq_len + 1;
    let b = cfg.eval_batch;

    // flatten (probe, candidate) pairs into rows
    struct RowRef {
        probe: usize,
        cand: usize,
        start: usize, // first candidate token index within the row
        len: usize,
    }
    let mut rows: Vec<(Vec<i32>, RowRef)> = vec![];
    for (pi, probe) in suite.probes.iter().enumerate() {
        for (ci, cand) in probe.candidates.iter().enumerate() {
            let mut seq = probe.prompt.clone();
            let start = seq.len();
            seq.extend_from_slice(cand);
            assert!(
                seq.len() <= t_plus1,
                "probe sequence {} exceeds context {}",
                seq.len(),
                t_plus1
            );
            seq.resize(t_plus1, 0);
            rows.push((seq, RowRef { probe: pi, cand: ci, start, len: cand.len() }));
        }
    }

    // score rows batch by batch
    let mut scores: Vec<Vec<f64>> =
        suite.probes.iter().map(|p| vec![0.0; p.candidates.len()]).collect();
    for chunk in rows.chunks(b) {
        let mut data = Vec::with_capacity(b * t_plus1);
        for (seq, _) in chunk {
            data.extend_from_slice(seq);
        }
        // pad the batch with copies of the first row (ignored)
        for _ in chunk.len()..b {
            data.extend_from_slice(&chunk[0].0);
        }
        let tokens = Value::I32(data, vec![b, t_plus1]);
        let (nll, _) = fwd_batch(rt, params, tokens, mode)?;
        let t = t_plus1 - 1; // nll row length
        for (ri, (_, rref)) in chunk.iter().enumerate() {
            // candidate token j sits at sequence index start+j; its NLL is
            // predicted at position start+j-1
            let mut ll = 0.0f64;
            for j in 0..rref.len {
                ll -= nll[ri * t + rref.start + j - 1] as f64;
            }
            if suite.kind.length_normalized() {
                ll /= rref.len as f64;
            }
            scores[rref.probe][rref.cand] = ll;
        }
    }

    let mut correct = 0usize;
    for (p, s) in suite.probes.iter().zip(&scores) {
        let best = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == p.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / suite.probes.len() as f64)
}

/// Full metric row for one (model, corpus): PPL + cosine vs reference.
pub struct LmMetrics {
    /// perplexity on the corpus
    pub ppl: f64,
    /// last-hidden cosine similarity vs the fp reference, in percent
    pub cosine_pct: f64,
}

/// PPL + hidden-cosine for one (model, corpus) pair.
pub fn lm_metrics(
    rt: &Runtime,
    fp_params: &dyn ParamSource,
    q_params: &dyn ParamSource,
    corpus: &Corpus,
    q_mode: FwdMode,
    n_batches: usize,
    seed: u64,
) -> Result<LmMetrics> {
    let ppl = perplexity(rt, q_params, corpus, q_mode, n_batches, seed)?;
    let cos = hidden_cosine(rt, fp_params, q_params, corpus, q_mode, n_batches, seed)?;
    Ok(LmMetrics { ppl, cosine_pct: cos * 100.0 })
}
