//! Ordered parameter store matching the manifest weight layout.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{manifest::Init, Manifest, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// All model weights, in manifest order (the order every artifact expects
/// its leading parameters in).
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub names: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Deterministic initialization from the manifest's init specs.
    pub fn init(manifest: &Manifest, seed: u64) -> ParamStore {
        let mut tensors = BTreeMap::new();
        let mut names = vec![];
        let n_layers = manifest.config.n_layers as f32;
        for (i, w) in manifest.weights.iter().enumerate() {
            let mut t = Tensor::zeros(&w.shape);
            let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37_79B9_7F4A_7C15));
            match w.init {
                Init::Ones => t.data.fill(1.0),
                Init::Normal(std) => rng.fill_normal(&mut t.data, 0.0, std),
                Init::NormalScaled(std) => {
                    rng.fill_normal(&mut t.data, 0.0, std / (2.0 * n_layers).sqrt())
                }
            }
            names.push(w.name.clone());
            tensors.insert(w.name.clone(), t);
        }
        ParamStore { names, tensors }
    }

    /// Zeros with the same layout (optimizer moments).
    pub fn zeros_like(&self) -> ParamStore {
        let tensors = self
            .tensors
            .iter()
            .map(|(k, v)| (k.clone(), Tensor::zeros(&v.shape)))
            .collect();
        ParamStore { names: self.names.clone(), tensors }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("no param '{name}'"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let cur = self.tensors.get(name).ok_or_else(|| anyhow!("no param '{name}'"))?;
        if cur.shape != t.shape {
            bail!("param '{name}': shape {:?} != {:?}", t.shape, cur.shape);
        }
        self.tensors.insert(name.to_string(), t);
        Ok(())
    }

    /// Flat values in manifest order (artifact marshalling).
    pub fn values(&self) -> Vec<Value> {
        self.names.iter().map(|n| Value::F32(self.tensors[n].clone())).collect()
    }

    /// Rebuild from flat values in manifest order.
    pub fn from_values(&self, vals: &[Value]) -> Result<ParamStore> {
        if vals.len() != self.names.len() {
            bail!("{} values for {} params", vals.len(), self.names.len());
        }
        let mut out = self.clone();
        for (name, v) in self.names.iter().zip(vals) {
            out.set(name, v.as_tensor()?.clone())?;
        }
        Ok(out)
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }

    // ---- single-file container: "FWTS" ------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"FWTS");
        buf.extend_from_slice(&(self.names.len() as u32).to_le_bytes());
        for name in &self.names {
            let t = &self.tensors[name];
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.rank() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let buf = std::fs::read(path)?;
        if buf.len() < 8 || &buf[..4] != b"FWTS" {
            bail!("{}: not a FWTS weights file", path.display());
        }
        let count = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let mut off = 8;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if buf.len() < *off + n {
                bail!("{}: truncated weights file", path.display());
            }
            let s = &buf[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let mut names = vec![];
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
            if nlen > 4096 {
                bail!("{}: implausible name length {nlen}", path.display());
            }
            let name = String::from_utf8(take(&mut off, nlen)?.to_vec())?;
            let rank = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
            if rank > 8 {
                bail!("{}: implausible rank {rank}", path.display());
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(&mut off, 8)?.try_into()?) as usize);
            }
            let numel: usize = shape.iter().product();
            if buf.len() < off + numel * 4 {
                bail!("{}: truncated", path.display());
            }
            let data: Vec<f32> = buf[off..off + numel * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += numel * 4;
            names.push(name.clone());
            tensors.insert(name, Tensor::new(data, shape));
        }
        Ok(ParamStore { names, tensors })
    }

    /// Validate layout against a manifest (after load).
    pub fn check_layout(&self, manifest: &Manifest) -> Result<()> {
        if self.names.len() != manifest.weights.len() {
            bail!("param count mismatch");
        }
        for (n, w) in self.names.iter().zip(&manifest.weights) {
            if n != &w.name {
                bail!("param order mismatch: '{n}' vs '{}'", w.name);
            }
            if self.tensors[n].shape != w.shape {
                bail!("param '{n}': shape {:?} != manifest {:?}", self.tensors[n].shape, w.shape);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn mini_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "config": {"name":"t","vocab":16,"d_model":32,"n_layers":1,"n_heads":2,
                     "seq_len":8,"block":16,"mlp_hidden":32,"head_dim":16,
                     "train_batch":2,"eval_batch":2,"stage1_rows":8,"stage2_batch":2},
          "weights": [
            {"name":"layers.wq","shape":[1,32,32],"init":"normal:0.02","quantized":true,"wd":true},
            {"name":"layers.wo","shape":[1,32,32],"init":"normal_scaled:0.02","quantized":true,"wd":true},
            {"name":"out_norm","shape":[32],"init":"ones","quantized":false,"wd":false}
          ],
          "qlinears": [{"name":"layers.wq","capture":"attn_in","k":32,"n":32}],
          "captures": ["attn_in"],
          "artifacts": {
            "pretrain_step": {"file":"p.hlo.txt","inputs":[],"outputs":[]},
            "lm_fwd": {"file":"f.hlo.txt","inputs":[],"outputs":[]},
            "lm_fwd_aq": {"file":"fa.hlo.txt","inputs":[],"outputs":[]},
            "lm_capture": {"file":"c.hlo.txt","inputs":[],"outputs":[]},
            "stage2_step": {"file":"s2.hlo.txt","inputs":[],"outputs":[]}
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn deterministic_init() {
        let m = mini_manifest();
        let a = ParamStore::init(&m, 42);
        let b = ParamStore::init(&m, 42);
        let c = ParamStore::init(&m, 43);
        assert_eq!(a.get("layers.wq").unwrap().data, b.get("layers.wq").unwrap().data);
        assert_ne!(a.get("layers.wq").unwrap().data, c.get("layers.wq").unwrap().data);
        assert!(a.get("out_norm").unwrap().data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn scaled_init_smaller() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 1);
        let std = |t: &Tensor| {
            let n = t.numel() as f64;
            (t.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n).sqrt()
        };
        let wq = std(p.get("layers.wq").unwrap());
        let wo = std(p.get("layers.wo").unwrap());
        assert!(wo < wq * 0.9, "wo std {wo} not scaled below wq {wq}");
    }

    #[test]
    fn values_roundtrip() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 2);
        let vals = p.values();
        assert_eq!(vals.len(), 3);
        let p2 = p.from_values(&vals).unwrap();
        assert_eq!(p2.get("layers.wq").unwrap().data, p.get("layers.wq").unwrap().data);
        assert!(p.from_values(&vals[..2]).is_err());
    }

    #[test]
    fn save_load_check() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 3);
        let dir = std::env::temp_dir().join(format!("faar_ps_{}", std::process::id()));
        let path = dir.join("w.fwts");
        p.save(&path).unwrap();
        let l = ParamStore::load(&path).unwrap();
        l.check_layout(&m).unwrap();
        assert_eq!(l.get("layers.wo").unwrap().data, p.get("layers.wo").unwrap().data);
        assert_eq!(l.total_params(), p.total_params());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn set_rejects_wrong_shape() {
        let m = mini_manifest();
        let mut p = ParamStore::init(&m, 4);
        assert!(p.set("out_norm", Tensor::zeros(&[16])).is_err());
        assert!(p.set("nope", Tensor::zeros(&[32])).is_err());
        assert!(p.set("out_norm", Tensor::zeros(&[32])).is_ok());
    }
}
