//! Ordered parameter stores matching the manifest weight layout.
//!
//! * [`ParamStore`] — dense fp32 tensors (the pretrained checkpoint, the
//!   optimizer state, the bf16 reference).
//! * [`QuantParamStore`] — the canonical *quantized* model: dense fp32
//!   for the non-quantized params, packed [`QuantTensor`]s for every
//!   quantized linear, dequantized lazily (per layer, memoized) when an
//!   eval graph needs f32.
//! * [`ParamSource`] — the common "give me all weights in manifest
//!   order" interface the runtime/eval/serve layers consume, so either
//!   store drives the graphs without conversion.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::formats::codec::QuantTensor;
use crate::runtime::{manifest::Init, Manifest, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// All model weights, in manifest order (the order every artifact expects
/// its leading parameters in).
#[derive(Clone, Debug)]
pub struct ParamStore {
    /// weight names in manifest order
    pub names: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Deterministic initialization from the manifest's init specs.
    pub fn init(manifest: &Manifest, seed: u64) -> ParamStore {
        let mut tensors = BTreeMap::new();
        let mut names = vec![];
        let n_layers = manifest.config.n_layers as f32;
        for (i, w) in manifest.weights.iter().enumerate() {
            let mut t = Tensor::zeros(&w.shape);
            let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37_79B9_7F4A_7C15));
            match w.init {
                Init::Ones => t.data.fill(1.0),
                Init::Normal(std) => rng.fill_normal(&mut t.data, 0.0, std),
                Init::NormalScaled(std) => {
                    rng.fill_normal(&mut t.data, 0.0, std / (2.0 * n_layers).sqrt())
                }
            }
            names.push(w.name.clone());
            tensors.insert(w.name.clone(), t);
        }
        ParamStore { names, tensors }
    }

    /// Zeros with the same layout (optimizer moments).
    pub fn zeros_like(&self) -> ParamStore {
        let tensors = self
            .tensors
            .iter()
            .map(|(k, v)| (k.clone(), Tensor::zeros(&v.shape)))
            .collect();
        ParamStore { names: self.names.clone(), tensors }
    }

    /// Borrow one tensor by name, or error.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("no param '{name}'"))
    }

    /// Replace one tensor (shape-checked), or error.
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let cur = self.tensors.get(name).ok_or_else(|| anyhow!("no param '{name}'"))?;
        if cur.shape != t.shape {
            bail!("param '{name}': shape {:?} != {:?}", t.shape, cur.shape);
        }
        self.tensors.insert(name.to_string(), t);
        Ok(())
    }

    /// Flat values in manifest order (artifact marshalling).
    pub fn values(&self) -> Vec<Value> {
        self.names.iter().map(|n| Value::F32(self.tensors[n].clone())).collect()
    }

    /// Rebuild from flat values in manifest order.
    pub fn from_values(&self, vals: &[Value]) -> Result<ParamStore> {
        if vals.len() != self.names.len() {
            bail!("{} values for {} params", vals.len(), self.names.len());
        }
        let mut out = self.clone();
        for (name, v) in self.names.iter().zip(vals) {
            out.set(name, v.as_tensor()?.clone())?;
        }
        Ok(out)
    }

    /// Total parameter count.
    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }

    // ---- single-file container: "FWTS" ------------------------------------

    /// Write the `FWTS` container (all tensors, manifest order).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"FWTS");
        buf.extend_from_slice(&(self.names.len() as u32).to_le_bytes());
        for name in &self.names {
            let t = &self.tensors[name];
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.rank() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Read an `FWTS` container, validating every section length.
    pub fn load(path: &Path) -> Result<ParamStore> {
        let buf = std::fs::read(path)?;
        if buf.len() < 8 || &buf[..4] != b"FWTS" {
            bail!("{}: not a FWTS weights file", path.display());
        }
        let count = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let mut off = 8;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if buf.len() < *off + n {
                bail!("{}: truncated weights file", path.display());
            }
            let s = &buf[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let mut names = vec![];
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
            if nlen > 4096 {
                bail!("{}: implausible name length {nlen}", path.display());
            }
            let name = String::from_utf8(take(&mut off, nlen)?.to_vec())?;
            let rank = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
            if rank > 8 {
                bail!("{}: implausible rank {rank}", path.display());
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(&mut off, 8)?.try_into()?) as usize);
            }
            let numel: usize = shape.iter().product();
            if buf.len() < off + numel * 4 {
                bail!("{}: truncated", path.display());
            }
            let data: Vec<f32> = buf[off..off + numel * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += numel * 4;
            names.push(name.clone());
            tensors.insert(name, Tensor::new(data, shape));
        }
        Ok(ParamStore { names, tensors })
    }

    /// Validate layout against a manifest (after load).
    pub fn check_layout(&self, manifest: &Manifest) -> Result<()> {
        if self.names.len() != manifest.weights.len() {
            bail!("param count mismatch");
        }
        for (n, w) in self.names.iter().zip(&manifest.weights) {
            if n != &w.name {
                bail!("param order mismatch: '{n}' vs '{}'", w.name);
            }
            if self.tensors[n].shape != w.shape {
                bail!("param '{n}': shape {:?} != manifest {:?}", self.tensors[n].shape, w.shape);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ParamSource: the weight interface the graphs consume

/// Anything that can hand the runtime a full weight set in manifest
/// order. Dense and packed stores both implement it, so eval/serve run
/// off either without materializing a conversion.
pub trait ParamSource {
    /// Flat values in manifest order (artifact marshalling).
    fn values(&self) -> Result<Vec<Value>>;

    /// One tensor by name (owned; implementations may decode on demand).
    fn tensor(&self, name: &str) -> Result<Tensor>;
}

impl ParamSource for ParamStore {
    fn values(&self) -> Result<Vec<Value>> {
        Ok(ParamStore::values(self))
    }

    fn tensor(&self, name: &str) -> Result<Tensor> {
        Ok(self.get(name)?.clone())
    }
}

// ---------------------------------------------------------------------------
// QuantParamStore: packed quantized layers, lazily dequantized

/// The canonical quantized-model representation: non-quantized params
/// stay dense fp32; every quantized linear is held as a packed
/// [`QuantTensor`] (~4.5 bits/weight for NVFP4) and dequantized lazily —
/// per layer, on first demand — when an eval graph asks for f32.
///
/// Dequantized layers are memoized so repeated forwards don't re-decode;
/// that cache trades memory for speed (packed payload + dense copies
/// while warm). `packed_payload_bytes` reports the payload itself (the
/// store/disk footprint); call [`Self::clear_dequant_cache`] to drop the
/// warm dense copies between requests if memory matters more than
/// latency.
///
/// The memoization is guarded by a `Mutex`, so the store is `Send + Sync`
/// and can be shared across the serving engine's threads behind an `Arc`
/// (connection readers never touch it; the scheduler thread and any
/// metrics thread may race on `get` — worst case both decode the same
/// layer once, which is benign).
#[derive(Debug)]
pub struct QuantParamStore {
    names: Vec<String>,
    dense: BTreeMap<String, Tensor>,
    packed: BTreeMap<String, QuantTensor>,
    cache: Mutex<BTreeMap<String, Tensor>>,
}

impl Clone for QuantParamStore {
    fn clone(&self) -> QuantParamStore {
        QuantParamStore {
            names: self.names.clone(),
            dense: self.dense.clone(),
            packed: self.packed.clone(),
            cache: Mutex::new(self.cache.lock().expect("dequant cache poisoned").clone()),
        }
    }
}

impl QuantParamStore {
    /// A store with no packed layers (the bf16 reference path).
    pub fn dense_only(fp: ParamStore) -> QuantParamStore {
        Self::from_store(&fp, BTreeMap::new())
    }

    /// Build from a dense store plus packed payloads. The fp32 copies of
    /// packed layers are dropped — packed is the representation.
    pub fn from_store(fp: &ParamStore, packed: BTreeMap<String, QuantTensor>) -> QuantParamStore {
        let mut dense = BTreeMap::new();
        for name in &fp.names {
            if !packed.contains_key(name) {
                dense.insert(name.clone(), fp.get(name).expect("name in layout").clone());
            }
        }
        QuantParamStore {
            names: fp.names.clone(),
            dense,
            packed,
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Weight names in manifest order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// The packed payload for a quantized layer, if `name` is one.
    pub fn packed(&self, name: &str) -> Option<&QuantTensor> {
        self.packed.get(name)
    }

    /// Number of packed (quantized) layers.
    pub fn n_packed(&self) -> usize {
        self.packed.len()
    }

    /// Bytes of the packed payloads (codes + block scales + globals) —
    /// the real memory footprint of the quantized layers.
    pub fn packed_payload_bytes(&self) -> usize {
        self.packed.values().map(|q| q.payload_bytes()).sum()
    }

    /// fp32 bytes the packed layers would cost dequantized.
    pub fn packed_dense_bytes(&self) -> usize {
        self.packed.values().map(|q| q.numel() * 4).sum()
    }

    /// Drop the memoized dequantized copies (they repopulate on demand).
    pub fn clear_dequant_cache(&self) {
        self.cache.lock().expect("dequant cache poisoned").clear();
    }

    /// Get one tensor, dequantizing (and memoizing) packed layers on
    /// demand. Safe to call from multiple threads; the decode itself runs
    /// outside the lock so a slow dequant never blocks cache hits.
    pub fn get(&self, name: &str) -> Result<Tensor> {
        if let Some(t) = self.dense.get(name) {
            return Ok(t.clone());
        }
        let q = self.packed.get(name).ok_or_else(|| anyhow!("no param '{name}'"))?;
        if let Some(t) = self.cache.lock().expect("dequant cache poisoned").get(name) {
            return Ok(t.clone());
        }
        let t = q.dequantize()?;
        self.cache
            .lock()
            .expect("dequant cache poisoned")
            .insert(name.to_string(), t.clone());
        Ok(t)
    }

    /// Total parameter count (dense + packed).
    pub fn total_params(&self) -> usize {
        self.dense.values().map(|t| t.numel()).sum::<usize>()
            + self.packed.values().map(|q| q.numel()).sum::<usize>()
    }
}

impl ParamSource for QuantParamStore {
    fn values(&self) -> Result<Vec<Value>> {
        self.names.iter().map(|n| Ok(Value::F32(self.get(n)?))).collect()
    }

    fn tensor(&self, name: &str) -> Result<Tensor> {
        self.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn mini_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "config": {"name":"t","vocab":16,"d_model":32,"n_layers":1,"n_heads":2,
                     "seq_len":8,"block":16,"mlp_hidden":32,"head_dim":16,
                     "train_batch":2,"eval_batch":2,"stage1_rows":8,"stage2_batch":2},
          "weights": [
            {"name":"layers.wq","shape":[1,32,32],"init":"normal:0.02","quantized":true,"wd":true},
            {"name":"layers.wo","shape":[1,32,32],"init":"normal_scaled:0.02","quantized":true,"wd":true},
            {"name":"out_norm","shape":[32],"init":"ones","quantized":false,"wd":false}
          ],
          "qlinears": [{"name":"layers.wq","capture":"attn_in","k":32,"n":32}],
          "captures": ["attn_in"],
          "artifacts": {
            "pretrain_step": {"file":"p.hlo.txt","inputs":[],"outputs":[]},
            "lm_fwd": {"file":"f.hlo.txt","inputs":[],"outputs":[]},
            "lm_fwd_aq": {"file":"fa.hlo.txt","inputs":[],"outputs":[]},
            "lm_capture": {"file":"c.hlo.txt","inputs":[],"outputs":[]},
            "stage2_step": {"file":"s2.hlo.txt","inputs":[],"outputs":[]}
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn deterministic_init() {
        let m = mini_manifest();
        let a = ParamStore::init(&m, 42);
        let b = ParamStore::init(&m, 42);
        let c = ParamStore::init(&m, 43);
        assert_eq!(a.get("layers.wq").unwrap().data, b.get("layers.wq").unwrap().data);
        assert_ne!(a.get("layers.wq").unwrap().data, c.get("layers.wq").unwrap().data);
        assert!(a.get("out_norm").unwrap().data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn scaled_init_smaller() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 1);
        let std = |t: &Tensor| {
            let n = t.numel() as f64;
            (t.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n).sqrt()
        };
        let wq = std(p.get("layers.wq").unwrap());
        let wo = std(p.get("layers.wo").unwrap());
        assert!(wo < wq * 0.9, "wo std {wo} not scaled below wq {wq}");
    }

    #[test]
    fn values_roundtrip() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 2);
        let vals = p.values();
        assert_eq!(vals.len(), 3);
        let p2 = p.from_values(&vals).unwrap();
        assert_eq!(p2.get("layers.wq").unwrap().data, p.get("layers.wq").unwrap().data);
        assert!(p.from_values(&vals[..2]).is_err());
    }

    #[test]
    fn save_load_check() {
        let m = mini_manifest();
        let p = ParamStore::init(&m, 3);
        let dir = std::env::temp_dir().join(format!("faar_ps_{}", std::process::id()));
        let path = dir.join("w.fwts");
        p.save(&path).unwrap();
        let l = ParamStore::load(&path).unwrap();
        l.check_layout(&m).unwrap();
        assert_eq!(l.get("layers.wo").unwrap().data, p.get("layers.wo").unwrap().data);
        assert_eq!(l.total_params(), p.total_params());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn set_rejects_wrong_shape() {
        let m = mini_manifest();
        let mut p = ParamStore::init(&m, 4);
        assert!(p.set("out_norm", Tensor::zeros(&[16])).is_err());
        assert!(p.set("nope", Tensor::zeros(&[32])).is_err());
        assert!(p.set("out_norm", Tensor::zeros(&[32])).is_ok());
    }

    fn packed_store() -> (ParamStore, QuantParamStore, QuantTensor) {
        use crate::formats::codec::{codec_for, rtn_decisions, FormatCodec, FormatKind};
        let m = mini_manifest();
        let fp = ParamStore::init(&m, 7);
        let codec = codec_for(FormatKind::Nvfp4);
        let w = fp.get("layers.wq").unwrap();
        let p = codec.prepare(w);
        let q = codec.encode(w, &p, &rtn_decisions(&p));
        let mut packed = BTreeMap::new();
        packed.insert("layers.wq".to_string(), q.clone());
        let store = QuantParamStore::from_store(&fp, packed);
        (fp, store, q)
    }

    #[test]
    fn quant_store_holds_packed_payload_size() {
        let (_, store, q) = packed_store();
        let numel = q.numel();
        assert_eq!(numel, 1024);
        // payload ≈ numel/2 code bytes + numel/16 E4M3 scale bytes + one
        // f32 global per slice — exactly, for this layout
        assert_eq!(q.payload_bytes(), numel / 2 + numel / 16 + 4);
        assert_eq!(store.packed_payload_bytes(), q.payload_bytes());
        assert_eq!(store.n_packed(), 1);
        // the fp32 copy of the quantized layer is gone: packed is ~7x
        // smaller than its dense form
        assert!(store.packed_payload_bytes() * 4 < store.packed_dense_bytes());
        assert_eq!(store.packed_dense_bytes(), numel * 4);
    }

    #[test]
    fn quant_store_lazy_dequant_and_passthrough() {
        let (fp, store, q) = packed_store();
        // lazy dequant equals direct decode, twice (memoized path)
        let deq = store.get("layers.wq").unwrap();
        assert_eq!(deq.data, q.dequantize().unwrap().data);
        assert_eq!(store.get("layers.wq").unwrap().data, deq.data);
        // dropping the memoized copies is safe; they repopulate on demand
        store.clear_dequant_cache();
        assert_eq!(store.get("layers.wq").unwrap().data, deq.data);
        // non-quantized params pass through untouched
        assert_eq!(store.get("out_norm").unwrap().data, fp.get("out_norm").unwrap().data);
        assert!(store.get("nope").is_err());
        assert_eq!(store.total_params(), fp.total_params());
        // manifest-order values: same count and shapes as the dense store
        let vals = ParamSource::values(&store).unwrap();
        let dense_vals = ParamStore::values(&fp);
        assert_eq!(vals.len(), dense_vals.len());
        for (a, b) in vals.iter().zip(&dense_vals) {
            assert_eq!(a.shape(), b.shape());
        }
        // dense_only keeps everything dense
        let plain = QuantParamStore::dense_only(fp.clone());
        assert_eq!(plain.n_packed(), 0);
        assert_eq!(plain.packed_payload_bytes(), 0);
        assert_eq!(plain.get("layers.wq").unwrap().data, fp.get("layers.wq").unwrap().data);
    }

    #[test]
    fn quant_store_shared_across_threads() {
        // the serving scheduler shares the store via Arc; concurrent
        // lazy dequant must be race-free and agree with a direct decode
        let (_, store, q) = packed_store();
        let expect = q.dequantize().unwrap().data;
        let store = std::sync::Arc::new(store);
        let mut handles = vec![];
        for _ in 0..8 {
            let s = store.clone();
            let e = expect.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..4 {
                    assert_eq!(s.get("layers.wq").unwrap().data, e);
                    s.clear_dequant_cache();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // a clone carries the warm cache but is independent afterwards
        let copy = store.as_ref().clone();
        assert_eq!(copy.get("layers.wq").unwrap().data, expect);
    }
}
