//! Model parameter store + the pretraining driver.
//!
//! The paper quantizes *pretrained* checkpoints (Llama3/Qwen3). Offline we
//! have none, so this module produces them: deterministic init from the
//! manifest's weight specs, then a full LM training loop driven from rust
//! through the AOT `pretrain_step` artifact (AdamW + clip fused in-graph;
//! rust owns the data pipeline, the LR schedule and checkpointing).

pub mod params;
pub mod pretrain;

pub use params::{ParamSource, ParamStore, QuantParamStore};
pub use pretrain::{pretrain, PretrainReport};
