//! Pretraining driver: the rust loop around the AOT `pretrain_step` graph.
//!
//! Rust owns the schedule (linear warmup → cosine decay), the data stream,
//! checkpointing and the loss log; XLA owns the math (fwd+bwd+AdamW+clip
//! fused in one executable). The checkpoint this produces is the "BF16
//! model" every quantization method in the paper starts from.

use std::path::Path;

use anyhow::{bail, Result};

use crate::data::{batcher::Split, Batcher, Corpus};
use crate::runtime::{Runtime, Value};
use crate::util::json::Json;

use super::ParamStore;

/// Summary of one pretraining run.
pub struct PretrainReport {
    /// per-step training losses
    pub losses: Vec<f64>,
    /// loss at the last step
    pub final_loss: f64,
    /// optimizer steps executed
    pub steps: usize,
    /// wall-clock seconds
    pub wall_s: f64,
    /// training throughput
    pub tokens_per_s: f64,
}

/// Linear warmup to `lr`, then cosine decay to 10% of `lr`.
pub fn lr_at(step: usize, total: usize, warmup: usize, lr: f32) -> f32 {
    if total == 0 {
        return lr;
    }
    if step < warmup {
        return lr * (step as f32 + 1.0) / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    lr * (0.1 + 0.9 * cos)
}

/// Train from `init` for `steps` steps over a mixture of corpora
/// (batches alternate round-robin — the "general web text" stand-in).
/// Returns final params + report.
pub fn pretrain(
    rt: &Runtime,
    corpora: &[&Corpus],
    init: ParamStore,
    steps: usize,
    lr: f32,
    warmup: usize,
    seed: u64,
) -> Result<(ParamStore, PretrainReport)> {
    let cfg = rt.config();
    let spec = rt.manifest.artifact("pretrain_step")?.clone();
    let n_w = init.names.len();
    if spec.inputs.len() != 3 * n_w + 3 {
        bail!(
            "pretrain_step expects {} inputs, weights imply {}",
            spec.inputs.len(),
            3 * n_w + 3
        );
    }
    if corpora.is_empty() {
        bail!("need at least one corpus");
    }

    let batchers: Vec<Batcher> = corpora
        .iter()
        .map(|c| Batcher::new(c, Split::Train, cfg.train_batch, cfg.seq_len + 1, seed))
        .collect();
    let mut weights = init.values();
    let mut m: Vec<Value> = init.zeros_like().values();
    let mut v: Vec<Value> = init.zeros_like().values();
    let mut losses = Vec::with_capacity(steps);

    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let tokens = batchers[step % batchers.len()].batch_at(step);
        let cur_lr = lr_at(step, steps, warmup, lr);
        let mut args = Vec::with_capacity(3 * n_w + 3);
        args.extend(weights.iter().cloned());
        args.extend(m.iter().cloned());
        args.extend(v.iter().cloned());
        args.push(tokens);
        args.push(Value::scalar_f32(step as f32 + 1.0));
        args.push(Value::scalar_f32(cur_lr));

        let mut out = rt.exec("pretrain_step", &args)?;
        let loss = out.last().unwrap().as_f32_scalar()? as f64;
        if !loss.is_finite() {
            bail!("pretraining diverged at step {step} (loss = {loss})");
        }
        losses.push(loss);
        // outputs: w' x n, m' x n, v' x n, loss
        let rest = out.split_off(n_w);
        weights = out;
        let (m2, mut rest2) = {
            let mut r = rest;
            let tail = r.split_off(n_w);
            (r, tail)
        };
        m = m2;
        rest2.truncate(n_w);
        v = rest2;

        if step % 50 == 0 || step + 1 == steps {
            crate::info!(
                "pretrain[{}] step {step}/{steps} loss {loss:.4} lr {cur_lr:.2e}",
                cfg.name
            );
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let toks = (steps * cfg.train_batch * cfg.seq_len) as f64;

    let final_params = init.from_values(&weights)?;
    let report = PretrainReport {
        final_loss: *losses.last().unwrap_or(&f64::NAN),
        losses,
        steps,
        wall_s,
        tokens_per_s: toks / wall_s.max(1e-9),
    };
    Ok((final_params, report))
}

/// Persist the loss curve for EXPERIMENTS.md.
pub fn save_loss_curve(report: &PretrainReport, path: &Path) -> Result<()> {
    let doc = Json::obj(vec![
        ("steps", Json::num(report.steps as f64)),
        ("final_loss", Json::Num(report.final_loss)),
        ("wall_s", Json::Num(report.wall_s)),
        ("tokens_per_s", Json::Num(report.tokens_per_s)),
        (
            "losses",
            Json::Arr(report.losses.iter().map(|&l| Json::Num(l)).collect()),
        ),
    ]);
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(path, doc.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let lr = 1e-3;
        assert!(lr_at(0, 100, 10, lr) < lr * 0.2); // warming up
        assert!((lr_at(9, 100, 10, lr) - lr).abs() < 1e-9); // peak
        assert!(lr_at(99, 100, 10, lr) < lr * 0.2); // decayed
        // monotone decay after warmup
        let mut prev = f32::INFINITY;
        for s in 10..100 {
            let cur = lr_at(s, 100, 10, lr);
            assert!(cur <= prev + 1e-9);
            prev = cur;
        }
    }

    #[test]
    fn lr_degenerate_cases() {
        assert_eq!(lr_at(5, 0, 0, 1e-3), 1e-3);
        // no warmup
        assert!((lr_at(0, 10, 0, 1e-3) - 1e-3).abs() < 1e-9);
    }
}
