//! `faar` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   pretrain   train the full-precision checkpoint for a model preset
//!   quantize   run one quantization method end-to-end (writes .nvfp4)
//!   eval       evaluate a method: PPL / cosine / zero-shot accuracy
//!   tables     regenerate paper tables (t1, t3, t4, t5, t6, t7, t8, all)
//!   figures    regenerate paper figures (f2)
//!   serve      serve the quantized model over TCP (JSON lines) or HTTP/SSE
//!   info       print manifest / artifact info for a model preset
//!
//! Every subcommand accepts the config overrides documented in
//! `config::PipelineConfig::apply_args` (--model, --stage1-steps, ...).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::data::tasks::TaskKind;
use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::kernels::{cpu_features, kernel_path};
use nvfp4_faar::infer::{
    check_draft_compat, native_manifest, quantize_store, KvFormat, NativeBackend, NativeModel,
    NativeOptions,
};
use nvfp4_faar::pipeline::{pack_model, Method, Workbench};
use nvfp4_faar::report::tables;
use nvfp4_faar::runtime::Runtime;
use nvfp4_faar::serve::{
    serve_backend, CodecKind, FaultBackend, FaultPlan, Lifecycle, ModelEntry, ModelRegistry,
    ServeOptions, SpecDecoder, SyntheticBackend, Transport,
};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::cli::Args;
use nvfp4_faar::{info, util, warn};

const USAGE: &str = "\
faar — FAAR/NVFP4 quantization framework (paper reproduction)

USAGE: faar <subcommand> [options]

  pretrain  --model tiny [--pretrain-steps N] [--seed S]
  quantize  --model tiny --method faar+2fa [--stage1-steps N] ...
  eval      --model tiny --method rtn[,gptq,...] [--tasks]
  tables    --id t1|t3|t4|t5|t6|t7|t8|all [--model tiny] [--models tiny,small]
  figures   --id f2
  serve     --model tiny [--addr 127.0.0.1:7745] [--backend native|xla|synthetic]
            [--method faar+2fa (xla only)] [--format nvfp4|mxfp4|e2m1 (native only)]
            [--workers N] [--max-batch N] [--queue-depth N]
            [--max-tokens-cap N] [--max-line-bytes N]
            [--read-timeout-ms MS] [--max-conns N] [--kv-pages N]
            [--kv-page-tokens N] [--kv-format f32|e4m3 (native only)]
            [--prefix-cache (native only)] [--prefill-chunk-tokens N]
            [--no-kv] [--no-act-quant]
            [--models NAME[=PRESET],... (native only)]
            [--draft-model PRESET] [--spec-k N (default 4)]
            [--transport tcp|http|auto] [--codec line|incremental]
            [--temperature T] [--top-k K] [--top-p P]
            [--repetition-penalty R] [--seed S]
            [--default-deadline-ms MS] [--max-queue-wait-ms MS]
            [--drain-timeout-ms MS (default 5000)]
            [--fault-plan SPEC (native|synthetic; or FAAR_FAULT_PLAN)]
  info      --model tiny

The native serve backend runs the quantized transformer in pure rust
(packed weights, fused dequant kernels, paged KV cache) and needs no
artifacts/ directory; xla is the AOT/PJRT path; synthetic is the
deterministic load-testing stand-in. The sampling flags set the server's
DEFAULT generation parameters (greedy unless --temperature is given);
any request can override them with a protocol-v2 "params" object, and
"stream": true turns on incremental token frames. --prefix-cache shares
KV pages between requests with a common prompt prefix (bit-identical
outputs); --prefill-chunk-tokens N bounds per-step prompt prefill so a
long prompt cannot stall decoding neighbours (0 = off).

--models hosts several native models behind one server (each with its
own KV pool and queue counters); requests pick one with a "model"
field, names default to their preset, and entry 0 is the default for
requests that name none (all presets must share one vocabulary).
--draft-model pairs a small draft preset with the default model and
decodes it speculatively: the draft proposes --spec-k tokens, the
target verifies them in one multi-row pass, and the emitted stream is
bit-identical to plain decoding. Needs the KV cache (conflicts with
--no-kv).

Overload protection and drain: --default-deadline-ms bounds every
request's total server time unless its line carries its own
\"deadline_ms\" (expired → structured deadline_exceeded / HTTP 504);
--max-queue-wait-ms sheds requests that waited too long in the queue
(structured overloaded with a retry_after_ms hint / HTTP 503 with
Retry-After) so a burst past capacity degrades to fast rejections
instead of unbounded queueing. SIGTERM or Ctrl-C starts a graceful
drain: the listener stops accepting, GET /readyz flips to 503, new
requests get shutting_down, and in-flight decodes run up to
--drain-timeout-ms before eviction. --fault-plan injects
deterministic, seeded faults (step errors, KV exhaustion, panics,
latency) into the backend for chaos testing — see
serve::fault::FaultPlan for the spec grammar.

--transport selects the wire protocol: tcp is newline-delimited JSON
(the reference protocol), http serves POST /v1/generate with the same
JSON body ("stream": true maps to server-sent events), and auto sniffs
each connection so both kinds of client share one listener. --codec
picks the JSONL frame decoder: line buffers whole lines; incremental
parses bytes as they arrive with bounded nesting/string/frame limits
(HTTP bodies always decode incrementally).

Common options: --artifacts DIR (default artifacts), --out DIR (default
results), --seed N, plus every pipeline hyperparameter (see README).";

fn main() {
    let t0 = std::time::Instant::now();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    info!("done in {:.1}s", t0.elapsed().as_secs_f64());
}

fn run() -> Result<()> {
    let args = Args::from_env(&["tasks", "pack", "help", "no-kv", "no-act-quant", "prefix-cache"])?;
    if args.positional.is_empty() || args.flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args)?;

    match args.subcommand()? {
        "pretrain" => cmd_pretrain(cfg),
        "quantize" => cmd_quantize(cfg, &args),
        "eval" => cmd_eval(cfg, &args),
        "tables" => cmd_tables(cfg, &args),
        "figures" => cmd_figures(cfg, &args),
        "serve" => cmd_serve(cfg, &args),
        "info" => cmd_info(cfg),
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_pretrain(cfg: PipelineConfig) -> Result<()> {
    // force re-train by removing any cached checkpoint
    let ckpt = Workbench::ckpt_path(&cfg);
    if ckpt.exists() {
        std::fs::remove_file(&ckpt)?;
    }
    let wb = Workbench::open(cfg)?;
    info!(
        "checkpoint ready: {} ({} params)",
        Workbench::ckpt_path(&wb.cfg).display(),
        wb.fp.total_params()
    );
    Ok(())
}

fn cmd_quantize(cfg: PipelineConfig, args: &Args) -> Result<()> {
    let method = Method::parse(&args.str_or("method", "faar+2fa"))?;
    let out_dir = PathBuf::from(&cfg.out_dir);
    let wb = Workbench::open(cfg)?;
    let outcome = wb.quantize(method)?;
    info!("quantized with {} in {:.1}s", method.name(), outcome.wall_s);

    let packed = outcome.params.packed_payload_bytes();
    if packed > 0 {
        let dense = outcome.params.packed_dense_bytes();
        info!(
            "{} layers held packed: {:.2} MiB vs {:.2} MiB fp32 ({:.1}x smaller)",
            outcome.params.n_packed(),
            packed as f64 / (1 << 20) as f64,
            dense as f64 / (1 << 20) as f64,
            dense as f64 / packed as f64
        );
    }

    if outcome.params.n_packed() > 0 {
        let dir = out_dir.join(format!("packed_{}_{}", wb.cfg.model, sanitize(&method.name())));
        let bytes = pack_model(&wb.rt, &outcome.params, &dir)?;
        let fp_bytes = wb.fp.total_params() * 4;
        info!(
            "packed payload: {:.2} MiB (fp32 model {:.2} MiB, {:.1}x smaller) → {}",
            bytes as f64 / (1 << 20) as f64,
            fp_bytes as f64 / (1 << 20) as f64,
            fp_bytes as f64 / bytes as f64,
            dir.display()
        );
    }
    let lm = wb.lm_metrics(&outcome, "wiki")?;
    println!(
        "{} on synthwiki: PPL {:.3}, hidden cosine {:.2}%",
        method.name(),
        lm.ppl,
        lm.cosine_pct
    );
    Ok(())
}

fn cmd_eval(cfg: PipelineConfig, args: &Args) -> Result<()> {
    let methods: Vec<Method> = args
        .list_or("method", &["bf16", "rtn", "faar+2fa"])
        .iter()
        .map(|s| Method::parse(s))
        .collect::<Result<_>>()?;
    let with_tasks = args.flag("tasks");
    let n_probes = args.usize_or("probes", 100)?;
    let wb = Workbench::open(cfg)?;

    println!(
        "{:<18}{:>10}{:>10}{:>10}{:>10}",
        "method", "wiki-ppl", "wiki-cos", "c4-ppl", "c4-cos"
    );
    for m in &methods {
        let out = wb.quantize(*m)?;
        let w = wb.lm_metrics(&out, "wiki")?;
        let c = wb.lm_metrics(&out, "c4")?;
        println!(
            "{:<18}{:>10.3}{:>10.2}{:>10.3}{:>10.2}",
            m.name(),
            w.ppl,
            w.cosine_pct,
            c.ppl,
            c.cosine_pct
        );
        if with_tasks {
            for k in TaskKind::all() {
                let acc = wb.task_accuracy(&out, k, n_probes)?;
                println!("    {:<12} {:.2}%", k.name(), acc);
            }
        }
    }
    Ok(())
}

fn cmd_tables(cfg: PipelineConfig, args: &Args) -> Result<()> {
    let id = args.str_or("id", "all");
    let out_dir = PathBuf::from(&cfg.out_dir).join("tables");
    let models = args.list_or("models", &[&cfg.model]);
    let ids: Vec<&str> = id.split(',').map(|s| s.trim()).collect();
    let run = |which: &str| ids.contains(&"all") || ids.contains(&which);

    for model in &models {
        let mut mcfg = cfg.clone();
        mcfg.model = model.clone();
        // sweep-heavy tables use the reduced schedule unless overridden
        let wb = Workbench::open(mcfg)?;

        if run("t1") {
            let trials = args.usize_or("trials", 20)?;
            tables::table1(&wb, trials)?.emit(&out_dir, &format!("table1_{model}"))?;
        }
        if run("t3") || run("t4") {
            let (t3, t4) = tables::table3_4(&wb, &tables::main_methods())?;
            if run("t3") {
                t3.emit(&out_dir, &format!("table3_{model}"))?;
            }
            if run("t4") {
                t4.emit(&out_dir, &format!("table4_{model}"))?;
            }
        }
        if run("t5") {
            let n_probes = args.usize_or("probes", 150)?;
            let methods = [
                Method::Bf16,
                Method::Rtn,
                Method::MrGptq,
                Method::Gptq,
                Method::GptqFourSix,
                Method::Faar2fa,
            ];
            tables::table5(&wb, &methods, n_probes)?
                .emit(&out_dir, &format!("table5_{model}"))?;
        }
        if run("t6") {
            tables::table6(&wb)?.emit(&out_dir, &format!("table6_{model}"))?;
        }
        if run("t7") {
            let cks = args.list_or("checkpoints", &["0", "50", "250", "1000"]);
            let cks: Vec<usize> =
                cks.iter().map(|s| s.parse()).collect::<std::result::Result<_, _>>()?;
            tables::table7(&wb, &cks)?.emit(&out_dir, &format!("table7_{model}"))?;
        }
        if run("t8") {
            let lrs = args.list_or("lrs", &["5e-5", "1e-4", "5e-4", "1e-3"]);
            let lrs: Vec<f32> =
                lrs.iter().map(|s| s.parse()).collect::<std::result::Result<_, _>>()?;
            tables::table8(&wb, &lrs)?.emit(&out_dir, &format!("table8_{model}"))?;
        }
        // extension (not in the paper): NVFP4 vs MXFP4 format ablation
        if ids.contains(&"fmt") {
            tables::format_ablation(&wb)?.emit(&out_dir, &format!("format_{model}"))?;
        }
    }
    Ok(())
}

fn cmd_figures(cfg: PipelineConfig, args: &Args) -> Result<()> {
    let id = args.str_or("id", "f2");
    if id == "f2" || id == "all" {
        tables::figure2(&PathBuf::from(&cfg.out_dir).join("figures"))?;
    } else {
        bail!("unknown figure id '{id}' (have: f2)");
    }
    Ok(())
}

fn cmd_serve(cfg: PipelineConfig, args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7745");
    let max_conns = args.get("max-conns").map(|s| s.parse()).transpose()?;
    let d = ServeOptions::default();
    let opts = ServeOptions {
        max_batch: args.usize_or("max-batch", d.max_batch)?,
        queue_depth: args.usize_or("queue-depth", d.queue_depth)?,
        max_tokens_cap: args.usize_or("max-tokens-cap", d.max_tokens_cap)?,
        max_line_bytes: args.usize_or("max-line-bytes", d.max_line_bytes)?,
        read_timeout_ms: args.u64_or("read-timeout-ms", d.read_timeout_ms)?,
        workers: args.usize_or("workers", d.workers)?,
        defaults: default_gen_params(args, cfg.seed)?,
        prefill_chunk_tokens: args.usize_or("prefill-chunk-tokens", d.prefill_chunk_tokens)?,
        transport: {
            let name = args.str_or("transport", d.transport.name());
            Transport::parse(&name)
                .ok_or_else(|| anyhow!("unknown --transport '{name}' (tcp|http|auto)"))?
        },
        codec: {
            let name = args.str_or("codec", d.codec.name());
            CodecKind::parse(&name)
                .ok_or_else(|| anyhow!("unknown --codec '{name}' (line|incremental)"))?
        },
        default_deadline_ms: args.u64_or("default-deadline-ms", d.default_deadline_ms)?,
        max_queue_wait_ms: args.u64_or("max-queue-wait-ms", d.max_queue_wait_ms)?,
        drain_timeout_ms: args.u64_or("drain-timeout-ms", d.drain_timeout_ms)?,
        lifecycle: d.lifecycle.clone(),
        // the registry path fills this in with the hosted names so the
        // protocol layer can validate request "model" fields
        models: Vec::new(),
    };
    // reject bad knob combinations at parse time, not deep in the engine
    opts.validate()?;
    // deterministic chaos: --fault-plan (or FAAR_FAULT_PLAN) wraps the
    // backend in a seeded fault injector, validated here at parse time
    let fault = match args
        .get("fault-plan")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("FAAR_FAULT_PLAN").ok().filter(|s| !s.is_empty()))
    {
        Some(spec) => {
            let plan = FaultPlan::parse(&spec)?;
            info!("fault injection armed: {spec}");
            Some(plan)
        }
        None => None,
    };
    let backend = args.str_or("backend", "xla");
    if backend != "xla" && args.get("method").is_some() {
        bail!(
            "--method applies to the xla backend only; the native backend serves \
             RTN-packed weights (pick the element format with --format)"
        );
    }
    if backend != "native" {
        for flag in ["models", "draft-model", "spec-k"] {
            if args.get(flag).is_some() {
                bail!("--{flag} applies to the native serve backend only");
            }
        }
    }
    if backend == "xla" && fault.is_some() {
        bail!("--fault-plan applies to the native and synthetic serve backends");
    }
    // SIGTERM / Ctrl-C flip the engine into a graceful drain instead of
    // killing in-flight decodes
    install_drain_handler(opts.lifecycle.clone());
    match backend.as_str() {
        "xla" => {
            let method = Method::parse(&args.str_or("method", "faar+2fa"))?;
            let wb = Workbench::open(cfg)?;
            let outcome = wb.quantize(method)?;
            info!("model quantized with {}; starting server (xla backend)", method.name());
            let gen = nvfp4_faar::serve::Generator::new(&wb.rt, outcome.params.clone());
            gen.serve_with(&addr, max_conns, opts).map(|_| ())
        }
        "native" => serve_native(cfg, args, &addr, max_conns, opts, fault),
        "synthetic" => {
            let manifest = native_manifest(&cfg.model)?;
            let backend = SyntheticBackend::new(
                manifest.config.vocab,
                manifest.config.seq_len,
                cfg.seed,
            );
            match fault {
                Some(plan) => {
                    serve_backend(&FaultBackend::new(backend, plan), &addr, max_conns, opts)
                        .map(|_| ())
                }
                None => serve_backend(&backend, &addr, max_conns, opts).map(|_| ()),
            }
        }
        other => bail!("unknown backend '{other}' (native|xla|synthetic)"),
    }
}

/// The flag an async-signal handler may touch: the watcher thread below
/// translates it into a [`Lifecycle`] drain outside signal context.
static DRAIN_SIGNAL: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_drain_signal(_sig: i32) {
    DRAIN_SIGNAL.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT into a graceful drain: the handler only
/// sets an atomic flag (the one thing that is async-signal-safe); a
/// watcher thread sees it and flips the shared [`Lifecycle`], which
/// stops the acceptor, flips `/readyz` to 503, and starts the
/// `--drain-timeout-ms` clock. Declared against `signal(2)` directly so
/// the offline build stays free of a libc crate dependency.
#[cfg(unix)]
fn install_drain_handler(lifecycle: std::sync::Arc<Lifecycle>) {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_drain_signal);
        signal(SIGTERM, on_drain_signal);
    }
    std::thread::spawn(move || {
        while !DRAIN_SIGNAL.load(std::sync::atomic::Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        warn!("shutdown signal received: draining (in-flight requests finish)");
        lifecycle.begin_drain();
    });
}

/// Non-unix fallback: no signal routing; drain still works through
/// [`ServeOptions::lifecycle`] for embedders and tests.
#[cfg(not(unix))]
fn install_drain_handler(_lifecycle: std::sync::Arc<Lifecycle>) {}

/// Build the server-default `GenParams` from the serve CLI flags
/// (greedy unless `--temperature` is given). Explicitly passing a
/// non-positive temperature or `--top-k 0` is rejected here, exactly as
/// the protocol boundary rejects it per request.
fn default_gen_params(args: &Args, seed: u64) -> Result<nvfp4_faar::serve::GenParams> {
    let mut p = nvfp4_faar::serve::GenParams::default();
    if let Some(t) = args.get("temperature") {
        let t: f32 = t.parse().map_err(|e| anyhow::anyhow!("--temperature: {e}"))?;
        if !t.is_finite() || t <= 0.0 {
            bail!("--temperature must be finite and > 0 (omit it for greedy)");
        }
        p.temperature = t;
    }
    if let Some(k) = args.get("top-k") {
        let k: usize = k.parse().map_err(|e| anyhow::anyhow!("--top-k: {e}"))?;
        if k == 0 {
            bail!("--top-k must be >= 1 (omit it to sample the full vocabulary)");
        }
        p.top_k = k;
    }
    p.top_p = args.f32_or("top-p", p.top_p)?;
    p.repetition_penalty = args.f32_or("repetition-penalty", p.repetition_penalty)?;
    p.seed = seed;
    p.validate()?;
    Ok(p)
}

/// The artifact-free serving path: deterministic (or checkpointed)
/// weights, pure-rust RTN quantization through the chosen codec, and the
/// native fused-kernel backend with a paged KV cache. With `--models`
/// or `--draft-model` the backends go behind a [`ModelRegistry`]; the
/// bare single-model case keeps the direct path.
fn serve_native(
    cfg: PipelineConfig,
    args: &Args,
    addr: &str,
    max_conns: Option<usize>,
    mut opts: ServeOptions,
    fault: Option<FaultPlan>,
) -> Result<()> {
    let draft = args.get("draft-model").map(|s| s.to_string());
    let spec_k = args.usize_or("spec-k", 4)?;
    if spec_k == 0 {
        bail!("--spec-k must be >= 1");
    }
    if args.get("spec-k").is_some() && draft.is_none() {
        bail!("--spec-k requires --draft-model");
    }
    if draft.is_some() && args.flag("no-kv") {
        bail!("--draft-model needs the KV cache for draft-verify rollback; drop --no-kv");
    }
    // --models NAME[=PRESET],... — names default to their preset
    let hosted: Vec<(String, String)> = match args.get("models") {
        Some(list) => {
            let mut out = Vec::new();
            for item in list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()) {
                let (name, preset) = match item.split_once('=') {
                    Some((n, p)) => (n.trim().to_string(), p.trim().to_string()),
                    None => (item.to_string(), item.to_string()),
                };
                if name.is_empty() || preset.is_empty() {
                    bail!("--models entries are NAME or NAME=PRESET, got '{item}'");
                }
                out.push((name, preset));
            }
            if out.is_empty() {
                bail!("--models needs at least one NAME[=PRESET] entry");
            }
            out
        }
        None => vec![(cfg.model.clone(), cfg.model.clone())],
    };
    if args.get("models").is_none() && draft.is_none() {
        // bare single-model serving: no registry indirection on the path
        let backend = build_native_backend(&cfg, &cfg.model, args, &opts)?;
        return match fault {
            Some(plan) => serve_backend(&FaultBackend::new(backend, plan), addr, max_conns, opts)
                .map(|_| ()),
            None => serve_backend(&backend, addr, max_conns, opts).map(|_| ()),
        };
    }
    if let Some(dp) = &draft {
        // fail a bad pairing before any weights are built or quantized
        check_draft_compat(&native_manifest(&hosted[0].1)?.config, &native_manifest(dp)?.config)?;
    }
    let mut entries = Vec::new();
    for (i, (name, preset)) in hosted.iter().enumerate() {
        let backend = build_native_backend(&cfg, preset, args, &opts)?;
        // the draft pairs with the default model (entry 0)
        let spec = match (&draft, i) {
            (Some(dp), 0) => {
                let db = build_native_backend(&cfg, dp, args, &opts)?;
                info!("model '{name}' decodes speculatively: draft preset {dp}, k={spec_k}");
                Some(SpecDecoder::new(db, spec_k))
            }
            _ => None,
        };
        entries.push(ModelEntry { name: name.clone(), backend, spec });
    }
    // rejects duplicate names and mixed vocabularies at startup
    let registry = ModelRegistry::new(entries)?;
    opts.models = registry.names();
    info!("serving {} hosted model(s): {}", opts.models.len(), opts.models.join(", "));
    match fault {
        Some(plan) => {
            serve_backend(&FaultBackend::new(registry, plan), addr, max_conns, opts).map(|_| ())
        }
        None => serve_backend(&registry, addr, max_conns, opts).map(|_| ()),
    }
}

/// Build one native backend for `preset`: checkpoint (or deterministic
/// init) weights, RTN packing through the chosen codec, and a paged KV
/// pool sized off the serve knobs. Factored out so the multi-model
/// registry path builds one per hosted preset.
fn build_native_backend(
    cfg: &PipelineConfig,
    preset: &str,
    args: &Args,
    opts: &ServeOptions,
) -> Result<NativeBackend> {
    let mut cfg = cfg.clone();
    cfg.model = preset.to_string();
    let manifest = native_manifest(&cfg.model)?;
    let ckpt = Workbench::ckpt_path(&cfg);
    let fp = if ckpt.exists() {
        match ParamStore::load(&ckpt).and_then(|p| {
            p.check_layout(&manifest)?;
            Ok(p)
        }) {
            Ok(p) => {
                info!("loaded checkpoint {}", ckpt.display());
                p
            }
            Err(e) => {
                warn!(
                    "checkpoint {} unusable ({e}); serving deterministic init weights",
                    ckpt.display()
                );
                ParamStore::init(&manifest, cfg.seed)
            }
        }
    } else {
        info!(
            "no checkpoint at {}; serving deterministic init weights (seed {})",
            ckpt.display(),
            cfg.seed
        );
        ParamStore::init(&manifest, cfg.seed)
    };
    let format = FormatKind::parse(&args.str_or("format", "nvfp4"))?;
    let store = quantize_store(&manifest, &fp, format)?;
    info!(
        "{} layers RTN-packed as {} ({:.2} MiB vs {:.2} MiB fp32, {:.1}x smaller)",
        store.n_packed(),
        format.name(),
        store.packed_payload_bytes() as f64 / (1 << 20) as f64,
        store.packed_dense_bytes() as f64 / (1 << 20) as f64,
        store.packed_dense_bytes() as f64 / store.packed_payload_bytes().max(1) as f64
    );
    let model = NativeModel::new(&manifest.config, &store, !args.flag("no-act-quant"))?;
    let nd = NativeOptions::default();
    // page geometry first (it sets the per-window page count), then the
    // KV budget: two full windows per micro-batch lane by default, so
    // retiring slots never starve admissions. The page size threads all
    // the way into the backend's uncached-fallback scratch pools — no
    // hardcoded geometry anywhere on the native path.
    let page_tokens = args.usize_or("kv-page-tokens", nd.page_tokens)?;
    if page_tokens == 0 {
        bail!("--kv-page-tokens must be >= 1");
    }
    let pages_per_window = manifest.config.seq_len.div_ceil(page_tokens);
    let max_pages =
        args.usize_or("kv-pages", 2 * opts.max_batch.max(1) * pages_per_window)?;
    if max_pages == 0 {
        bail!("--kv-pages must be >= 1");
    }
    let kv_name = args.str_or("kv-format", nd.kv_format.name());
    let kv_format = KvFormat::parse(&kv_name)
        .ok_or_else(|| anyhow!("unknown --kv-format '{kv_name}' (expected f32 or e4m3)"))?;
    let prefix_cache = args.flag("prefix-cache");
    if prefix_cache && args.flag("no-kv") {
        bail!("--prefix-cache needs the KV cache; drop --no-kv");
    }
    let backend = NativeBackend::new(
        model,
        NativeOptions {
            use_cache: !args.flag("no-kv"),
            max_pages,
            page_tokens,
            kv_format,
            prefix_cache,
            ..nd
        },
    );
    info!(
        "native backend ready (model {}, kv {} pages x {} tokens [{}], cache {}, \
         prefix cache {}, kernels {} [{}])",
        manifest.config.name,
        max_pages,
        page_tokens,
        kv_format.name(),
        if args.flag("no-kv") { "off" } else { "on" },
        if prefix_cache { "on" } else { "off" },
        kernel_path().name(),
        cpu_features()
    );
    Ok(backend)
}

fn cmd_info(cfg: PipelineConfig) -> Result<()> {
    let rt = Runtime::load(Path::new(&cfg.artifact_root), &cfg.model)?;
    let m = &rt.manifest;
    let c = &m.config;
    println!("model preset '{}'", c.name);
    println!(
        "  vocab {}  d_model {}  layers {}  heads {}  mlp {}  seq {}",
        c.vocab, c.d_model, c.n_layers, c.n_heads, c.mlp_hidden, c.seq_len
    );
    let total: usize = m.weights.iter().map(|w| w.shape.iter().product::<usize>()).sum();
    let qtotal: usize = m
        .weights
        .iter()
        .filter(|w| w.quantized)
        .map(|w| w.shape.iter().product::<usize>())
        .sum();
    println!(
        "  params {} total, {} quantized ({:.1}%)",
        total,
        qtotal,
        100.0 * qtotal as f64 / total as f64
    );
    println!("  artifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "    {:<24} {:>3} in / {:>3} out   {}",
            name,
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    let _ = util::timed(|| ());
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_alphanumeric() { c } else { '_' }).collect()
}
