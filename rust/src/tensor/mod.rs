//! Host tensor: a flat `Vec<f32>` plus shape, with the handful of ops the
//! coordinator needs (the heavy math runs in AOT-compiled XLA; this type
//! exists for marshalling, codecs, GPTQ and evaluation plumbing).

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
/// Row-major f32 host tensor: flat data plus shape.
pub struct Tensor {
    /// flat row-major elements
    pub data: Vec<f32>,
    /// dimensions, outermost first
    pub shape: Vec<usize>,
}

impl Tensor {
    /// A tensor from flat data and a shape (panics on length mismatch).
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data len {} != shape {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape }
    }

    /// An all-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// A constant-filled tensor of the given shape.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    /// A rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        Tensor { data: vec![v], shape: vec![] }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Last two dims as (rows, cols); errors on rank < 2.
    pub fn mat_dims(&self) -> Result<(usize, usize)> {
        if self.rank() < 2 {
            bail!("expected rank >= 2, got {:?}", self.shape);
        }
        Ok((self.shape[self.rank() - 2], self.shape[self.rank() - 1]))
    }

    /// Number of leading (batch) slices for a [..., K, N] tensor.
    pub fn lead(&self) -> usize {
        self.shape[..self.rank().saturating_sub(2)].iter().product::<usize>().max(1)
    }

    /// Reinterpret the shape (same element count, no data movement).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.numel() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Slice the leading axis: returns the i-th sub-tensor of shape[1..].
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(self.rank() >= 1 && i < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor::new(
            self.data[i * sub..(i + 1) * sub].to_vec(),
            self.shape[1..].to_vec(),
        )
    }

    /// Write a sub-tensor into position i along the leading axis.
    pub fn set_index0(&mut self, i: usize, t: &Tensor) {
        let sub: usize = self.shape[1..].iter().product();
        assert_eq!(t.numel(), sub);
        self.data[i * sub..(i + 1) * sub].copy_from_slice(&t.data);
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = parts[0].shape.clone();
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            assert_eq!(p.shape, inner);
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend(inner);
        Tensor::new(data, shape)
    }

    /// Elementwise transform into a new tensor.
    pub fn map(&self, mut f: impl FnMut(f32) -> f32) -> Tensor {
        Tensor::new(self.data.iter().map(|&x| f(x)).collect(), self.shape.clone())
    }

    /// Elementwise combine with another tensor of identical shape.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
            self.shape.clone(),
        )
    }

    /// Largest absolute element (0 for empty tensors).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Row-major matmul (self [M,K] x other [K,N]) in f64 accumulation —
    /// used only by tests and the GPTQ substrate, not on the serving path.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = self.mat_dims()?;
        let (k2, n) = other.mat_dims()?;
        if self.rank() != 2 || other.rank() != 2 || k != k2 {
            bail!("matmul shape mismatch {:?} x {:?}", self.shape, other.shape);
        }
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += self.data[i * k + p] as f64 * other.data[p * n + j] as f64;
                }
                out[i * n + j] = acc as f32;
            }
        }
        Ok(Tensor::new(out, vec![m, n]))
    }

    // ---- binary IO ---------------------------------------------------------
    // Simple self-describing format: magic "FT32", rank, dims (u64 LE), data.

    /// Write the `FT32` container (magic, rank, dims, LE f32 data).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut buf = Vec::with_capacity(16 + self.numel() * 4);
        buf.extend_from_slice(b"FT32");
        buf.extend_from_slice(&(self.rank() as u32).to_le_bytes());
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &self.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Read an `FT32` container, validating rank and length.
    pub fn load(path: &std::path::Path) -> Result<Tensor> {
        let buf = std::fs::read(path)?;
        if buf.len() < 8 || &buf[..4] != b"FT32" {
            bail!("{}: not an FT32 tensor file", path.display());
        }
        let rank = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
        let mut off = 8;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u64::from_le_bytes(buf[off..off + 8].try_into()?) as usize);
            off += 8;
        }
        let numel: usize = shape.iter().product();
        if buf.len() != off + numel * 4 {
            bail!("{}: truncated tensor file", path.display());
        }
        let data = buf[off..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Tensor::new(data, shape))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.mat_dims().unwrap(), (2, 3));
        assert_eq!(Tensor::scalar(5.0).rank(), 0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![1.0; 5], vec![2, 3]);
    }

    #[test]
    fn reshape_and_lead() {
        let t = Tensor::zeros(&[4, 2, 8]).reshape(&[2, 2, 2, 8]).unwrap();
        assert_eq!(t.lead(), 4);
        assert!(Tensor::zeros(&[4]).reshape(&[5]).is_err());
    }

    #[test]
    fn index_roundtrip() {
        let mut t = Tensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]);
        let row1 = t.index0(1);
        assert_eq!(row1.data, vec![4.0, 5.0, 6.0, 7.0]);
        t.set_index0(0, &row1);
        assert_eq!(t.index0(0).data, row1.data);
    }

    #[test]
    fn stack() {
        let a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        let s = Tensor::stack(&[a, b]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        assert_eq!(s.index0(1).data, vec![2.0; 4]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let eye = Tensor::new(vec![1.0, 0.0, 0.0, 1.0], vec![2, 2]);
        assert_eq!(a.matmul(&eye).unwrap().data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let b = Tensor::new(vec![1.0, 1.0, 1.0, 1.0], vec![2, 2]);
        assert_eq!(a.matmul(&b).unwrap().data, vec![3.0, 3.0, 7.0, 7.0]);
        assert!(a.matmul(&Tensor::zeros(&[3, 2])).is_err());
    }

    #[test]
    fn io_roundtrip() {
        let t = Tensor::new(vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE], vec![2, 2]);
        let dir = std::env::temp_dir().join(format!("faar_t_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.ft32");
        t.save(&p).unwrap();
        assert_eq!(Tensor::load(&p).unwrap(), t);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("faar_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ft32");
        std::fs::write(&p, b"nope").unwrap();
        assert!(Tensor::load(&p).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zip_map_absmax() {
        let a = Tensor::new(vec![1.0, -3.0], vec![2]);
        let b = Tensor::new(vec![2.0, 2.0], vec![2]);
        assert_eq!(a.zip(&b, |x, y| x * y).data, vec![2.0, -6.0]);
        assert_eq!(a.map(|x| x + 1.0).data, vec![2.0, -2.0]);
        assert_eq!(a.abs_max(), 3.0);
    }
}
