//! Continuous-batching scheduler: the single thread that owns the model
//! backend and turns a bounded queue of decode requests into micro-batched
//! decode steps.
//!
//! ```text
//!  readers ──▶ bounded request queue ──▶ scheduler ──▶ per-conn writer queues
//!  (1/conn)    (sync_channel, depth=Q)   (this file)   (bounded, reordered)
//! ```
//!
//! Invariants:
//!
//! * **Token identity** — a request decodes to exactly the tokens the
//!   sequential path produces, because every step goes through the same
//!   [`decode_step`] core and logits row `i` depends only on slot `i`.
//! * **Continuous batching** — new requests are admitted between steps
//!   (never mid-step) up to `max_batch`; finished slots retire
//!   immediately, so a short request never waits for a long neighbour to
//!   finish, only for its next step boundary.
//! * **Backpressure without starvation** — the request queue and the
//!   writer queues are bounded; *readers* block when the request queue
//!   fills (per-connection backpressure). The scheduler itself never
//!   blocks on a client: a connection whose writer queue is full has
//!   queue-depth unread responses outstanding and is force-disconnected
//!   rather than allowed to wedge every other connection.
//! * **Isolation** — a backend failure fails the in-flight requests with
//!   a structured error; the scheduler itself keeps serving.
//! * **Bounded prefill** (`--prefill-chunk-tokens`) — a long prompt no
//!   longer rides into its first decode step whole. Admission marks the
//!   slot *prefilling*; each scheduler iteration spends at most a fixed
//!   token budget on [`StepBackend::prefill_chunk`] calls (FIFO across
//!   prefilling slots) and then decodes a micro-batch of only the slots
//!   whose prompts are fully cached — so a 4k-token prompt costs each
//!   streaming neighbour a chunk of prefill per token, not the whole
//!   prompt at once. Chunking never changes tokens: the backend's next
//!   step simply finds more of the window already cached.

use std::collections::{HashMap, HashSet};
use std::net::{Shutdown, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batch::{decode_step, CacheStats, DecodeSlot, StepBackend};
use super::codec::CodecKind;
use super::sampling::GenParams;
use super::spec::{ModelQueueStats, SpecStats};

/// Which wire transport the serve listener speaks.
///
/// Both transports feed the identical scheduler/admission loop — the
/// transport only decides how bytes become frames (see
/// [`super::codec`]) and how responses are framed back.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Transport {
    /// newline-delimited JSON over raw TCP (the reference protocol)
    #[default]
    Tcp,
    /// HTTP/1.1 `POST /v1/generate`, with `"stream": true` mapped to
    /// server-sent events
    Http,
    /// per-connection sniffing: a leading HTTP method token selects
    /// HTTP, anything else falls back to TCP-JSONL — lets HTTP and
    /// JSONL clients share one listener
    Auto,
}

impl Transport {
    /// Parses a `--transport` CLI value.
    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "tcp" => Some(Transport::Tcp),
            "http" => Some(Transport::Http),
            "auto" => Some(Transport::Auto),
            _ => None,
        }
    }

    /// The CLI spelling of this transport.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Tcp => "tcp",
            Transport::Http => "http",
            Transport::Auto => "auto",
        }
    }
}

/// Shared server lifecycle state: the drain flag every layer watches.
///
/// One `Arc<Lifecycle>` is threaded through [`ServeOptions`] to the
/// acceptor (stop accepting), the HTTP front end (`/readyz` flips to
/// 503), the admission path (new work answers `shutting_down`), and the
/// scheduler (in-flight slots get `--drain-timeout-ms` to finish, then
/// evict with a structured error). `begin_drain` is safe to call from a
/// signal handler's watcher thread or a test.
#[derive(Debug, Default)]
pub struct Lifecycle {
    draining: AtomicBool,
    /// when the drain began (set exactly once, by the first `begin_drain`)
    since: Mutex<Option<Instant>>,
}

impl Lifecycle {
    /// A fresh, non-draining lifecycle.
    pub fn new() -> Lifecycle {
        Lifecycle::default()
    }

    /// Flip the server into draining mode (idempotent): the acceptor
    /// stops accepting, `/readyz` reports 503, and in-flight requests
    /// get the configured drain timeout to finish.
    pub fn begin_drain(&self) {
        let mut since = self.since.lock().unwrap_or_else(|e| e.into_inner());
        if since.is_none() {
            *since = Some(Instant::now());
        }
        drop(since);
        self.draining.store(true, Ordering::SeqCst);
    }

    /// True once `begin_drain` has been called.
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// When the drain began (`None` while the server is live).
    pub fn drain_started(&self) -> Option<Instant> {
        *self.since.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// How long the server has been draining (`None` while live).
    pub fn drain_elapsed(&self) -> Option<Duration> {
        self.drain_started().map(|t| t.elapsed())
    }
}

/// Serving engine knobs (`faar serve --max-batch 16 --queue-depth 128 ...`).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// micro-batch ceiling for one scheduler step
    pub max_batch: usize,
    /// bounded request-queue depth (readers block when full)
    pub queue_depth: usize,
    /// server-side cap on a request's `max_tokens` (requests are clamped)
    pub max_tokens_cap: usize,
    /// reject request lines longer than this many bytes
    pub max_line_bytes: usize,
    /// per-connection read timeout in ms; 0 disables
    pub read_timeout_ms: u64,
    /// max concurrently served connections (accept blocks beyond this)
    pub workers: usize,
    /// generation parameters applied when a request carries no `params`
    /// object (v1 lines, or v2 requests relying on server defaults) —
    /// `faar serve --temperature 0.8 --top-p 0.9`; greedy by default
    pub defaults: GenParams,
    /// per-scheduler-iteration prompt-token budget for chunked prefill
    /// (`--prefill-chunk-tokens`); 0 disables chunking and prompts
    /// prefill whole inside their first decode step, as before
    pub prefill_chunk_tokens: usize,
    /// wire transport on the listener (`--transport tcp|http|auto`)
    pub transport: Transport,
    /// frame decoder for JSONL connections (`--codec line|incremental`);
    /// HTTP bodies always use the incremental decoder
    pub codec: CodecKind,
    /// names of the hosted models (`--models a=nano,b=tiny`); empty in
    /// single-model mode, where requests must not carry a `"model"`
    /// field naming anything (the protocol layer rejects unknown names
    /// with a structured `unknown_model` error before admission)
    pub models: Vec<String>,
    /// deadline applied to requests that carry no `"deadline_ms"` field
    /// (`--default-deadline-ms`); 0 = no default deadline
    pub default_deadline_ms: u64,
    /// admission-time load shedding (`--max-queue-wait-ms`): a request
    /// that waited longer than this in the bounded queue is rejected
    /// with a structured `overloaded` error (HTTP 503 + `Retry-After`)
    /// instead of decoding late; 0 disables shedding
    pub max_queue_wait_ms: u64,
    /// graceful-drain budget (`--drain-timeout-ms`): once draining,
    /// in-flight requests get this long to finish before they are
    /// evicted with a structured `shutting_down` error
    pub drain_timeout_ms: u64,
    /// shared lifecycle (drain) state; `Arc` so the acceptor, readers,
    /// the HTTP health endpoints, the scheduler, and a signal-watcher
    /// thread all observe one flag
    pub lifecycle: Arc<Lifecycle>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            max_batch: 8,
            queue_depth: 64,
            max_tokens_cap: 256,
            max_line_bytes: 64 * 1024,
            read_timeout_ms: 30_000,
            workers: 64,
            defaults: GenParams::default(),
            prefill_chunk_tokens: 0,
            transport: Transport::Tcp,
            codec: CodecKind::Line,
            models: Vec::new(),
            default_deadline_ms: 0,
            max_queue_wait_ms: 0,
            drain_timeout_ms: 5_000,
            lifecycle: Arc::new(Lifecycle::new()),
        }
    }
}

impl ServeOptions {
    /// Reject nonsensical knob values with a structured error at
    /// configuration time — `serve_on` calls this before binding
    /// anything, and `main.rs` calls it at CLI parse time, so a bad
    /// `--max-batch 0` fails the command instead of panicking (or
    /// silently clamping) deep inside the engine.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("--max-batch must be >= 1");
        }
        if self.queue_depth == 0 {
            bail!("--queue-depth must be >= 1");
        }
        if self.workers == 0 {
            bail!("--workers must be >= 1");
        }
        if self.max_line_bytes < 2 {
            bail!("--max-line-bytes must be >= 2 (one byte plus the newline)");
        }
        Ok(())
    }
}

/// A structured protocol error: `code` is machine-matchable, `message`
/// human-readable. Serialized as `{"error":{"code":...,"message":...}}`.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeError {
    /// machine-matchable error class (`bad_json`, `backend`, ...)
    pub code: &'static str,
    /// human-readable detail
    pub message: String,
    /// how long the client should wait before retrying, for pre-admission
    /// rejections (`overloaded`); serialized into the error body and, on
    /// HTTP, into a `Retry-After` header
    pub retry_after_ms: Option<u64>,
}

impl ServeError {
    /// A structured error from a code and message.
    pub fn new(code: &'static str, message: impl Into<String>) -> ServeError {
        ServeError { code, message: message.into(), retry_after_ms: None }
    }

    /// Attach a retry hint (pre-admission rejections only — a request
    /// that may already have executed must never invite a retry).
    pub fn with_retry_after(mut self, ms: u64) -> ServeError {
        self.retry_after_ms = Some(ms);
        self
    }
}

/// A validated request on its way to the scheduler.
#[derive(Debug)]
pub struct DecodeRequest {
    /// connection the request arrived on
    pub conn: u64,
    /// per-connection sequence number (writers restore request order)
    pub seq: u64,
    /// validated prompt token ids
    pub prompt: Vec<i32>,
    /// tokens to decode (already clamped to the server cap)
    pub max_tokens: usize,
    /// validated generation parameters (defaults merged in by the
    /// protocol layer)
    pub params: GenParams,
    /// emit incremental token frames while the request decodes
    pub stream: bool,
    /// hosted model the request targets (`None` = the default model);
    /// validated against the hosted set by the protocol layer, re-checked
    /// by [`StepBackend::bind_model`] at admission as the backstop
    pub model: Option<String>,
    /// when the reader enqueued the request (latency accounting)
    pub enqueued: Instant,
    /// wall-clock budget from enqueue to completion, validated by the
    /// protocol layer (request `"deadline_ms"` merged with the server's
    /// `--default-deadline-ms`); `None` = no deadline. Expired requests
    /// are rejected at admission or evicted mid-decode with a structured
    /// `deadline_exceeded` error, their backend state released
    pub deadline_ms: Option<u64>,
}

/// A finished decode, ready for the protocol layer to serialize.
#[derive(Debug)]
pub struct Decoded {
    /// the decoded continuation
    pub tokens: Vec<i32>,
    /// request-to-completion wall time
    pub latency_ms: f64,
    /// time spent waiting in the request queue before the first step
    pub queue_ms: f64,
}

/// What flows into a per-connection writer thread.
#[derive(Debug)]
pub enum WriterMsg {
    /// One incremental token frame of a streaming request. Frames for a
    /// given `seq` arrive in `index` order and always precede that
    /// request's terminal [`WriterMsg::Resp`].
    Frame {
        /// reader-assigned per-connection sequence number
        seq: u64,
        /// zero-based position of the token in the request's output
        index: usize,
        /// the decoded token
        token: i32,
    },
    /// One response, tagged with its request sequence number.
    Resp {
        /// reader-assigned per-connection sequence number
        seq: u64,
        /// the decode result (or a structured rejection)
        result: Result<Decoded, ServeError>,
    },
    /// The reader is gone: exactly `next_seq` requests were issued on
    /// this connection; the writer exits once all of them are written.
    Done {
        /// total requests issued on the connection
        next_seq: u64,
    },
    /// Switches the writer to HTTP response framing. Sent once by the
    /// reader after transport selection (forced or sniffed), causally
    /// before any request can reach the scheduler, so the writer never
    /// frames a response for this connection the wrong way.
    Http,
    /// Declares request `seq`'s streaming mode before it enters the
    /// scheduler (HTTP readers only: `sse` selects server-sent-events
    /// framing for that request's frames and terminal).
    Mode {
        /// reader-assigned per-connection sequence number
        seq: u64,
        /// frame this request's output as an SSE event stream
        sse: bool,
    },
    /// A pre-rendered response (the HTTP health endpoints) that must
    /// still flow through the per-connection reorder queue so pipelined
    /// requests are answered strictly in request order.
    Raw {
        /// reader-assigned per-connection sequence number
        seq: u64,
        /// the exact bytes to write (a full HTTP response)
        body: String,
    },
}

/// One registered connection: the writer queue plus a handle to force
/// the socket shut if the connection stops draining responses.
struct ConnEntry {
    tx: SyncSender<WriterMsg>,
    stream: Option<TcpStream>,
    /// request seqs the client asked to cancel (`{"cancel": seq}`),
    /// consumed by the scheduler at the next step boundary
    cancels: HashSet<u64>,
}

/// Routes scheduler responses back to connection writers. Connections
/// register on accept and unregister when their writer exits, which also
/// cancels their in-flight slots at the next step boundary.
#[derive(Default)]
pub struct Registry {
    conns: Mutex<HashMap<u64, ConnEntry>>,
    cv: Condvar,
}

impl Registry {
    /// The connection map, with explicit poison recovery: a reader or
    /// writer thread that panics while holding this lock (e.g. a bug in
    /// a codec, or a chaos-injected panic crossing a channel) must not
    /// wedge every other connection. Every critical section over the map
    /// performs a single insert/remove/lookup, so the map is valid at
    /// every panic point and recovering the guard is sound.
    fn locked(&self) -> MutexGuard<'_, HashMap<u64, ConnEntry>> {
        self.conns.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// `stream` (a clone of the connection socket) lets the scheduler
    /// force-disconnect a client whose writer queue stopped draining;
    /// `None` is fine for in-process tests.
    pub fn register(&self, conn: u64, tx: SyncSender<WriterMsg>, stream: Option<TcpStream>) {
        self.locked().insert(conn, ConnEntry { tx, stream, cancels: HashSet::new() });
    }

    /// Remove a connection (its in-flight slots cancel at the next step).
    pub fn unregister(&self, conn: u64) {
        self.locked().remove(&conn);
        self.cv.notify_all();
    }

    /// True while `conn` is registered.
    pub fn contains(&self, conn: u64) -> bool {
        self.locked().contains_key(&conn)
    }

    /// Live connection count.
    pub fn len(&self) -> usize {
        self.locked().len()
    }

    /// True when no connections are live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record a client-side cancellation of request `seq` on `conn`
    /// (`{"cancel": seq}` control frame). The scheduler consumes it with
    /// [`Registry::take_cancel`] at the next step boundary — before the
    /// request's first step if it has not been admitted yet, mid-decode
    /// otherwise. The per-connection set is capped so a client spamming
    /// cancel frames for never-issued seqs cannot grow memory unboundedly.
    pub fn request_cancel(&self, conn: u64, seq: u64) {
        let mut conns = self.locked();
        if let Some(e) = conns.get_mut(&conn) {
            if e.cancels.len() < 1024 {
                e.cancels.insert(seq);
            }
        }
    }

    /// Consume a pending cancellation for (`conn`, `seq`), returning
    /// whether one was recorded. Consuming is what makes cancellation
    /// exactly-once: admission and the in-flight sweep both check, but
    /// only one of them can observe the entry.
    pub fn take_cancel(&self, conn: u64, seq: u64) -> bool {
        let mut conns = self.locked();
        conns.get_mut(&conn).map(|e| e.cancels.remove(&seq)).unwrap_or(false)
    }

    fn sender(&self, conn: u64) -> Option<SyncSender<WriterMsg>> {
        self.locked().get(&conn).map(|e| e.tx.clone())
    }

    /// Unregister and shut the socket down, unblocking a writer stuck in
    /// `write_all` to a client that stopped reading.
    fn force_disconnect(&self, conn: u64) {
        let entry = self.locked().remove(&conn);
        if let Some(e) = entry {
            if let Some(s) = e.stream {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        self.cv.notify_all();
    }

    /// Drop every connection at once: the drain deadline passed and the
    /// remaining clients already received their structured terminals (or
    /// stopped reading). Socket shutdown unblocks any reader or writer
    /// thread still parked in I/O so the process can exit.
    fn force_disconnect_all(&self) {
        let mut conns = self.locked();
        for (_, e) in conns.drain() {
            if let Some(s) = e.stream {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        drop(conns);
        self.cv.notify_all();
    }

    /// Block until fewer than `n` connections are live (the acceptor's
    /// `--workers` admission control).
    pub fn wait_below(&self, n: usize) {
        let mut conns = self.locked();
        while conns.len() >= n.max(1) {
            conns = self.cv.wait(conns).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Counters the engine reports when it exits (tests assert on these).
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    /// decode steps executed
    pub steps: u64,
    /// steps that carried more than one slot
    pub batched_steps: u64,
    /// requests answered successfully
    pub completed: u64,
    /// responses dropped because the connection was gone
    pub cancelled: u64,
    /// requests failed by a backend error
    pub errors: u64,
    /// requests rejected at admission by overload shedding
    /// (`--max-queue-wait-ms` exceeded, structured `overloaded`)
    pub shed: u64,
    /// requests rejected or evicted because their deadline expired
    /// (structured `deadline_exceeded`)
    pub deadline_evictions: u64,
    /// requests rejected or evicted by the graceful drain (structured
    /// `shutting_down`)
    pub drain_evictions: u64,
    /// backend panics caught and converted to structured `backend_panic`
    /// errors (the scheduler survived each one)
    pub backend_panics: u64,
    /// largest micro-batch seen
    pub peak_batch: usize,
    /// `prefill_chunk` calls issued by the chunked-prefill budget loop
    pub prefill_chunks: u64,
    /// prompt tokens prefilled through the budget loop (cache-attached
    /// tokens count too — they consumed budget headroom)
    pub prefill_tokens: u64,
    /// total chunk-token budget offered across iterations that had at
    /// least one prefilling slot — the denominator of
    /// [`Self::budget_utilization`]
    pub budget_tokens: u64,
    /// backend cache/pool counters ([`StepBackend::cache_stats`]),
    /// captured when the engine drains
    pub cache: CacheStats,
    /// speculative-decoding counters ([`StepBackend::spec_stats`]),
    /// captured when the engine drains; all-zero when the backend does
    /// not speculate
    pub spec: SpecStats,
    /// per-model admission/completion/queue-depth counters
    /// ([`StepBackend::model_queue_stats`]), captured when the engine
    /// drains; empty for single-model backends
    pub model_queues: Vec<ModelQueueStats>,
}

impl SchedStats {
    /// Fraction of prefix-cache lookups that attached at least one
    /// cached page (0.0 when the cache was off or never consulted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.cache.prefix_lookups == 0 {
            0.0
        } else {
            self.cache.prefix_hits as f64 / self.cache.prefix_lookups as f64
        }
    }

    /// Fraction of the offered chunked-prefill token budget actually
    /// spent (0.0 when chunking was off or never engaged).
    pub fn budget_utilization(&self) -> f64 {
        if self.budget_tokens == 0 {
            0.0
        } else {
            self.prefill_tokens as f64 / self.budget_tokens as f64
        }
    }
}

struct SlotMeta {
    conn: u64,
    seq: u64,
    enqueued: Instant,
    started: Instant,
    /// emit per-token frames while decoding
    stream: bool,
    /// output tokens already sent as frames
    sent: usize,
    /// `Some(n)` while the slot is still prefilling its prompt through
    /// the chunked budget loop (`n` = prompt tokens the scheduler
    /// believes are missing); `None` once the slot decodes
    missing: Option<usize>,
    /// absolute completion deadline (enqueue time + the request's
    /// deadline budget); checked every step boundary
    deadline: Option<Instant>,
}

/// Render a `catch_unwind` payload into the structured error message
/// (panics carry a `&str` or `String` in practice; anything else is
/// reported opaquely).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run the scheduler until the request queue disconnects (all readers and
/// the acceptor are gone) and every in-flight slot has drained. Never
/// returns in serve-forever mode.
pub fn run<B: StepBackend + ?Sized>(
    backend: &B,
    rx: Receiver<DecodeRequest>,
    registry: &Registry,
    opts: &ServeOptions,
) -> Result<SchedStats> {
    let seq_len = backend.seq_len();
    let max_batch = opts.max_batch.max(1);
    let chunk = opts.prefill_chunk_tokens;
    let mut stats = SchedStats::default();
    // `slots` and `meta` move in lockstep (same index = same request)
    let mut slots: Vec<DecodeSlot> = Vec::new();
    let mut meta: Vec<SlotMeta> = Vec::new();

    loop {
        // admit up to max_batch; block (with a short tick, so drain
        // deadlines fire while idle) only when fully idle
        while slots.len() < max_batch {
            let req = if slots.is_empty() {
                match rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(r) => r,
                    Err(RecvTimeoutError::Timeout) => {
                        // nothing in flight: once the drain budget is
                        // spent, shut the remaining idle connections so
                        // their readers exit and the queue can close
                        if let Some(elapsed) = opts.lifecycle.drain_elapsed() {
                            if elapsed >= Duration::from_millis(opts.drain_timeout_ms) {
                                registry.force_disconnect_all();
                            }
                        }
                        break;
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        // queue closed, nothing in flight
                        stats.cache = backend.cache_stats().unwrap_or_default();
                        stats.spec = backend.spec_stats().unwrap_or_default();
                        stats.model_queues = backend.model_queue_stats();
                        return Ok(stats);
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(r) => r,
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            };
            admit(backend, req, seq_len, chunk, registry, opts, &mut slots, &mut meta, &mut stats);
        }
        stats.peak_batch = stats.peak_batch.max(slots.len());

        // cancel slots whose connection already went away — including
        // one admitted and dropped before its first step. The backend is
        // told on every cancellation so per-slot state (KV cache pages)
        // is freed instead of leaking for the life of the process. An
        // explicit `{"cancel": seq}` control frame evicts its slot here
        // too, mid-decode, but (unlike a disconnect) gets a structured
        // `cancelled` response back.
        for i in (0..slots.len()).rev() {
            if !registry.contains(meta[i].conn) {
                let slot = slots.swap_remove(i);
                backend.release(&slot);
                meta.swap_remove(i);
                stats.cancelled += 1;
            } else if registry.take_cancel(meta[i].conn, meta[i].seq) {
                let slot = slots.swap_remove(i);
                backend.release(&slot);
                let m = meta.swap_remove(i);
                let err = ServeError::new("cancelled", "request cancelled by client");
                let _ = respond(registry, m.conn, m.seq, Err(err));
                stats.cancelled += 1;
            }
        }

        // evict slots whose deadline expired mid-decode: the client gets
        // a structured `deadline_exceeded` terminal and the backend
        // releases the slot's pages here, exactly once (the same release
        // path every other eviction takes)
        let now = Instant::now();
        for i in (0..slots.len()).rev() {
            let Some(deadline) = meta[i].deadline else { continue };
            if now < deadline {
                continue;
            }
            let slot = slots.swap_remove(i);
            release_contained(backend, &slot, &mut stats);
            let m = meta.swap_remove(i);
            let err = ServeError::new(
                "deadline_exceeded",
                format!("deadline expired after {} decoded tokens", slot.out.len()),
            );
            let _ = respond(registry, m.conn, m.seq, Err(err));
            stats.deadline_evictions += 1;
        }

        // drain-timeout eviction: in-flight requests had their chance to
        // finish; the rest end with a structured `shutting_down` so no
        // client is left hanging when the process exits
        if let Some(elapsed) = opts.lifecycle.drain_elapsed() {
            if elapsed >= Duration::from_millis(opts.drain_timeout_ms) && !slots.is_empty() {
                let err = ServeError::new(
                    "shutting_down",
                    "server draining: drain timeout reached before completion",
                );
                for (slot, m) in slots.drain(..).zip(meta.drain(..)) {
                    release_contained(backend, &slot, &mut stats);
                    let _ = respond(registry, m.conn, m.seq, Err(err.clone()));
                    stats.drain_evictions += 1;
                }
                continue;
            }
        }
        if slots.is_empty() {
            continue;
        }

        // spend this iteration's prefill-token budget, FIFO across the
        // slots still prefilling: attached prefixes and chunked prompt
        // work both draw it down, and a slot whose backend reports no
        // progress graduates immediately so the budget loop can never
        // livelock (the decode step's uncached path absorbs whatever the
        // chunker could not cache).
        if chunk > 0 {
            let mut left = chunk;
            let mut offered = false;
            let mut fail = None;
            for i in 0..slots.len() {
                if left == 0 {
                    break;
                }
                let Some(miss) = meta[i].missing else { continue };
                offered = true;
                stats.prefill_chunks += 1;
                let caught = catch_unwind(AssertUnwindSafe(|| {
                    backend.prefill_chunk(&slots[i], left)
                }));
                match caught {
                    Ok(Ok(now_missing)) => {
                        let consumed = miss.saturating_sub(now_missing).min(left);
                        left -= consumed;
                        stats.prefill_tokens += consumed as u64;
                        meta[i].missing = if consumed == 0 {
                            None
                        } else {
                            (now_missing > 0).then_some(now_missing)
                        };
                    }
                    Ok(Err(e)) => {
                        fail = Some(ServeError::new(
                            "backend",
                            format!("prefill chunk failed: {e:#}"),
                        ));
                        break;
                    }
                    Err(payload) => {
                        // a panicking backend is contained exactly like
                        // an erroring one, just under its own code
                        stats.backend_panics += 1;
                        fail = Some(ServeError::new(
                            "backend_panic",
                            format!(
                                "backend panicked in prefill_chunk: {}",
                                panic_message(payload.as_ref())
                            ),
                        ));
                        break;
                    }
                }
            }
            if offered {
                stats.budget_tokens += chunk as u64;
            }
            if let Some(err) = fail {
                // same isolation policy as a failed decode step: fail
                // every in-flight request, keep serving
                for (slot, m) in slots.drain(..).zip(meta.drain(..)) {
                    release_contained(backend, &slot, &mut stats);
                    if respond(registry, m.conn, m.seq, Err(err.clone())) {
                        stats.errors += 1;
                    } else {
                        stats.cancelled += 1;
                    }
                }
                continue;
            }
        }

        // decode only the slots that finished prefilling: stable-partition
        // them to the front (lockstep with meta) so decode_step still sees
        // one contiguous slice
        let mut active = 0;
        for i in 0..slots.len() {
            if meta[i].missing.is_none() {
                slots.swap(active, i);
                meta.swap(active, i);
                active += 1;
            }
        }
        if active == 0 {
            continue;
        }

        stats.steps += 1;
        if active > 1 {
            stats.batched_steps += 1;
        }
        // backends that speculate (a registry hosting a draft-paired
        // model) advance every slot through their own draft/verify step;
        // everything else takes the plain decode path. Both run inside
        // `catch_unwind`: a panicking backend must cost exactly the
        // in-flight requests (structured `backend_panic`, pages
        // released), never the scheduler thread — writers, readers, and
        // the other hosted models keep serving
        let caught = catch_unwind(AssertUnwindSafe(|| {
            match backend.spec_step(&mut slots[..active]) {
                Some(r) => r,
                None => decode_step(backend, &mut slots[..active]),
            }
        }));
        let failure = match caught {
            Ok(Ok(())) => None,
            Ok(Err(e)) => {
                Some(ServeError::new("backend", format!("decode step failed: {e:#}")))
            }
            Err(payload) => {
                stats.backend_panics += 1;
                Some(ServeError::new(
                    "backend_panic",
                    format!(
                        "backend panicked during decode step: {}",
                        panic_message(payload.as_ref())
                    ),
                ))
            }
        };
        if let Some(err) = failure {
            // fail the in-flight requests, keep the server up (each
            // request lands in exactly one of errors/cancelled); every
            // failed slot is released so backend state never outlives it
            for (slot, m) in slots.drain(..).zip(meta.drain(..)) {
                release_contained(backend, &slot, &mut stats);
                if respond(registry, m.conn, m.seq, Err(err.clone())) {
                    stats.errors += 1;
                } else {
                    stats.cancelled += 1;
                }
            }
            continue;
        }

        // stream newly decoded tokens before retiring anything, so a
        // request's final frame always precedes its terminal response.
        // A failed frame send means the connection is gone — stop
        // streaming it; the cancellation sweep reaps the slot next tick.
        for (slot, m) in slots.iter().zip(meta.iter_mut()) {
            while m.stream && m.sent < slot.out.len() {
                if !send_frame(registry, m.conn, m.seq, m.sent, slot.out[m.sent]) {
                    m.stream = false;
                    break;
                }
                m.sent += 1;
            }
        }

        // retire finished slots immediately (continuous batching)
        for i in (0..slots.len()).rev() {
            if slots[i].done() {
                let slot = slots.swap_remove(i);
                backend.release(&slot);
                let m = meta.swap_remove(i);
                let now = Instant::now();
                let decoded = Decoded {
                    tokens: slot.out,
                    latency_ms: (now - m.enqueued).as_secs_f64() * 1e3,
                    queue_ms: (m.started - m.enqueued).as_secs_f64() * 1e3,
                };
                if respond(registry, m.conn, m.seq, Ok(decoded)) {
                    stats.completed += 1;
                } else {
                    stats.cancelled += 1;
                }
            }
        }
    }
}

/// Release a slot's backend state with the same panic containment the
/// step path gets: a backend whose `release` panics (e.g. mid-poisoned
/// internal state after an injected panic) must not take the scheduler
/// down while it is cleaning up.
fn release_contained<B: StepBackend + ?Sized>(
    backend: &B,
    slot: &DecodeSlot,
    stats: &mut SchedStats,
) {
    if catch_unwind(AssertUnwindSafe(|| backend.release(slot))).is_err() {
        stats.backend_panics += 1;
        crate::warn!("backend panicked in release for slot {}", slot.id);
    }
}

#[allow(clippy::too_many_arguments)]
fn admit<B: StepBackend + ?Sized>(
    backend: &B,
    req: DecodeRequest,
    seq_len: usize,
    chunk: usize,
    registry: &Registry,
    opts: &ServeOptions,
    slots: &mut Vec<DecodeSlot>,
    meta: &mut Vec<SlotMeta>,
    stats: &mut SchedStats,
) {
    let started = Instant::now();
    if registry.take_cancel(req.conn, req.seq) {
        // cancelled before admission: never touches the backend
        let err = ServeError::new("cancelled", "request cancelled by client");
        let _ = respond(registry, req.conn, req.seq, Err(err));
        stats.cancelled += 1;
        return;
    }
    // graceful drain: work that arrived before the drain began is
    // in-flight and gets its chance; anything enqueued after is
    // rejected up front so clients fail over fast
    if let Some(drain_start) = opts.lifecycle.drain_started() {
        if req.enqueued >= drain_start {
            let err =
                ServeError::new("shutting_down", "server draining: not accepting new requests");
            let _ = respond(registry, req.conn, req.seq, Err(err));
            stats.drain_evictions += 1;
            return;
        }
    }
    // admission-time load shedding: a request that already waited past
    // the queue-wait bound would only decode late and crowd out fresher
    // work — reject it now with a retry hint instead
    if opts.max_queue_wait_ms > 0 {
        let waited = started.duration_since(req.enqueued);
        if waited > Duration::from_millis(opts.max_queue_wait_ms) {
            let err = ServeError::new(
                "overloaded",
                format!("request waited {}ms in queue (shed)", waited.as_millis()),
            )
            .with_retry_after(opts.max_queue_wait_ms.max(1));
            let _ = respond(registry, req.conn, req.seq, Err(err));
            stats.shed += 1;
            return;
        }
    }
    // deadline accounting starts at enqueue; a request whose budget is
    // already spent never touches the backend
    let deadline = req.deadline_ms.map(|ms| req.enqueued + Duration::from_millis(ms));
    if let Some(d) = deadline {
        if started >= d {
            let err =
                ServeError::new("deadline_exceeded", "deadline expired before admission");
            let _ = respond(registry, req.conn, req.seq, Err(err));
            stats.deadline_evictions += 1;
            return;
        }
    }
    if req.max_tokens == 0 {
        // nothing to decode; complete immediately (still a valid request)
        let decoded = Decoded {
            tokens: vec![],
            latency_ms: (started - req.enqueued).as_secs_f64() * 1e3,
            queue_ms: (started - req.enqueued).as_secs_f64() * 1e3,
        };
        if respond(registry, req.conn, req.seq, Ok(decoded)) {
            stats.completed += 1;
        } else {
            stats.cancelled += 1;
        }
        return;
    }
    match DecodeSlot::with_params(&req.prompt, req.max_tokens, seq_len, req.params) {
        Ok(slot) => {
            // route the slot to its model before any backend work; the
            // protocol layer already validated the name, so a failure
            // here is the multi-model backstop (e.g. in-process callers
            // bypassing the wire protocol)
            if let Err(e) = backend.bind_model(&slot, req.model.as_deref()) {
                let err = ServeError::new("unknown_model", e.to_string());
                if respond(registry, req.conn, req.seq, Err(err)) {
                    stats.errors += 1;
                } else {
                    stats.cancelled += 1;
                }
                return;
            }
            // prompts longer than one chunk enter the budget loop; short
            // ones (and everything when chunking is off) prefill whole
            // inside their first decode step as before
            let win = slot.window().len();
            let missing = (chunk > 0 && win.saturating_sub(1) > chunk).then_some(win - 1);
            slots.push(slot);
            meta.push(SlotMeta {
                conn: req.conn,
                seq: req.seq,
                enqueued: req.enqueued,
                started,
                stream: req.stream,
                sent: 0,
                missing,
                deadline,
            });
        }
        // the protocol layer validates first; this is the backstop
        // (each request lands in exactly one of errors/cancelled)
        Err(e) => {
            let err = ServeError::new("bad_request", e.to_string());
            if respond(registry, req.conn, req.seq, Err(err)) {
                stats.errors += 1;
            } else {
                stats.cancelled += 1;
            }
        }
    }
}

/// Route one response to its connection's writer without ever blocking
/// the scheduler: a missing or closed writer means the client is gone
/// (drop the response); a *full* writer queue means the client has
/// queue-depth responses outstanding and is not reading — keeping the
/// scheduler's single thread alive matters more than that client, so it
/// is force-disconnected (socket shutdown unblocks its writer thread).
/// Returns whether delivery succeeded.
fn respond(
    registry: &Registry,
    conn: u64,
    seq: u64,
    result: Result<Decoded, ServeError>,
) -> bool {
    deliver(registry, conn, WriterMsg::Resp { seq, result })
}

/// Route one streaming token frame to its connection's writer under the
/// same never-block policy as [`respond`]: a streaming client that lets
/// queue-depth frames pile up unread is force-disconnected rather than
/// allowed to stall the scheduler.
fn send_frame(registry: &Registry, conn: u64, seq: u64, index: usize, token: i32) -> bool {
    deliver(registry, conn, WriterMsg::Frame { seq, index, token })
}

fn deliver(registry: &Registry, conn: u64, msg: WriterMsg) -> bool {
    match registry.sender(conn) {
        Some(tx) => match tx.try_send(msg) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                crate::warn!(
                    "connection {conn}: writer queue full (client not reading); disconnecting"
                );
                registry.force_disconnect(conn);
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        },
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batch::{generate_greedy, SyntheticBackend};
    use std::sync::mpsc::sync_channel;

    fn req(conn: u64, seq: u64, prompt: Vec<i32>, max_tokens: usize) -> DecodeRequest {
        DecodeRequest {
            conn,
            seq,
            prompt,
            max_tokens,
            params: GenParams::default(),
            stream: false,
            model: None,
            enqueued: Instant::now(),
            deadline_ms: None,
        }
    }

    #[test]
    fn scheduler_drains_and_matches_sequential() {
        let backend = SyntheticBackend::new(32, 8, 3);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(16);
        registry.register(1, w_tx, None);
        let (tx, rx) = sync_channel(16);
        for i in 0..6u64 {
            tx.send(req(1, i, vec![i as i32 + 1, 2], 4 + i as usize)).unwrap();
        }
        drop(tx);
        let opts = ServeOptions { max_batch: 4, ..ServeOptions::default() };
        let stats = run(&backend, rx, &registry, &opts).unwrap();
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.cancelled, 0);
        assert!(stats.batched_steps > 0, "expected micro-batched steps");
        assert!(stats.peak_batch > 1 && stats.peak_batch <= 4);
        let mut got: Vec<(u64, Vec<i32>)> = (0..6)
            .map(|_| match w_rx.recv().unwrap() {
                WriterMsg::Resp { seq, result } => (seq, result.unwrap().tokens),
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        got.sort_by_key(|(s, _)| *s);
        for (i, (seq, tokens)) in got.iter().enumerate() {
            assert_eq!(*seq, i as u64);
            let expect =
                generate_greedy(&backend, &[i as i32 + 1, 2], 4 + i).unwrap();
            assert_eq!(tokens, &expect, "request {i} diverged from sequential decode");
        }
    }

    #[test]
    fn disconnected_conn_slots_are_cancelled() {
        let backend = SyntheticBackend::new(16, 8, 9);
        let registry = Registry::default();
        // conn 7 never registers a writer: its requests cancel
        let (tx, rx) = sync_channel(4);
        tx.send(req(7, 0, vec![1, 2], 50)).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.cancelled, 1);
    }

    #[test]
    fn zero_max_tokens_completes_empty() {
        let backend = SyntheticBackend::new(16, 8, 1);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(2, w_tx, None);
        let (tx, rx) = sync_channel(4);
        tx.send(req(2, 0, vec![3], 0)).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.completed, 1);
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { seq: 0, result } => assert!(result.unwrap().tokens.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn full_writer_queue_forces_disconnect() {
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(1);
        registry.register(9, w_tx, None);
        let ok = Decoded { tokens: vec![], latency_ms: 0.0, queue_ms: 0.0 };
        // first response fills the depth-1 queue (nobody draining)
        assert!(respond(&registry, 9, 0, Ok(ok)));
        // second finds it full: the scheduler must not block — the
        // connection is dropped instead
        let ok = Decoded { tokens: vec![1], latency_ms: 0.0, queue_ms: 0.0 };
        assert!(!respond(&registry, 9, 1, Ok(ok)));
        assert!(!registry.contains(9));
        drop(w_rx);
    }

    /// Wraps the synthetic backend and records which slot ids were
    /// released — the probe for the KV/slot-state leak regressions.
    struct ReleaseProbe {
        inner: SyntheticBackend,
        released: Mutex<Vec<u64>>,
    }

    impl ReleaseProbe {
        fn new(inner: SyntheticBackend) -> ReleaseProbe {
            ReleaseProbe { inner, released: Mutex::new(Vec::new()) }
        }

        fn released(&self) -> Vec<u64> {
            self.released.lock().unwrap().clone()
        }
    }

    impl StepBackend for ReleaseProbe {
        fn vocab(&self) -> usize {
            self.inner.vocab()
        }

        fn seq_len(&self) -> usize {
            self.inner.seq_len()
        }

        fn step(&self, slots: &[DecodeSlot]) -> anyhow::Result<Vec<Vec<f32>>> {
            self.inner.step(slots)
        }

        fn release(&self, slot: &DecodeSlot) {
            self.released.lock().unwrap().push(slot.id);
        }
    }

    #[test]
    fn disconnect_between_admit_and_first_step_releases_slot() {
        // regression: a connection that disappears after its request was
        // admitted but before its first decode step used to leave the
        // slot's backend state (KV pages) stranded — the cancellation
        // path must release it exactly like the completion path does
        let backend = ReleaseProbe::new(SyntheticBackend::new(16, 8, 9));
        let registry = Registry::default();
        // conn 7 never registers a writer: cancelled before any step
        let (tx, rx) = sync_channel(4);
        tx.send(req(7, 0, vec![1, 2], 50)).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(backend.released().len(), 1, "cancelled slot was not released");
    }

    #[test]
    fn completion_and_backend_error_release_every_slot() {
        // completion path: every finished slot is released exactly once
        let backend = ReleaseProbe::new(SyntheticBackend::new(32, 8, 3));
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(16);
        registry.register(1, w_tx, None);
        let (tx, rx) = sync_channel(16);
        for i in 0..3u64 {
            tx.send(req(1, i, vec![i as i32 + 1], 4)).unwrap();
        }
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.completed, 3);
        let released = backend.released();
        assert_eq!(released.len(), 3);
        let mut unique = released.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), 3, "slots must be released exactly once each");
        drop(w_rx);

        // error path: a failing backend still releases the in-flight slot
        struct FailingBackend(ReleaseProbe);
        impl StepBackend for FailingBackend {
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn seq_len(&self) -> usize {
                self.0.seq_len()
            }
            fn step(&self, _slots: &[DecodeSlot]) -> anyhow::Result<Vec<Vec<f32>>> {
                anyhow::bail!("injected backend failure")
            }
            fn release(&self, slot: &DecodeSlot) {
                self.0.release(slot);
            }
        }
        let failing = FailingBackend(ReleaseProbe::new(SyntheticBackend::new(16, 8, 1)));
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(2, w_tx, None);
        let (tx, rx) = sync_channel(4);
        tx.send(req(2, 0, vec![3], 4)).unwrap();
        drop(tx);
        let stats = run(&failing, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.errors, 1);
        assert_eq!(failing.0.released().len(), 1, "failed slot was not released");
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => assert_eq!(e.code, "backend"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn streaming_frames_precede_response_and_concatenate() {
        let backend = SyntheticBackend::new(32, 8, 3);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(64);
        registry.register(1, w_tx, None);
        let (tx, rx) = sync_channel(4);
        tx.send(DecodeRequest {
            conn: 1,
            seq: 0,
            prompt: vec![4, 5],
            max_tokens: 6,
            params: GenParams::default(),
            stream: true,
            model: None,
            enqueued: Instant::now(),
            deadline_ms: None,
        })
        .unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.completed, 1);
        let mut streamed = vec![];
        loop {
            match w_rx.recv().unwrap() {
                WriterMsg::Frame { seq: 0, index, token } => {
                    assert_eq!(index, streamed.len(), "frames must arrive in order");
                    streamed.push(token);
                }
                WriterMsg::Resp { seq: 0, result } => {
                    let tokens = result.unwrap().tokens;
                    assert_eq!(streamed, tokens, "frames must concatenate to the response");
                    assert_eq!(tokens, generate_greedy(&backend, &[4, 5], 6).unwrap());
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn sampled_request_matches_sequential_generate() {
        let backend = SyntheticBackend::new(32, 8, 21);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(16);
        registry.register(1, w_tx, None);
        let params = GenParams { temperature: 0.9, top_k: 8, seed: 77, ..GenParams::default() };
        let (tx, rx) = sync_channel(4);
        tx.send(DecodeRequest {
            conn: 1,
            seq: 0,
            prompt: vec![2, 3],
            max_tokens: 10,
            params: params.clone(),
            stream: false,
            model: None,
            enqueued: Instant::now(),
            deadline_ms: None,
        })
        .unwrap();
        drop(tx);
        run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result, .. } => {
                let expect =
                    crate::serve::batch::generate(&backend, &[2, 3], 10, params).unwrap();
                assert_eq!(result.unwrap().tokens, expect);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn validate_rejects_zero_knobs() {
        assert!(ServeOptions::default().validate().is_ok());
        let bad = [
            ServeOptions { max_batch: 0, ..ServeOptions::default() },
            ServeOptions { queue_depth: 0, ..ServeOptions::default() },
            ServeOptions { workers: 0, ..ServeOptions::default() },
            ServeOptions { max_line_bytes: 1, ..ServeOptions::default() },
        ];
        for opts in bad {
            assert!(opts.validate().is_err(), "expected rejection: {opts:?}");
        }
    }

    #[test]
    fn chunked_prefill_interleaves_and_matches_sequential() {
        let backend = SyntheticBackend::new(32, 64, 3)
            .with_prefill_cost(std::time::Duration::from_micros(2));
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(64);
        registry.register(1, w_tx, None);
        let (tx, rx) = sync_channel(16);
        // one long prompt that must chunk, decoding next to short ones
        let long: Vec<i32> = (0..40).map(|i| (i % 7) + 1).collect();
        tx.send(req(1, 0, long.clone(), 4)).unwrap();
        for i in 1..4u64 {
            tx.send(req(1, i, vec![i as i32, 2], 6)).unwrap();
        }
        drop(tx);
        let opts = ServeOptions {
            max_batch: 4,
            prefill_chunk_tokens: 8,
            ..ServeOptions::default()
        };
        let stats = run(&backend, rx, &registry, &opts).unwrap();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.cancelled, 0);
        // the 40-token prompt has 39 prefill positions: several budgeted
        // chunks, every offered token accounted
        assert!(stats.prefill_chunks >= 5, "expected >= 5 chunks, got {}", stats.prefill_chunks);
        assert_eq!(stats.prefill_tokens, 39);
        assert!(stats.budget_tokens >= stats.prefill_tokens);
        let util = stats.budget_utilization();
        assert!(util > 0.0 && util <= 1.0, "utilization out of range: {util}");
        // chunking must never change tokens: compare against sequential
        // greedy decodes on a cost-free backend with the same seed
        let reference = SyntheticBackend::new(32, 64, 3);
        let mut got: Vec<(u64, Vec<i32>)> = (0..4)
            .map(|_| match w_rx.recv().unwrap() {
                WriterMsg::Resp { seq, result } => (seq, result.unwrap().tokens),
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        got.sort_by_key(|(s, _)| *s);
        assert_eq!(got[0].1, generate_greedy(&reference, &long, 4).unwrap());
        for (i, (_, tokens)) in got.iter().enumerate().skip(1) {
            let expect = generate_greedy(&reference, &[i as i32, 2], 6).unwrap();
            assert_eq!(tokens, &expect, "request {i} diverged under chunked prefill");
        }
    }

    #[test]
    fn empty_prompt_backstop_errors() {
        let backend = SyntheticBackend::new(16, 8, 1);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(3, w_tx, None);
        let (tx, rx) = sync_channel(4);
        tx.send(req(3, 0, vec![], 4)).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.errors, 1);
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => assert_eq!(e.code, "bad_request"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn panicking_backend_is_contained_and_slots_released() {
        // a backend that panics on its first step must cost exactly the
        // in-flight requests (structured backend_panic, slots released)
        // — the scheduler itself survives and drains normally
        struct PanicBackend(ReleaseProbe);
        impl StepBackend for PanicBackend {
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn seq_len(&self) -> usize {
                self.0.seq_len()
            }
            fn step(&self, _slots: &[DecodeSlot]) -> anyhow::Result<Vec<Vec<f32>>> {
                panic!("injected: backend blew up");
            }
            fn release(&self, slot: &DecodeSlot) {
                self.0.release(slot);
            }
        }
        let backend = PanicBackend(ReleaseProbe::new(SyntheticBackend::new(16, 8, 1)));
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(2, w_tx, None);
        let (tx, rx) = sync_channel(4);
        tx.send(req(2, 0, vec![3], 4)).unwrap();
        drop(tx);
        // silence the default panic hook for the injected panic; restore
        // it afterwards so real test failures keep their backtraces
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        std::panic::set_hook(hook);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.backend_panics, 1);
        assert_eq!(backend.0.released().len(), 1, "panicked slot was not released");
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => {
                assert_eq!(e.code, "backend_panic");
                assert!(e.message.contains("backend blew up"), "{}", e.message);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn expired_deadline_rejects_at_admission() {
        let backend = SyntheticBackend::new(16, 8, 1);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(4, w_tx, None);
        let (tx, rx) = sync_channel(4);
        let mut r = req(4, 0, vec![3], 8);
        r.deadline_ms = Some(1);
        r.enqueued = Instant::now() - std::time::Duration::from_millis(50);
        tx.send(r).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.deadline_evictions, 1);
        assert_eq!(stats.completed, 0);
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => assert_eq!(e.code, "deadline_exceeded"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn slow_decode_evicted_mid_flight_by_deadline() {
        // 2ms per step against a 20ms deadline and a 1000-token budget:
        // the deadline sweep must evict mid-decode with pages released
        let backend = ReleaseProbe::new(
            SyntheticBackend::new(16, 8, 1)
                .with_costs(std::time::Duration::from_millis(2), std::time::Duration::ZERO),
        );
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(8);
        registry.register(5, w_tx, None);
        let (tx, rx) = sync_channel(4);
        let mut r = req(5, 0, vec![3], 1000);
        r.deadline_ms = Some(20);
        tx.send(r).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &ServeOptions::default()).unwrap();
        assert_eq!(stats.deadline_evictions, 1);
        assert_eq!(backend.released().len(), 1, "evicted slot was not released");
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => assert_eq!(e.code, "deadline_exceeded"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stale_queue_wait_sheds_with_retry_hint() {
        let backend = SyntheticBackend::new(16, 8, 1);
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(4);
        registry.register(6, w_tx, None);
        let (tx, rx) = sync_channel(4);
        let mut r = req(6, 0, vec![3], 4);
        // enqueued 80ms ago against a 10ms queue-wait bound: shed
        r.enqueued = Instant::now() - std::time::Duration::from_millis(80);
        tx.send(r).unwrap();
        drop(tx);
        let opts = ServeOptions { max_queue_wait_ms: 10, ..ServeOptions::default() };
        let stats = run(&backend, rx, &registry, &opts).unwrap();
        assert_eq!(stats.shed, 1);
        match w_rx.recv().unwrap() {
            WriterMsg::Resp { result: Err(e), .. } => {
                assert_eq!(e.code, "overloaded");
                assert_eq!(e.retry_after_ms, Some(10));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn drain_rejects_new_requests_and_evicts_at_timeout() {
        let backend = ReleaseProbe::new(
            SyntheticBackend::new(16, 8, 1)
                .with_costs(std::time::Duration::from_millis(1), std::time::Duration::ZERO),
        );
        let registry = Registry::default();
        let (w_tx, w_rx) = sync_channel(8);
        registry.register(7, w_tx, None);
        let opts = ServeOptions { drain_timeout_ms: 15, ..ServeOptions::default() };
        // a long request is in flight when the drain begins
        let (tx, rx) = sync_channel(4);
        tx.send(req(7, 0, vec![3], 100_000)).unwrap();
        opts.lifecycle.begin_drain();
        // this one is enqueued after the drain began: rejected up front
        tx.send(req(7, 1, vec![4], 4)).unwrap();
        drop(tx);
        let stats = run(&backend, rx, &registry, &opts).unwrap();
        assert_eq!(stats.drain_evictions, 2);
        assert_eq!(stats.completed, 0);
        assert_eq!(backend.released().len(), 1, "drained slot was not released");
        let mut codes = vec![];
        while let Ok(WriterMsg::Resp { result: Err(e), .. }) = w_rx.try_recv() {
            codes.push(e.code);
        }
        assert_eq!(codes, vec!["shutting_down", "shutting_down"]);
    }
}
