//! Pluggable token selection for the generation API v2.
//!
//! The decode core ([`super::batch`]) is *logits-out*: a
//! [`StepBackend`](super::batch::StepBackend) step returns one raw
//! `[vocab]` logits row per slot and never picks a token. Everything
//! that turns a logits row into the next token id lives here:
//!
//! * [`GenParams`] — per-request generation parameters, carried from the
//!   wire protocol (`"params": {...}`) through the scheduler into each
//!   [`DecodeSlot`](super::batch::DecodeSlot). The default is greedy:
//!   `temperature == 0` selects the NaN-safe argmax, bit-identical to
//!   the pre-v2 decode path.
//! * [`Sampler`] — the per-slot selection state: the parameters plus a
//!   deterministic [`Rng`] seeded from `GenParams::seed`. A slot's
//!   sampler consumes exactly one uniform draw per sampled token, so the
//!   same seed over the same logits sequence reproduces the same tokens
//!   — across runs, and identically for batched vs sequential decode
//!   (the scheduler carries each slot's sampler across micro-batched
//!   steps; batch composition never touches it). The one-draw-per-token
//!   invariant is also what makes speculative decoding
//!   ([`super::spec`]) *exact* rather than merely distribution-
//!   preserving: a verify pass feeds the slot the same logits rows in
//!   the same order sequential decoding would, so the sampler's RNG
//!   stream — and therefore every emitted token — is bit-identical
//!   whether or not a draft proposed it.
//!
//! Selection pipeline (applied in this order, skipped entirely for
//! greedy): repetition penalty over the visible token window → divide by
//! `temperature` → keep the `top_k` highest logits → keep the smallest
//! nucleus of cumulative probability `top_p` → sample from the
//! renormalized remainder. Masking is applied *after* the penalty, so a
//! penalized-but-masked id can never be selected.
//!
//! Stop conditions ([`GenParams::stop_tokens`] /
//! [`GenParams::stop_sequences`]) apply to every mode, greedy included:
//! a stop token ends the request without being emitted; a stop sequence
//! ends the request with the matched tokens included in the output (so
//! streamed token frames always concatenate to the final response).

use anyhow::{bail, Result};

use super::batch::argmax;
use crate::util::rng::Rng;

/// Protocol cap on `stop_tokens` entries per request.
pub const MAX_STOP_TOKENS: usize = 16;
/// Protocol cap on stop sequences per request.
pub const MAX_STOP_SEQS: usize = 8;
/// Protocol cap on the token length of one stop sequence.
pub const MAX_STOP_SEQ_TOKENS: usize = 16;

/// Per-request generation parameters.
///
/// The default is pure greedy decoding — argmax over the logits row,
/// token-identical to the v1 protocol — with no stop conditions. The
/// shaping knobs (`top_k`, `top_p`, `repetition_penalty`) require
/// `temperature > 0`: [`GenParams::validate`] rejects a knob that greedy
/// selection would silently ignore. The stop conditions apply in every
/// mode, and `seed` is carried harmlessly (greedy consumes no
/// randomness).
///
/// ```
/// use nvfp4_faar::serve::GenParams;
/// assert!(GenParams::default().is_greedy());
/// assert!(!GenParams { temperature: 0.8, ..GenParams::default() }.is_greedy());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct GenParams {
    /// softmax temperature; `0` selects greedy argmax decoding
    pub temperature: f32,
    /// keep only the `top_k` highest logits before sampling; `0` keeps all
    pub top_k: usize,
    /// keep the smallest set of tokens with cumulative probability
    /// `>= top_p` (nucleus sampling); `1` keeps all
    pub top_p: f32,
    /// divide (positive) / multiply (negative) the logits of tokens
    /// already visible in the decode window by this factor; `1` disables
    pub repetition_penalty: f32,
    /// RNG seed for the request's sampler (reproducibility contract)
    pub seed: u64,
    /// token ids that end the request when selected (not emitted)
    pub stop_tokens: Vec<i32>,
    /// token sequences that end the request once the output ends with
    /// one of them (the matched tokens stay in the output)
    pub stop_sequences: Vec<Vec<i32>>,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed: 0,
            stop_tokens: Vec::new(),
            stop_sequences: Vec::new(),
        }
    }
}

impl GenParams {
    /// Greedy decoding (the default).
    pub fn greedy() -> GenParams {
        GenParams::default()
    }

    /// Temperature sampling with a seed, everything else default.
    pub fn sampled(temperature: f32, seed: u64) -> GenParams {
        GenParams { temperature, seed, ..GenParams::default() }
    }

    /// True when selection is the NaN-safe argmax (`temperature == 0`).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// True when selecting `t` should end the request (without emitting).
    pub fn is_stop_token(&self, t: i32) -> bool {
        self.stop_tokens.contains(&t)
    }

    /// True when the emitted output now ends with a stop sequence.
    pub fn stops_output(&self, out: &[i32]) -> bool {
        self.stop_sequences.iter().any(|s| !s.is_empty() && out.ends_with(s))
    }

    /// Core invariants every carried parameter set must satisfy; the
    /// protocol boundary additionally rejects an *explicit*
    /// `temperature <= 0` or `top_k == 0` (omitting them is how a client
    /// asks for greedy / unrestricted).
    pub fn validate(&self) -> Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            bail!("temperature must be a finite number > 0 (omit it for greedy)");
        }
        if !(self.top_p > 0.0 && self.top_p <= 1.0) {
            bail!("top_p must be in (0, 1]");
        }
        if !self.repetition_penalty.is_finite() || self.repetition_penalty <= 0.0 {
            bail!("repetition_penalty must be a finite number > 0");
        }
        // greedy selection is pure argmax; a shaping knob that would be
        // silently ignored is rejected, not carried (stop conditions and
        // the seed are fine — stops apply in every mode, the seed is
        // just unused randomness)
        if self.is_greedy()
            && (self.top_k != 0 || self.top_p != 1.0 || self.repetition_penalty != 1.0)
        {
            bail!("top_k/top_p/repetition_penalty require temperature > 0 (greedy ignores them)");
        }
        if self.stop_tokens.len() > MAX_STOP_TOKENS {
            bail!("at most {MAX_STOP_TOKENS} stop_tokens per request");
        }
        if self.stop_sequences.len() > MAX_STOP_SEQS {
            bail!("at most {MAX_STOP_SEQS} stop sequences per request");
        }
        for s in &self.stop_sequences {
            if s.is_empty() {
                bail!("stop sequences must be non-empty");
            }
            if s.len() > MAX_STOP_SEQ_TOKENS {
                bail!("stop sequences are capped at {MAX_STOP_SEQ_TOKENS} tokens");
            }
        }
        Ok(())
    }
}

/// Per-slot token selection: [`GenParams`] plus the request's
/// deterministic RNG stream. One `Sampler` lives inside each
/// [`DecodeSlot`](super::batch::DecodeSlot) for the slot's whole
/// lifetime, so selection state survives micro-batched scheduling
/// exactly as it would sequential decoding.
///
/// ```
/// use nvfp4_faar::serve::{GenParams, Sampler};
/// let p = GenParams { temperature: 0.7, seed: 9, ..GenParams::default() };
/// let mut a = Sampler::new(p.clone());
/// let mut b = Sampler::new(p);
/// let row = [0.3f32, 1.9, 0.2, 1.1];
/// assert_eq!(a.select(&row, &[]), b.select(&row, &[]));
/// ```
#[derive(Clone, Debug)]
pub struct Sampler {
    params: GenParams,
    rng: Rng,
    // Reusable per-select scratch: the sampler lives in the slot for the
    // request's lifetime and runs on the single scheduler thread, so the
    // hot loop must not reallocate vocab-sized buffers per token.
    cand: Vec<(usize, f32)>,
    probs: Vec<f64>,
    seen: Vec<bool>,
}

impl Sampler {
    /// A sampler over `params`, its RNG seeded from `params.seed`.
    pub fn new(params: GenParams) -> Sampler {
        let rng = Rng::new(params.seed);
        Sampler { params, rng, cand: Vec::new(), probs: Vec::new(), seen: Vec::new() }
    }

    /// The request parameters this sampler applies.
    pub fn params(&self) -> &GenParams {
        &self.params
    }

    /// Select the next token id from a logits row. `history` is the
    /// token window the model conditioned on (prompt tail + emitted
    /// tokens) — the repetition-penalty support. Greedy parameters take
    /// the NaN-safe [`argmax`] path and consume no randomness; sampling
    /// parameters consume exactly one uniform draw per call.
    pub fn select(&mut self, logits: &[f32], history: &[i32]) -> usize {
        if self.params.is_greedy() {
            return argmax(logits);
        }
        let (temp, top_k, top_p, penalty) = (
            self.params.temperature as f64,
            self.params.top_k,
            self.params.top_p,
            self.params.repetition_penalty,
        );
        // candidate set: NaN logits are dropped (same policy as argmax —
        // a NaN is a model bug, not a reason to fail the request)
        let cand = &mut self.cand;
        cand.clear();
        cand.extend(
            logits.iter().enumerate().filter(|(_, v)| !v.is_nan()).map(|(i, &v)| (i, v)),
        );
        if cand.is_empty() {
            return 0;
        }
        // repetition penalty (CTRL rule) over the visible window,
        // applied BEFORE top-k/top-p so masking bounds what the penalty
        // can surface
        if penalty != 1.0 {
            let seen = &mut self.seen;
            seen.clear();
            seen.resize(logits.len(), false);
            for &t in history {
                if t >= 0 && (t as usize) < seen.len() {
                    seen[t as usize] = true;
                }
            }
            for (i, v) in cand.iter_mut() {
                if seen[*i] {
                    *v = if *v > 0.0 { *v / penalty } else { *v * penalty };
                }
            }
        }
        // top-k: keep the k highest logits (descending partial select)
        let k = if top_k > 0 { top_k.min(cand.len()) } else { cand.len() };
        if k < cand.len() {
            cand.select_nth_unstable_by(k - 1, |a, b| b.1.total_cmp(&a.1));
            cand.truncate(k);
        }
        // only the nucleus truncation needs the candidates in descending
        // order — a plain weighted draw does not, so temperature-only
        // sampling skips the O(V log V) sort on the scheduler thread
        let m = if top_p < 1.0 {
            cand.sort_unstable_by(|a, b| b.1.total_cmp(&a.1));
            cand[0].1
        } else {
            cand.iter().map(|&(_, v)| v).fold(f32::NEG_INFINITY, f32::max)
        };
        // temperature-scaled softmax, max-subtracted for stability; the
        // f64 accumulation keeps tiny temperatures (→ greedy) exact
        let probs = &mut self.probs;
        probs.clear();
        probs.extend(cand.iter().map(|(_, v)| (((*v - m) as f64) / temp).exp()));
        // top-p: smallest prefix of the descending distribution whose
        // cumulative mass reaches top_p
        if top_p < 1.0 {
            let total: f64 = probs.iter().sum();
            let mut cum = 0.0;
            let mut keep = probs.len();
            for (i, pr) in probs.iter().enumerate() {
                cum += pr / total.max(f64::MIN_POSITIVE);
                if cum >= top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            cand.truncate(keep);
            probs.truncate(keep);
        }
        // one uniform draw over the renormalized remainder
        let total: f64 = probs.iter().sum();
        if total <= 0.0 {
            // every candidate underflowed (enormous logit gap at a tiny
            // temperature): fall back to the best candidate — the argmax
            return cand
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|&(i, _)| i)
                .unwrap_or(0);
        }
        let mut x = self.rng.f64() * total;
        for ((i, _), pr) in cand.iter().zip(probs.iter()) {
            // a zero-mass candidate (underflowed exp) can never win the
            // draw, even when x lands exactly on 0
            if *pr <= 0.0 {
                continue;
            }
            x -= pr;
            if x <= 0.0 {
                return *i;
            }
        }
        cand.last().map(|(i, _)| *i).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_argmax_including_nan_rows() {
        let mut s = Sampler::new(GenParams::default());
        for row in [
            vec![0.1f32, 3.0, 2.0],
            vec![1.0, f32::NAN, 3.0, 2.0],
            vec![f32::NAN, f32::NAN],
            vec![],
        ] {
            assert_eq!(s.select(&row, &[]), argmax(&row));
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic() {
        let p =
            GenParams { temperature: 1.2, top_k: 3, top_p: 0.9, seed: 17, ..GenParams::default() };
        let mut a = Sampler::new(p.clone());
        let mut b = Sampler::new(p.clone());
        let mut c = Sampler::new(GenParams { seed: 18, ..p });
        let row: Vec<f32> = (0..32).map(|i| ((i * 37 % 11) as f32) * 0.3).collect();
        let picks_a: Vec<usize> = (0..20).map(|_| a.select(&row, &[1, 2])).collect();
        let picks_b: Vec<usize> = (0..20).map(|_| b.select(&row, &[1, 2])).collect();
        let picks_c: Vec<usize> = (0..20).map(|_| c.select(&row, &[1, 2])).collect();
        assert_eq!(picks_a, picks_b);
        assert_ne!(picks_a, picks_c, "different seeds should diverge");
    }

    #[test]
    fn top_k_one_is_greedy() {
        let p = GenParams { temperature: 2.0, top_k: 1, seed: 5, ..GenParams::default() };
        let mut s = Sampler::new(p);
        let row = [0.4f32, 2.5, 1.1, 2.4];
        for _ in 0..10 {
            assert_eq!(s.select(&row, &[]), 1);
        }
    }

    #[test]
    fn tiny_temperature_converges_to_greedy() {
        let p = GenParams { temperature: 1e-6, seed: 3, ..GenParams::default() };
        let mut s = Sampler::new(p);
        let row = [0.1f32, 0.9, 0.3, 0.89];
        for _ in 0..20 {
            assert_eq!(s.select(&row, &[]), 1);
        }
    }

    #[test]
    fn stop_helpers() {
        let p = GenParams {
            stop_tokens: vec![7],
            stop_sequences: vec![vec![1, 2]],
            ..GenParams::default()
        };
        assert!(p.is_stop_token(7));
        assert!(!p.is_stop_token(8));
        assert!(p.stops_output(&[9, 1, 2]));
        assert!(!p.stops_output(&[1, 2, 9]));
        assert!(!p.stops_output(&[2]));
    }

    #[test]
    fn validate_rejects_bad_params() {
        let ok = GenParams::default();
        assert!(ok.validate().is_ok());
        let bad = |f: fn(&mut GenParams)| {
            let mut p = GenParams::default();
            f(&mut p);
            p.validate().is_err()
        };
        assert!(bad(|p| p.temperature = f32::NAN));
        assert!(bad(|p| p.temperature = f32::INFINITY));
        assert!(bad(|p| p.temperature = -0.5));
        assert!(bad(|p| p.top_p = 0.0));
        assert!(bad(|p| p.top_p = 1.5));
        assert!(bad(|p| p.top_p = f32::NAN));
        assert!(bad(|p| p.repetition_penalty = 0.0));
        assert!(bad(|p| p.repetition_penalty = f32::NAN));
        // shaping knobs without temperature would be silently ignored by
        // greedy argmax — rejected instead
        assert!(bad(|p| p.top_k = 5));
        assert!(bad(|p| p.top_p = 0.9));
        assert!(bad(|p| p.repetition_penalty = 1.5));
        let ok_with_temp = GenParams { temperature: 0.8, top_k: 5, ..GenParams::default() };
        assert!(ok_with_temp.validate().is_ok());
        // seed and stop conditions are legal in greedy mode
        let greedy_stops = GenParams {
            seed: 9,
            stop_tokens: vec![1],
            stop_sequences: vec![vec![2, 3]],
            ..GenParams::default()
        };
        assert!(greedy_stops.validate().is_ok());
        assert!(bad(|p| p.stop_tokens = vec![0; MAX_STOP_TOKENS + 1]));
        assert!(bad(|p| p.stop_sequences = vec![vec![]]));
        assert!(bad(|p| p.stop_sequences = vec![vec![1]; MAX_STOP_SEQS + 1]));
        assert!(bad(|p| p.stop_sequences = vec![vec![1; MAX_STOP_SEQ_TOKENS + 1]]));
    }
}
