//! Speculative decoding + multi-model serving.
//!
//! Two pieces that ship together because the second needs the first:
//!
//! * [`SpecDecoder`] — draft-verify decoding. A small *draft* model
//!   greedily proposes `k` tokens; the *target* model verifies all of
//!   them in ONE `[k+1, V]` multi-row pass ([`SpecModel::verify_rows`],
//!   the batched-prefill kernels from PR 5 applied to consecutive
//!   positions of a single sequence); the longest prefix of proposals
//!   the target's own selection reproduces is accepted, and the first
//!   divergent verify row supplies the correction token. Because every
//!   verify row is **bitwise identical** to the row sequential decoding
//!   would have computed at that position, and the slot's [`Sampler`]
//!   consumes exactly one RNG draw per emitted token (zero for greedy),
//!   the emitted stream is *deterministically* equal to non-speculative
//!   decoding — a strictly stronger property than the distributional
//!   guarantee of classic rejection sampling, pinned by property test.
//! * [`ModelRegistry`] — several named backends behind one
//!   [`StepBackend`], each with its own KV pool and optional draft
//!   pairing. The protocol's validated `"model"` field routes a request
//!   at admission ([`StepBackend::bind_model`]); the scheduler's decode
//!   tick dispatches through [`StepBackend::spec_step`], which chunks
//!   the active micro-batch into consecutive same-model runs and
//!   decodes each run through its own backend — speculatively where a
//!   draft is paired, via the ordinary [`decode_step`] elsewhere.
//!
//! KV lifecycle for rejected drafts: a verify pass stores KV rows for
//! the decode token *and all `k` proposals*; when only `m < k` are
//! accepted, [`SpecModel::truncate_slot`] rolls the target cache back
//! to `window + m` (the correction token's KV was never stored — the
//! next round's catch-up feeds it), and the draft cache is rolled back
//! to the same prefix. Rejection therefore never leaks pages, which the
//! property tests assert via `kv_outstanding == 0` after release.
//!
//! [`Sampler`]: super::sampling::Sampler

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::batch::{
    argmax, decode_step, spin, CacheStats, DecodeSlot, StepBackend, SyntheticBackend,
};
use super::sampling::GenParams;
use crate::infer::{kv::KvExhausted, NativeBackend};

/// A backend a [`SpecDecoder`] can drive: the three per-sequence
/// primitives draft-verify needs on top of the batched [`StepBackend`]
/// contract. The load-bearing invariant: `verify_rows` row `i` must be
/// bitwise identical to what `decode_row` would return after feeding
/// `drafts[..i]` — speculative acceptance is only exact because the
/// rows ARE the sequential rows.
pub trait SpecModel: StepBackend {
    /// One logits row for `window`, with per-sequence cache state keyed
    /// on `slot_id` (bitwise identical to the row `StepBackend::step`
    /// would return for a slot with this window).
    fn decode_row(&self, slot_id: u64, window: &[i32]) -> Result<Vec<f32>>;

    /// `drafts.len() + 1` logits rows — for `window`'s decode token and
    /// each draft appended after it — in one multi-row pass. On success
    /// the per-slot cache holds `window + drafts`; rejected suffixes are
    /// rolled back with [`Self::truncate_slot`].
    fn verify_rows(&self, slot_id: u64, window: &[i32], drafts: &[i32])
        -> Result<Vec<Vec<f32>>>;

    /// Roll the per-slot cache back to its first `keep` tokens. No-op
    /// for stateless backends (the default) and for unknown slots.
    fn truncate_slot(&self, _slot_id: u64, _keep: usize) {}
}

impl SpecModel for NativeBackend {
    fn decode_row(&self, slot_id: u64, window: &[i32]) -> Result<Vec<f32>> {
        NativeBackend::decode_row(self, slot_id, window)
    }

    fn verify_rows(
        &self,
        slot_id: u64,
        window: &[i32],
        drafts: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        NativeBackend::verify_rows(self, slot_id, window, drafts)
    }

    fn truncate_slot(&self, slot_id: u64, keep: usize) {
        NativeBackend::truncate_slot(self, slot_id, keep)
    }
}

impl SpecModel for SyntheticBackend {
    fn decode_row(&self, _slot_id: u64, window: &[i32]) -> Result<Vec<f32>> {
        let Some(&last) = window.last() else {
            bail!("decode_row on an empty window");
        };
        // a B=1 step's worth of simulated cost
        spin(self.fixed_cost);
        spin(self.per_slot_cost);
        Ok(self.row(last, window.len() - 1))
    }

    fn verify_rows(
        &self,
        _slot_id: u64,
        window: &[i32],
        drafts: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let Some(&last) = window.last() else {
            bail!("verify_rows on an empty window");
        };
        if window.len() + drafts.len() > self.seq_len() {
            bail!(
                "verify window of {} + {} drafts overflows seq_len {}",
                window.len(),
                drafts.len(),
                self.seq_len()
            );
        }
        // ONE pass: fixed cost once, per-slot cost once — the multi-row
        // verify being nearly free relative to k sequential steps is
        // exactly the economics the spec bench measures
        spin(self.fixed_cost);
        spin(self.per_slot_cost);
        let mut rows = Vec::with_capacity(drafts.len() + 1);
        let mut pos = window.len() - 1;
        rows.push(self.row(last, pos));
        for &d in drafts {
            pos += 1;
            rows.push(self.row(d, pos));
        }
        Ok(rows)
    }
}

/// Speculative-decode counters, aggregated across every draft-paired
/// model and surfaced through `SchedStats`, the serve shutdown log, and
/// `BENCH_serve.json` / `BENCH_spec.json`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    /// draft tokens proposed across all verify passes
    pub drafted: u64,
    /// draft tokens the target's own selection reproduced (emitted verbatim)
    pub accepted: u64,
    /// multi-row `[k+1, V]` verify passes through a target model
    pub verify_passes: u64,
    /// speculative rounds, including degenerate rounds (no draft room /
    /// budget of 1 / pool pressure) that fell back to a plain step
    pub rounds: u64,
}

impl SpecStats {
    /// `accepted / drafted` — the fraction of proposals the target kept
    /// (0.0 before anything was drafted).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Fold another counter set into this one.
    pub fn add(&mut self, other: &SpecStats) {
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.verify_passes += other.verify_passes;
        self.rounds += other.rounds;
    }
}

/// Per-model admission/queue counters from a [`ModelRegistry`],
/// surfaced through `SchedStats`, the shutdown log, and
/// `BENCH_serve.json`.
#[derive(Clone, Debug, Default)]
pub struct ModelQueueStats {
    /// registry entry name (the request `"model"` field that routes here)
    pub name: String,
    /// slots ever bound to this model
    pub admitted: u64,
    /// bound slots since released (completed, cancelled, or failed)
    pub completed: u64,
    /// peak concurrently-bound slots
    pub peak_depth: u64,
}

/// A draft backend paired with a speculation depth `k`, driving
/// draft-verify rounds against a target it shares a vocabulary with.
pub struct SpecDecoder<B> {
    /// the small draft model (same vocab as its target; usually a
    /// cheaper preset or a distilled student)
    pub draft: B,
    /// tokens proposed per verify pass (clamped per round by the token
    /// budget and both models' window room)
    pub k: usize,
}

impl<B: SpecModel> SpecDecoder<B> {
    /// Pair `draft` with a speculation depth.
    pub fn new(draft: B, k: usize) -> SpecDecoder<B> {
        SpecDecoder { draft, k }
    }

    /// One speculative round for `slot` against `target`: draft up to
    /// `k` tokens greedily, verify them in one multi-row pass, emit the
    /// longest prefix the target's own selection reproduces (plus the
    /// correction or bonus token from the first non-matching row), and
    /// roll both caches back past anything rejected. Degenerate rounds
    /// — no draft room left in either window, a token budget of 1, or
    /// pool pressure during verify — fall back to one plain target
    /// step, so a round ALWAYS makes progress. Counters accumulate into
    /// `stats`.
    pub fn advance_slot(
        &self,
        target: &B,
        slot: &mut DecodeSlot,
        stats: &mut SpecStats,
    ) -> Result<()> {
        stats.rounds += 1;
        let vmax = target.vocab() as i32 - 1;
        let w = slot.window().len();
        let n = self
            .k
            .min(slot.remaining().saturating_sub(1))
            .min(target.seq_len().saturating_sub(w))
            .min(self.draft.seq_len().saturating_sub(w));
        if n == 0 {
            let row = target.decode_row(slot.id, slot.window())?;
            let _ = slot.accept(&row, vmax);
            return Ok(());
        }
        // greedy draft: n proposals, each conditioned on the previous
        let mut dw = slot.window().to_vec();
        let mut drafts = Vec::with_capacity(n);
        for _ in 0..n {
            let row = self.draft.decode_row(slot.id, &dw)?;
            let t = (argmax(&row) as i32).min(vmax);
            drafts.push(t);
            dw.push(t);
        }
        // one [n+1, V] pass through the target
        let rows = match target.verify_rows(slot.id, slot.window(), &drafts) {
            Ok(rows) => rows,
            Err(e) if e.downcast_ref::<KvExhausted>().is_some() => {
                // no page budget for the multi-row pass: degrade to a
                // plain step (which has its own uncached fallback) and
                // drop the unverified proposals from the draft cache
                self.draft.truncate_slot(slot.id, w);
                let row = target.decode_row(slot.id, slot.window())?;
                let _ = slot.accept(&row, vmax);
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        stats.verify_passes += 1;
        stats.drafted += n as u64;
        // sequential acceptance: row i is only valid while every earlier
        // emission matched its draft — the first divergence IS the
        // correction token, and a full match makes row n a bonus token
        let mut matched = 0usize;
        for (i, row) in rows.iter().enumerate() {
            let emitted = slot.accept(row, vmax);
            if i == n {
                break;
            }
            match emitted {
                Some(t) if t == drafts[i] => {
                    matched += 1;
                    stats.accepted += 1;
                    if slot.done() {
                        break;
                    }
                }
                _ => break,
            }
        }
        if matched < n {
            // rejected proposals' KV rows are stale: roll the target back
            // to window + accepted (the correction token's KV was never
            // stored — next round's catch-up feeds it)
            target.truncate_slot(slot.id, w + matched);
        }
        self.draft.truncate_slot(slot.id, w + matched);
        Ok(())
    }
}

/// Sequential speculative generation — the B=1 reference driver the
/// property tests pin against plain `generate` and the spec bench
/// measures. Releases both models' per-slot state on every exit path.
pub fn spec_generate<B: SpecModel>(
    target: &B,
    spec: &SpecDecoder<B>,
    prompt: &[i32],
    max_tokens: usize,
    params: GenParams,
) -> Result<(Vec<i32>, SpecStats)> {
    let mut slot = DecodeSlot::with_params(prompt, max_tokens, target.seq_len(), params)?;
    let mut stats = SpecStats::default();
    while !slot.done() {
        if let Err(e) = spec.advance_slot(target, &mut slot, &mut stats) {
            target.release(&slot);
            spec.draft.release(&slot);
            return Err(e);
        }
    }
    target.release(&slot);
    spec.draft.release(&slot);
    Ok((slot.out, stats))
}

/// One named model hosted by a [`ModelRegistry`].
pub struct ModelEntry<B> {
    /// the name requests route to via the protocol `"model"` field
    pub name: String,
    /// the serving backend (its own KV pool, preset, weights)
    pub backend: B,
    /// optional draft pairing: decode this model speculatively
    pub spec: Option<SpecDecoder<B>>,
}

#[derive(Default)]
struct QueueCounters {
    admitted: u64,
    completed: u64,
    depth: u64,
    peak: u64,
}

/// Several named backends behind ONE [`StepBackend`], so the existing
/// admission/decode scheduler serves them all unchanged: requests bind
/// to an entry by name at admission, the decode tick routes consecutive
/// same-model runs of the micro-batch to their backends (speculatively
/// where a draft is paired), and release unbinds. Entry 0 is the
/// default model for requests that name none. Construction validates
/// the registry shape — at least one entry, unique names, one shared
/// vocabulary (drafts included, so proposals are always valid target
/// tokens).
pub struct ModelRegistry<B> {
    entries: Vec<ModelEntry<B>>,
    /// live slot → entry index, written at bind and dropped at release.
    /// All three locks recover from poisoning (`into_inner`): their
    /// critical sections are single inserts/removes/counter bumps that
    /// cannot be observed half-done, and a panicking backend must not
    /// wedge routing for the other hosted models
    routes: Mutex<HashMap<u64, usize>>,
    stats: Mutex<SpecStats>,
    queues: Mutex<Vec<QueueCounters>>,
}

impl<B: SpecModel> ModelRegistry<B> {
    /// Validate and build a registry over `entries`.
    pub fn new(entries: Vec<ModelEntry<B>>) -> Result<ModelRegistry<B>> {
        if entries.is_empty() {
            bail!("model registry needs at least one model");
        }
        let vocab = entries[0].backend.vocab();
        let mut seen = HashSet::new();
        for e in &entries {
            if e.name.is_empty() {
                bail!("model names must be non-empty");
            }
            if !seen.insert(e.name.as_str()) {
                bail!("duplicate model name '{}'", e.name);
            }
            if e.backend.vocab() != vocab {
                bail!(
                    "model '{}' vocab {} differs from '{}' vocab {vocab}; \
                     one registry serves one vocabulary",
                    e.name,
                    e.backend.vocab(),
                    entries[0].name
                );
            }
            if let Some(sd) = &e.spec {
                if sd.k == 0 {
                    bail!("model '{}': speculation depth k must be >= 1", e.name);
                }
                if sd.draft.vocab() != vocab {
                    bail!(
                        "model '{}': draft vocab {} differs from target vocab {vocab}",
                        e.name,
                        sd.draft.vocab()
                    );
                }
            }
        }
        let queues = entries.iter().map(|_| QueueCounters::default()).collect();
        Ok(ModelRegistry {
            entries,
            routes: Mutex::new(HashMap::new()),
            stats: Mutex::new(SpecStats::default()),
            queues: Mutex::new(queues),
        })
    }

    /// The hosted model names, in entry order (entry 0 is the default).
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// The entries, for direct inspection in tests and benches.
    pub fn entries(&self) -> &[ModelEntry<B>] {
        &self.entries
    }

    fn resolve(&self, name: Option<&str>) -> Result<usize> {
        match name {
            None => Ok(0),
            Some(n) => self
                .entries
                .iter()
                .position(|e| e.name == n)
                .ok_or_else(|| anyhow!("unknown model '{n}'")),
        }
    }

    /// Unbound slots route to the default entry — bind_model always runs
    /// before the first step, so this is a belt-and-braces default, not
    /// a code path requests normally take.
    fn route_of(&self, slot_id: u64) -> usize {
        self.routes.lock().unwrap_or_else(|e| e.into_inner()).get(&slot_id).copied().unwrap_or(0)
    }

    /// The `spec_step` body: chunk the micro-batch into consecutive
    /// same-model runs; draft-paired entries advance each slot through a
    /// speculative round, the rest take one ordinary [`decode_step`].
    fn advance(&self, slots: &mut [DecodeSlot]) -> Result<()> {
        let mut i = 0;
        while i < slots.len() {
            let m = self.route_of(slots[i].id);
            let mut j = i + 1;
            while j < slots.len() && self.route_of(slots[j].id) == m {
                j += 1;
            }
            let entry = &self.entries[m];
            match &entry.spec {
                Some(sd) => {
                    let mut round = SpecStats::default();
                    for slot in slots[i..j].iter_mut().filter(|s| !s.done()) {
                        sd.advance_slot(&entry.backend, slot, &mut round)?;
                    }
                    self.stats.lock().unwrap_or_else(|e| e.into_inner()).add(&round);
                }
                None => decode_step(&entry.backend, &mut slots[i..j])?,
            }
            i = j;
        }
        Ok(())
    }
}

impl<B: SpecModel> StepBackend for ModelRegistry<B> {
    fn vocab(&self) -> usize {
        self.entries[0].backend.vocab()
    }

    /// The registry's window is the MINIMUM across every hosted model
    /// (drafts included): every slot must fit every backend it might
    /// route to, and a draft window shorter than the target's would
    /// silently disable drafting for long sequences anyway.
    fn seq_len(&self) -> usize {
        self.entries
            .iter()
            .map(|e| {
                let mut s = e.backend.seq_len();
                if let Some(sd) = &e.spec {
                    s = s.min(sd.draft.seq_len());
                }
                s
            })
            .min()
            .expect("registry has at least one entry")
    }

    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        let mut rows = Vec::with_capacity(slots.len());
        let mut i = 0;
        while i < slots.len() {
            let m = self.route_of(slots[i].id);
            let mut j = i + 1;
            while j < slots.len() && self.route_of(slots[j].id) == m {
                j += 1;
            }
            rows.extend(self.entries[m].backend.step(&slots[i..j])?);
            i = j;
        }
        Ok(rows)
    }

    fn spec_step(&self, slots: &mut [DecodeSlot]) -> Option<Result<()>> {
        Some(self.advance(slots))
    }

    fn prefill_chunk(&self, slot: &DecodeSlot, max_tokens: usize) -> Result<usize> {
        self.entries[self.route_of(slot.id)].backend.prefill_chunk(slot, max_tokens)
    }

    fn bind_model(&self, slot: &DecodeSlot, model: Option<&str>) -> Result<()> {
        let idx = self.resolve(model)?;
        self.routes.lock().unwrap_or_else(|e| e.into_inner()).insert(slot.id, idx);
        let mut queues = self.queues.lock().unwrap_or_else(|e| e.into_inner());
        let q = &mut queues[idx];
        q.admitted += 1;
        q.depth += 1;
        q.peak = q.peak.max(q.depth);
        Ok(())
    }

    fn release(&self, slot: &DecodeSlot) {
        let route = self.routes.lock().unwrap_or_else(|e| e.into_inner()).remove(&slot.id);
        match route {
            Some(idx) => {
                let entry = &self.entries[idx];
                entry.backend.release(slot);
                if let Some(sd) = &entry.spec {
                    sd.draft.release(slot);
                }
                let mut queues = self.queues.lock().unwrap_or_else(|e| e.into_inner());
                let q = &mut queues[idx];
                q.completed += 1;
                q.depth = q.depth.saturating_sub(1);
            }
            None => {
                // release must be idempotent and safe for slots never
                // bound: forward to everyone (a stateless no-op each)
                for entry in &self.entries {
                    entry.backend.release(slot);
                    if let Some(sd) = &entry.spec {
                        sd.draft.release(slot);
                    }
                }
            }
        }
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        let mut agg = CacheStats::default();
        let mut any = false;
        let mut fold = |s: Option<CacheStats>| {
            if let Some(s) = s {
                any = true;
                agg.prefix_lookups += s.prefix_lookups;
                agg.prefix_hits += s.prefix_hits;
                agg.prefix_hit_tokens += s.prefix_hit_tokens;
                agg.prefix_pages += s.prefix_pages;
                agg.kv_pages_hwm += s.kv_pages_hwm;
            }
        };
        for entry in &self.entries {
            fold(entry.backend.cache_stats());
            if let Some(sd) = &entry.spec {
                fold(sd.draft.cache_stats());
            }
        }
        any.then_some(agg)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        self.entries
            .iter()
            .any(|e| e.spec.is_some())
            .then(|| *self.stats.lock().unwrap_or_else(|e| e.into_inner()))
    }

    fn model_queue_stats(&self) -> Vec<ModelQueueStats> {
        let queues = self.queues.lock().unwrap_or_else(|e| e.into_inner());
        self.entries
            .iter()
            .zip(queues.iter())
            .map(|(e, q)| ModelQueueStats {
                name: e.name.clone(),
                admitted: q.admitted,
                completed: q.completed,
                peak_depth: q.peak,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batch::generate;

    const VOCAB: usize = 64;
    const SEQ: usize = 24;

    fn target() -> SyntheticBackend {
        SyntheticBackend::new(VOCAB, SEQ, 7)
    }

    fn draft(p: f32) -> SyntheticBackend {
        SyntheticBackend::new(VOCAB, SEQ, 7).with_divergence(p, 99)
    }

    #[test]
    fn greedy_spec_is_bit_identical_to_plain_decode() {
        let t = target();
        for k in [1usize, 2, 3, 5, 8] {
            for p in [0.0f32, 0.25, 1.0] {
                let sd = SpecDecoder::new(draft(p), k);
                for prompt in [vec![1, 2, 3], vec![9], vec![4, 4, 4, 4]] {
                    let plain = generate(&t, &prompt, 16, GenParams::default()).unwrap();
                    let (spec, stats) =
                        spec_generate(&t, &sd, &prompt, 16, GenParams::default()).unwrap();
                    assert_eq!(spec, plain, "k={k} p={p} prompt={prompt:?}");
                    assert!(stats.accepted <= stats.drafted);
                }
            }
        }
    }

    #[test]
    fn seeded_sampling_spec_matches_plain_decode() {
        let t = target();
        let params = GenParams {
            temperature: 0.9,
            top_k: 12,
            top_p: 0.95,
            seed: 11,
            ..GenParams::default()
        };
        for k in [1usize, 3, 6] {
            let sd = SpecDecoder::new(draft(0.25), k);
            let plain = generate(&t, &[1, 2, 3], 16, params.clone()).unwrap();
            let (spec, _) = spec_generate(&t, &sd, &[1, 2, 3], 16, params.clone()).unwrap();
            assert_eq!(spec, plain, "seeded sampling diverged at k={k}");
        }
    }

    #[test]
    fn accept_rate_tracks_divergence_knob() {
        let t = target();
        let sd = SpecDecoder::new(draft(0.25), 4);
        let mut total = SpecStats::default();
        for seed_tok in 0..16i32 {
            let prompt = [seed_tok, seed_tok + 1];
            let (_, s) = spec_generate(&t, &sd, &prompt, 18, GenParams::default()).unwrap();
            total.add(&s);
        }
        assert!(total.drafted > 100, "drafted only {} tokens", total.drafted);
        let rate = total.accept_rate();
        assert!((0.45..=0.95).contains(&rate), "accept rate {rate} implausible for p=0.25");
        // a perfect draft accepts everything
        let perfect = SpecDecoder::new(draft(0.0), 4);
        let (_, s) = spec_generate(&t, &perfect, &[1, 2], 17, GenParams::default()).unwrap();
        assert_eq!(s.accepted, s.drafted, "zero-divergence draft must always match");
        assert!(s.accept_rate() > 0.999);
    }

    #[test]
    fn registry_validates_shape() {
        let entry = |name: &str, vocab: usize| ModelEntry {
            name: name.to_string(),
            backend: SyntheticBackend::new(vocab, SEQ, 1),
            spec: None,
        };
        assert!(ModelRegistry::<SyntheticBackend>::new(vec![]).is_err(), "empty registry");
        let dup = ModelRegistry::new(vec![entry("a", 32), entry("a", 32)]);
        assert!(dup.unwrap_err().to_string().contains("duplicate"));
        let mix = ModelRegistry::new(vec![entry("a", 32), entry("b", 64)]);
        assert!(mix.unwrap_err().to_string().contains("vocab"));
        let bad_draft = ModelRegistry::new(vec![ModelEntry {
            name: "a".to_string(),
            backend: SyntheticBackend::new(32, SEQ, 1),
            spec: Some(SpecDecoder::new(SyntheticBackend::new(64, SEQ, 1), 4)),
        }]);
        assert!(bad_draft.unwrap_err().to_string().contains("draft vocab"));
        let zero_k = ModelRegistry::new(vec![ModelEntry {
            name: "a".to_string(),
            backend: SyntheticBackend::new(32, SEQ, 1),
            spec: Some(SpecDecoder::new(SyntheticBackend::new(32, SEQ, 1), 0)),
        }]);
        assert!(zero_k.unwrap_err().to_string().contains("k must be >= 1"));
    }

    #[test]
    fn registry_routes_runs_to_their_models_and_counts_queues() {
        // two models with different seeds: outputs must match each
        // model's own sequential reference, interleaved in one batch
        let reg = ModelRegistry::new(vec![
            ModelEntry {
                name: "a".to_string(),
                backend: SyntheticBackend::new(VOCAB, SEQ, 1),
                spec: None,
            },
            ModelEntry {
                name: "b".to_string(),
                backend: SyntheticBackend::new(VOCAB, SEQ, 2),
                spec: Some(SpecDecoder::new(
                    SyntheticBackend::new(VOCAB, SEQ, 2).with_divergence(0.2, 5),
                    3,
                )),
            },
        ])
        .unwrap();
        let greedy = GenParams::default;
        let ref_a =
            generate(&SyntheticBackend::new(VOCAB, SEQ, 1), &[3, 1], 10, greedy()).unwrap();
        let ref_b =
            generate(&SyntheticBackend::new(VOCAB, SEQ, 2), &[3, 1], 10, greedy()).unwrap();
        let mut slots = vec![
            DecodeSlot::new(&[3, 1], 10, reg.seq_len()).unwrap(),
            DecodeSlot::new(&[3, 1], 10, reg.seq_len()).unwrap(),
            DecodeSlot::new(&[3, 1], 10, reg.seq_len()).unwrap(),
        ];
        reg.bind_model(&slots[0], Some("a")).unwrap();
        reg.bind_model(&slots[1], Some("b")).unwrap();
        reg.bind_model(&slots[2], None).unwrap(); // default = entry 0
        let unknown = reg.bind_model(&slots[0], Some("nope")).unwrap_err();
        assert!(unknown.to_string().contains("unknown"));
        while slots.iter().any(|s| !s.done()) {
            reg.spec_step(&mut slots).expect("registry owns the tick").unwrap();
        }
        assert_eq!(slots[0].out, ref_a);
        assert_eq!(slots[1].out, ref_b, "speculative route changed the stream");
        assert_eq!(slots[2].out, ref_a, "unnamed request must route to entry 0");
        for s in &slots {
            reg.release(s);
        }
        let queues = reg.model_queue_stats();
        assert_eq!(queues.len(), 2);
        assert_eq!((queues[0].admitted, queues[0].completed), (2, 2));
        assert_eq!((queues[1].admitted, queues[1].completed), (1, 1));
        assert!(queues[0].peak_depth >= 2);
        let spec = reg.spec_stats().expect("a drafted entry reports spec stats");
        assert!(spec.drafted > 0 && spec.verify_passes > 0);
        // double release is safe
        reg.release(&slots[0]);
    }

    #[test]
    fn registry_without_drafts_reports_no_spec_stats() {
        let reg = ModelRegistry::new(vec![ModelEntry {
            name: "only".to_string(),
            backend: target(),
            spec: None,
        }])
        .unwrap();
        assert!(reg.spec_stats().is_none());
        assert_eq!(reg.vocab(), VOCAB);
        assert_eq!(reg.seq_len(), SEQ);
    }
}
