//! HTTP/1.1 + SSE front end over the shared serving engine.
//!
//! `faar serve --transport http` (or `auto`) accepts
//! `POST /v1/generate` with the exact same JSON request body the
//! TCP-JSONL protocol uses as a line — the body streams through the
//! [`IncrementalDecoder`] as it arrives and the validated request
//! enters the same scheduler/admission loop, so protocol v2 semantics
//! (params validation, clamping, frame ordering, reorder buffers,
//! disconnect cancellation) are shared with raw TCP rather than
//! reimplemented.
//!
//! Response mapping (DESIGN.md §14):
//!
//! * non-streaming → one `application/json` response, keep-alive,
//!   status from the structured error code (`bad_*` → 400,
//!   `oversized` → 413, `length_required` → 411, `not_found` → 404,
//!   `method_not_allowed` → 405, `overloaded`/`shutting_down` → 503
//!   with a `Retry-After` header, `deadline_exceeded` → 504,
//!   `backend`/`backend_panic` → 500);
//! * `GET /healthz` → 200 while the process is alive;
//!   `GET /readyz` → 200 normally, 503 once a drain begins — both
//!   answer through the writer's reorder queue so they stay in
//!   request order with pipelined generate calls;
//! * `"stream": true` → a `text/event-stream` response: one
//!   `data: {"token":...}` event per token frame, then the terminal
//!   response object as the last event, then connection close (the
//!   preamble promises `Connection: close`);
//! * every rejection body is the same `{"error":{code,message}}`
//!   object a JSONL client would get as a line.
//!
//! Deliberate simplifications, matching the offline no-deps build: no
//! chunked transfer encoding (rejected with a structured error),
//! `Expect: 100-continue` is ignored (clients fall back to sending
//! the body), and a request pipelined behind an SSE stream dies with
//! the promised connection close.

use std::io::ErrorKind;
use std::io::Read as _;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::SyncSender;
use std::time::Instant;

use super::codec::{err_oversized, CodecLimits, DecodeEvent, FrameDecoder as _, IncrementalDecoder};
use super::scheduler::{DecodeRequest, Decoded, ServeError, ServeOptions, WriterMsg};
use super::{parse_request, ConnProgress, ParsedRequest};
use crate::data::Tokenizer;

/// Upper bound on an HTTP request head (request line + headers).
const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Response preamble for an SSE stream. `Connection: close` is a
/// promise the writer keeps after the terminal event.
pub(crate) const SSE_PREAMBLE: &[u8] = b"HTTP/1.1 200 OK\r\n\
content-type: text/event-stream\r\n\
cache-control: no-cache\r\n\
connection: close\r\n\
\r\n";

/// The HTTP status for a terminal result, derived from the structured
/// error code (the body carries the full error object either way).
pub(crate) fn status_for(result: &Result<Decoded, ServeError>) -> u16 {
    match result {
        Ok(_) => 200,
        Err(e) => match e.code {
            "bad_json" | "bad_request" | "bad_params" | "bad_token" | "empty_prompt" => 400,
            "length_required" => 411,
            "oversized" => 413,
            "not_found" | "unknown_model" => 404,
            "method_not_allowed" => 405,
            "overloaded" | "shutting_down" => 503,
            "deadline_exceeded" => 504,
            _ => 500,
        },
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Status",
    }
}

/// A complete keep-alive `application/json` response.
pub(crate) fn json_response(status: u16, body: &str) -> Vec<u8> {
    json_response_with(status, body, None)
}

/// A complete keep-alive `application/json` response for a terminal
/// result: status from the structured error code, plus a `Retry-After`
/// header (whole seconds, rounded up — the header's granularity) when
/// the rejection carries a backoff hint.
pub(crate) fn terminal_response(result: &Result<Decoded, ServeError>, body: &str) -> Vec<u8> {
    let retry = match result {
        Err(e) => e.retry_after_ms,
        Ok(_) => None,
    };
    json_response_with(status_for(result), body, retry)
}

fn json_response_with(status: u16, body: &str, retry_after_ms: Option<u64>) -> Vec<u8> {
    let retry = match retry_after_ms {
        Some(ms) => format!("retry-after: {}\r\n", ms.div_ceil(1000).max(1)),
        None => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\n{retry}content-length: {}\r\n\r\n",
        reason(status),
        body.len()
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}

/// The parsed request head: only what routing needs.
struct Head {
    method: String,
    path: String,
    content_length: Option<usize>,
    chunked: bool,
}

/// Locate the end of the head: `(head_len, separator_len)` for the
/// first `\r\n\r\n` or `\n\n`.
fn find_head_end(buf: &[u8]) -> Option<(usize, usize)> {
    for i in 0..buf.len() {
        if buf[i..].starts_with(b"\r\n\r\n") {
            return Some((i, 4));
        }
        if buf[i..].starts_with(b"\n\n") {
            return Some((i, 2));
        }
    }
    None
}

fn bad(msg: impl Into<String>) -> ServeError {
    ServeError::new("bad_request", msg)
}

fn parse_head(bytes: &[u8]) -> Result<Head, ServeError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| bad("request head is not valid UTF-8"))?;
    let mut lines = text.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Err(bad("malformed HTTP request line"));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad(format!("unsupported protocol version '{version}'")));
    }
    // route on the path only; a query string is ignored
    let path = target.split('?').next().unwrap_or(target).to_string();
    let mut content_length = None;
    let mut chunked = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad("malformed header line"));
        };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                let n: usize =
                    value.parse().map_err(|_| bad("invalid content-length header"))?;
                content_length = Some(n);
            }
            "transfer-encoding" => {
                if !value.eq_ignore_ascii_case("identity") {
                    chunked = true;
                }
            }
            _ => {}
        }
    }
    Ok(Head { method: method.to_string(), path, content_length, chunked })
}

/// Read more bytes into `carry`. `Ok(false)` = clean EOF. A read
/// timeout only reaps *idle* connections — while responses are still
/// owed the reader keeps waiting, same policy as the JSONL loop.
fn fill(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    progress: &ConnProgress,
    peer: &str,
) -> std::io::Result<bool> {
    let mut buf = [0u8; 4096];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return Ok(false),
            Ok(n) => {
                carry.extend_from_slice(&buf[..n]);
                return Ok(true);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if progress.issued.load(Ordering::Acquire)
                    > progress.written.load(Ordering::Acquire)
                {
                    continue;
                }
                crate::debug!("connection {peer}: idle past read timeout, closing");
                return Err(e);
            }
            Err(e) => return Err(e),
        }
    }
}

/// Discard an error-path request body so the connection can keep
/// serving pipelined requests. Returns `false` (close instead) when
/// the body is missing a sane bound or the stream dies.
fn skip_body(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    progress: &ConnProgress,
    peer: &str,
    content_length: Option<usize>,
    cap: usize,
) -> bool {
    let Some(mut remaining) = content_length else {
        return true; // no body to skip
    };
    if remaining > cap {
        return false;
    }
    while remaining > 0 {
        if carry.is_empty() && !matches!(fill(stream, carry, progress, peer), Ok(true)) {
            return false;
        }
        let take = remaining.min(carry.len());
        carry.drain(..take);
        remaining -= take;
    }
    true
}

/// Per-connection HTTP read loop: parse heads, route, stream bodies
/// through the incremental decoder, and hand validated requests to the
/// same scheduler queue the JSONL readers use. `carry` holds bytes the
/// transport sniffer already consumed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn reader_loop(
    mut stream: TcpStream,
    carry: Vec<u8>,
    conn: u64,
    peer: &str,
    req_tx: &SyncSender<DecodeRequest>,
    w_tx: &SyncSender<WriterMsg>,
    opts: &ServeOptions,
    tok: &Tokenizer,
    progress: &ConnProgress,
) {
    let vocab = tok.vocab();
    let mut carry = carry;
    let mut seq = 0u64;
    // assign the next seq and send a structured rejection; false =
    // writer gone, close the connection
    let respond_err = |seq: &mut u64, e: ServeError| -> bool {
        let this = *seq;
        *seq += 1;
        progress.issued.store(*seq, Ordering::Release);
        w_tx.send(WriterMsg::Resp { seq: this, result: Err(e) }).is_ok()
    };
    'conn: loop {
        // ---- request head ----
        let (head_len, sep_len) = loop {
            if let Some(x) = find_head_end(&carry) {
                break x;
            }
            if carry.len() > MAX_HEAD_BYTES {
                respond_err(
                    &mut seq,
                    bad(format!("request head exceeds {MAX_HEAD_BYTES} bytes")),
                );
                break 'conn;
            }
            match fill(&mut stream, &mut carry, progress, peer) {
                Ok(true) => {}
                Ok(false) => {
                    // clean EOF between requests is a normal close; a
                    // partial head gets no response (we cannot frame one
                    // the client would still read)
                    break 'conn;
                }
                Err(_) => break 'conn,
            }
        };
        let head = parse_head(&carry[..head_len]);
        carry.drain(..head_len + sep_len);
        let head = match head {
            Ok(h) => h,
            Err(e) => {
                // body framing is unknown after a bad head: answer, close
                respond_err(&mut seq, e);
                break 'conn;
            }
        };
        // ---- routing ----
        // health endpoints answer without touching the scheduler, but
        // still consume a sequence number and ride the writer's reorder
        // queue so pipelined responses stay in request order
        if head.method == "GET" && (head.path == "/healthz" || head.path == "/readyz") {
            let this = seq;
            seq += 1;
            progress.issued.store(seq, Ordering::Release);
            // liveness never flips (a responding process is alive);
            // readiness goes 503 the moment a drain begins so load
            // balancers stop routing new work here
            let ready = head.path == "/healthz" || !opts.lifecycle.draining();
            let (status, state) = if ready { (200, "ok") } else { (503, "draining") };
            let body = format!("{{\"status\":\"{state}\"}}");
            let resp = String::from_utf8(json_response(status, &body))
                .expect("http responses are always UTF-8");
            if w_tx.send(WriterMsg::Raw { seq: this, body: resp }).is_err() {
                break 'conn;
            }
            if !skip_body(
                &mut stream,
                &mut carry,
                progress,
                peer,
                head.content_length,
                opts.max_line_bytes,
            ) {
                break 'conn;
            }
            continue;
        }
        if head.chunked {
            respond_err(&mut seq, bad("chunked transfer encoding is not supported"));
            break 'conn;
        }
        if head.method != "POST" {
            if !respond_err(
                &mut seq,
                ServeError::new(
                    "method_not_allowed",
                    format!("method '{}' not allowed; use POST /v1/generate", head.method),
                ),
            ) {
                break 'conn;
            }
            if !skip_body(
                &mut stream,
                &mut carry,
                progress,
                peer,
                head.content_length,
                opts.max_line_bytes,
            ) {
                break 'conn;
            }
            continue;
        }
        if head.path != "/v1/generate" {
            if !respond_err(
                &mut seq,
                ServeError::new(
                    "not_found",
                    format!("no route '{}'; use POST /v1/generate", head.path),
                ),
            ) {
                break 'conn;
            }
            if !skip_body(
                &mut stream,
                &mut carry,
                progress,
                peer,
                head.content_length,
                opts.max_line_bytes,
            ) {
                break 'conn;
            }
            continue;
        }
        let Some(content_length) = head.content_length else {
            respond_err(
                &mut seq,
                ServeError::new("length_required", "a content-length header is required"),
            );
            break 'conn;
        };
        if content_length > opts.max_line_bytes {
            // refuse before reading: same bound, same error code the
            // JSONL path applies to an oversized line
            respond_err(&mut seq, err_oversized(opts.max_line_bytes));
            break 'conn;
        }
        // ---- body: incremental decode as the bytes arrive ----
        let mut decoder = IncrementalDecoder::new(CodecLimits::from_options(opts));
        let mut events: Vec<DecodeEvent> = Vec::new();
        let mut remaining = content_length;
        while remaining > 0 {
            if carry.is_empty() && !matches!(fill(&mut stream, &mut carry, progress, peer), Ok(true))
            {
                // truncated body: the request never completed
                break 'conn;
            }
            let take = remaining.min(carry.len());
            decoder.feed(&carry[..take], &mut events);
            carry.drain(..take);
            remaining -= take;
        }
        decoder.finish(&mut events);
        let outcome = match events.as_slice() {
            [] => Err(ServeError::new("bad_json", "empty request body")),
            [DecodeEvent::Reject(e), ..] => Err(e.clone()),
            [DecodeEvent::Frame(_), _, ..] => Err(bad(
                "request body must contain exactly one JSON document",
            )),
            [DecodeEvent::Frame(frame)] => parse_request(frame, tok, vocab, opts),
        };
        let this = seq;
        seq += 1;
        progress.issued.store(seq, Ordering::Release);
        match outcome {
            Ok(ParsedRequest { prompt, max_tokens, params, stream: sse, model, deadline_ms }) => {
                // declare the framing mode first: writer-queue order
                // guarantees the writer knows before any frame arrives
                if w_tx.send(WriterMsg::Mode { seq: this, sse }).is_err() {
                    seq = this;
                    break 'conn;
                }
                let req = DecodeRequest {
                    conn,
                    seq: this,
                    prompt,
                    max_tokens,
                    params,
                    stream: sse,
                    model,
                    deadline_ms,
                    enqueued: Instant::now(),
                };
                if req_tx.send(req).is_err() {
                    // scheduler gone: this request will never be
                    // answered — don't make the writer wait for it
                    seq = this;
                    break 'conn;
                }
            }
            Err(e) => {
                if w_tx.send(WriterMsg::Resp { seq: this, result: Err(e) }).is_err() {
                    break 'conn;
                }
            }
        }
    }
    let _ = w_tx.send(WriterMsg::Done { next_seq: seq });
    crate::debug!("connection {peer}: http reader closed after {seq} requests");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"POST / HTTP/1.1\r\nhost: x\r\n\r\nbody"), Some((24, 4)));
        assert_eq!(find_head_end(b"POST / HTTP/1.1\nhost: x\n\nbody"), Some((23, 2)));
        assert_eq!(find_head_end(b"POST / HTTP/1.1\r\nhost: x\r\n"), None);
    }

    #[test]
    fn head_parsing() {
        let h = parse_head(
            b"POST /v1/generate?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 42\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.path, "/v1/generate");
        assert_eq!(h.content_length, Some(42));
        assert!(!h.chunked);
        let h = parse_head(b"GET / HTTP/1.0\ntransfer-encoding: chunked\n").unwrap();
        assert!(h.chunked);
        assert!(parse_head(b"POST /v1/generate").is_err()); // no version
        assert!(parse_head(b"POST /v1/generate SPDY/3").is_err());
        assert!(parse_head(b"POST / HTTP/1.1\r\ncontent-length: nope\r\n").is_err());
        assert!(parse_head(b"POST / HTTP/1.1\r\njunk line\r\n").is_err());
    }

    #[test]
    fn status_mapping() {
        let ok: Result<Decoded, ServeError> =
            Ok(Decoded { tokens: vec![], latency_ms: 0.0, queue_ms: 0.0 });
        assert_eq!(status_for(&ok), 200);
        let s = |code: &'static str| status_for(&Err(ServeError::new(code, "x")));
        assert_eq!(s("bad_json"), 400);
        assert_eq!(s("bad_request"), 400);
        assert_eq!(s("bad_params"), 400);
        assert_eq!(s("bad_token"), 400);
        assert_eq!(s("empty_prompt"), 400);
        assert_eq!(s("length_required"), 411);
        assert_eq!(s("oversized"), 413);
        assert_eq!(s("not_found"), 404);
        assert_eq!(s("unknown_model"), 404);
        assert_eq!(s("method_not_allowed"), 405);
        assert_eq!(s("backend"), 500);
        assert_eq!(s("backend_panic"), 500);
        assert_eq!(s("overloaded"), 503);
        assert_eq!(s("shutting_down"), 503);
        assert_eq!(s("deadline_exceeded"), 504);
    }

    #[test]
    fn retry_after_header_rounds_up_to_seconds() {
        let shed: Result<Decoded, ServeError> =
            Err(ServeError::new("overloaded", "shed").with_retry_after(1500));
        let text = String::from_utf8(terminal_response(&shed, "{}")).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("retry-after: 2\r\n"), "{text}");
        // sub-second hints still round up to the header's 1s floor
        let shed: Result<Decoded, ServeError> =
            Err(ServeError::new("overloaded", "shed").with_retry_after(10));
        let text = String::from_utf8(terminal_response(&shed, "{}")).unwrap();
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        // no hint → no header
        let plain: Result<Decoded, ServeError> = Err(ServeError::new("bad_json", "x"));
        let text = String::from_utf8(terminal_response(&plain, "{}")).unwrap();
        assert!(!text.contains("retry-after"), "{text}");
    }

    #[test]
    fn json_response_shape() {
        let resp = json_response(400, "{\"error\":{}}");
        let text = String::from_utf8(resp).unwrap();
        assert!(text.starts_with("HTTP/1.1 400 Bad Request\r\n"));
        assert!(text.contains("content-length: 12\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"error\":{}}"));
    }
}
