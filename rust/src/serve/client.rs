//! Small typed client for the serving protocol, over either transport.
//!
//! One place that knows how to connect, build v1/v2 request lines
//! (sampling `params`, `stream`), and read response lines / token
//! frames back — so the integration tests, the load-generator bench,
//! and example snippets stop hand-rolling the wire format. Protocol
//! rejections surface as typed [`ProtocolError`]s (match on
//! [`ProtocolError::code`]); transport failures surface as `Err`.
//!
//! [`Client::connect`] speaks the reference TCP-JSONL protocol;
//! [`Client::connect_http`] sends the same JSON documents as
//! `POST /v1/generate` bodies and reads HTTP responses back (an SSE
//! event stream for streaming requests — note the server closes the
//! connection after a stream's terminal event, so streaming HTTP
//! clients are one-shot). [`Client::last_status`] exposes the most
//! recent HTTP status for tests that assert on the mapping.
//!
//! ```no_run
//! use nvfp4_faar::serve::client::{Client, ClientRequest};
//! # fn main() -> anyhow::Result<()> {
//! let mut c = Client::connect("127.0.0.1:7745")?;
//! let req = ClientRequest::text("ba kuto").max_tokens(8).sampled(0.8, 42).top_p(0.9);
//! let reply = c.request(&req)?.map_err(|e| anyhow::anyhow!("{}: {}", e.code, e.message))?;
//! println!("{} -> {}", reply.tokens.len(), reply.text);
//! # Ok(())
//! # }
//! ```

use std::io::{BufRead, BufReader, Read as _, Write as _};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// A request under construction: `None` / empty fields stay off the
/// wire, so a default request is a plain v1 greedy line.
#[derive(Clone, Debug, Default)]
pub struct ClientRequest {
    /// text prompt (mutually exclusive with `tokens`; `tokens` wins)
    pub prompt: Option<String>,
    /// prompt as raw token ids
    pub tokens: Option<Vec<i32>>,
    /// continuation length (server default when `None`)
    pub max_tokens: Option<usize>,
    /// sampling temperature (`params.temperature`)
    pub temperature: Option<f64>,
    /// top-k restriction (`params.top_k`)
    pub top_k: Option<usize>,
    /// nucleus restriction (`params.top_p`)
    pub top_p: Option<f64>,
    /// repetition penalty (`params.repetition_penalty`)
    pub repetition_penalty: Option<f64>,
    /// sampler seed (`params.seed`)
    pub seed: Option<u64>,
    /// stop token ids (`params.stop_tokens`)
    pub stop_tokens: Vec<i32>,
    /// text stop sequences (`params.stop`)
    pub stop: Vec<String>,
    /// request incremental token frames
    pub stream: bool,
    /// hosted model to route to (`"model"`; server default when `None`)
    pub model: Option<String>,
    /// total server-side time budget (`"deadline_ms"`; server default
    /// when `None`)
    pub deadline_ms: Option<u64>,
}

impl ClientRequest {
    /// A greedy request from a text prompt.
    pub fn text(prompt: impl Into<String>) -> ClientRequest {
        ClientRequest { prompt: Some(prompt.into()), ..ClientRequest::default() }
    }

    /// A greedy request from raw token ids.
    pub fn tokens(tokens: impl Into<Vec<i32>>) -> ClientRequest {
        ClientRequest { tokens: Some(tokens.into()), ..ClientRequest::default() }
    }

    /// Set the continuation length.
    pub fn max_tokens(mut self, n: usize) -> ClientRequest {
        self.max_tokens = Some(n);
        self
    }

    /// Enable seeded temperature sampling.
    pub fn sampled(mut self, temperature: f64, seed: u64) -> ClientRequest {
        self.temperature = Some(temperature);
        self.seed = Some(seed);
        self
    }

    /// Restrict sampling to the `k` highest logits.
    pub fn top_k(mut self, k: usize) -> ClientRequest {
        self.top_k = Some(k);
        self
    }

    /// Restrict sampling to the nucleus of cumulative probability `p`.
    pub fn top_p(mut self, p: f64) -> ClientRequest {
        self.top_p = Some(p);
        self
    }

    /// Penalize tokens already visible in the decode window.
    pub fn repetition_penalty(mut self, x: f64) -> ClientRequest {
        self.repetition_penalty = Some(x);
        self
    }

    /// Request incremental token frames (`"stream": true`).
    pub fn streaming(mut self) -> ClientRequest {
        self.stream = true;
        self
    }

    /// Route the request to a named hosted model (`--models` servers).
    pub fn model(mut self, name: impl Into<String>) -> ClientRequest {
        self.model = Some(name.into());
        self
    }

    /// Bound the request's total server-side time: queue wait plus
    /// decode (`"deadline_ms"`; expired requests end with a structured
    /// `deadline_exceeded` error).
    pub fn deadline_ms(mut self, ms: u64) -> ClientRequest {
        self.deadline_ms = Some(ms);
        self
    }

    /// Serialize to one protocol line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(toks) = &self.tokens {
            fields.push((
                "tokens",
                Json::Arr(toks.iter().map(|&t| Json::num(t as f64)).collect()),
            ));
        } else if let Some(p) = &self.prompt {
            fields.push(("prompt", Json::str(p.as_str())));
        }
        if let Some(n) = self.max_tokens {
            fields.push(("max_tokens", Json::num(n as f64)));
        }
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        let mut params: Vec<(&str, Json)> = Vec::new();
        if let Some(t) = self.temperature {
            params.push(("temperature", Json::Num(t)));
        }
        if let Some(k) = self.top_k {
            params.push(("top_k", Json::num(k as f64)));
        }
        if let Some(p) = self.top_p {
            params.push(("top_p", Json::Num(p)));
        }
        if let Some(x) = self.repetition_penalty {
            params.push(("repetition_penalty", Json::Num(x)));
        }
        if let Some(s) = self.seed {
            params.push(("seed", Json::num(s as f64)));
        }
        if !self.stop_tokens.is_empty() {
            params.push((
                "stop_tokens",
                Json::Arr(self.stop_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
            ));
        }
        if !self.stop.is_empty() {
            params.push((
                "stop",
                Json::Arr(self.stop.iter().map(|s| Json::str(s.as_str())).collect()),
            ));
        }
        if !params.is_empty() {
            fields.push(("params", Json::obj(params)));
        }
        if self.stream {
            fields.push(("stream", Json::Bool(true)));
        }
        if let Some(m) = &self.model {
            fields.push(("model", Json::str(m.as_str())));
        }
        Json::obj(fields).to_string()
    }
}

/// A completed decode as reported by the server.
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    /// the decoded continuation
    pub tokens: Vec<i32>,
    /// the continuation rendered through the server tokenizer
    pub text: String,
    /// request-to-completion wall time, server-side
    pub latency_ms: f64,
    /// time the request waited before its first decode step
    pub queue_ms: f64,
}

/// A structured protocol rejection (`{"error":{code,message}}`).
#[derive(Clone, Debug, PartialEq)]
pub struct ProtocolError {
    /// machine-matchable error class (`bad_json`, `bad_params`, ...)
    pub code: String,
    /// human-readable detail
    pub message: String,
    /// server backoff hint in milliseconds (shed/drain rejections)
    pub retry_after_ms: Option<u64>,
}

impl ProtocolError {
    /// Whether this rejection was issued *before* the request reached a
    /// decode slot — the only class a client may safely retry without
    /// risking double execution (`overloaded` queue sheds and
    /// `shutting_down` drain refusals; both happen at admission).
    pub fn is_pre_admission(&self) -> bool {
        matches!(self.code.as_str(), "overloaded" | "shutting_down")
    }
}

/// Capped exponential backoff with deterministic jitter, applied only
/// to pre-admission rejections (see [`ProtocolError::is_pre_admission`]).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// retries after the initial attempt (0 = never retry)
    pub max_retries: u32,
    /// delay before the first retry (doubles each attempt)
    pub base_ms: u64,
    /// upper bound on any single delay, including server hints
    pub cap_ms: u64,
    /// jitter seed, so test backoff schedules are reproducible
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 4, base_ms: 50, cap_ms: 2_000, seed: 0 }
    }
}

impl RetryPolicy {
    /// The delay before 0-based retry `attempt`: the server's
    /// `Retry-After` hint when it sent one (capped), otherwise
    /// `base * 2^attempt` capped, with ±25% deterministic jitter so a
    /// shed burst of clients does not reconverge in lockstep.
    pub fn delay(&self, attempt: u32, hint_ms: Option<u64>) -> Duration {
        if let Some(ms) = hint_ms {
            return Duration::from_millis(ms.min(self.cap_ms));
        }
        let exp = self.base_ms.saturating_mul(1u64 << attempt.min(16)).min(self.cap_ms);
        let jitter = 0.75 + 0.5 * Rng::new(self.seed ^ 0x5245_5452).fork(attempt as u64).f64();
        Duration::from_millis((exp as f64 * jitter) as u64)
    }
}

/// One incremental token frame of a streaming request.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamFrame {
    /// zero-based position in the request's output
    pub index: usize,
    /// the decoded token
    pub token: i32,
    /// the token rendered through the server tokenizer
    pub text: String,
}

/// What one response line held: a completion or a protocol rejection.
pub type Reply = std::result::Result<Completion, ProtocolError>;

/// How the client frames requests and responses on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WireMode {
    /// one JSON line per request/response (raw TCP)
    Jsonl,
    /// `POST /v1/generate` per request; JSON or SSE responses
    Http,
}

/// A connected protocol client (blocking).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    mode: WireMode,
    /// status of the most recent HTTP response (`None` before the
    /// first, and always in JSONL mode)
    last_status: Option<u16>,
}

impl Client {
    /// Connect with a 60 s read timeout (tests and benches must fail,
    /// not hang, if the server wedges).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        Client::connect_timeout(addr, Duration::from_secs(60))
    }

    /// Connect with an explicit read timeout.
    pub fn connect_timeout(addr: impl ToSocketAddrs, timeout: Duration) -> Result<Client> {
        Client::connect_inner(addr, timeout, WireMode::Jsonl)
    }

    /// Connect in HTTP mode with a 60 s read timeout: every request is
    /// a `POST /v1/generate`, every reply an HTTP response.
    pub fn connect_http(addr: impl ToSocketAddrs) -> Result<Client> {
        Client::connect_http_timeout(addr, Duration::from_secs(60))
    }

    /// Connect in HTTP mode with an explicit read timeout.
    pub fn connect_http_timeout(addr: impl ToSocketAddrs, timeout: Duration) -> Result<Client> {
        Client::connect_inner(addr, timeout, WireMode::Http)
    }

    fn connect_inner(
        addr: impl ToSocketAddrs,
        timeout: Duration,
        mode: WireMode,
    ) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_read_timeout(Some(timeout)).context("set read timeout")?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(Client { stream, reader, mode, last_status: None })
    }

    /// The HTTP status of the most recent response (`None` before the
    /// first response, and always in JSONL mode).
    pub fn last_status(&self) -> Option<u16> {
        self.last_status
    }

    /// Send one request without waiting for the reply (pipelining).
    pub fn send(&mut self, req: &ClientRequest) -> Result<()> {
        self.send_raw(&req.to_line())
    }

    /// Send a raw request body verbatim (malformed-input tests). In
    /// JSONL mode it goes out as one line; in HTTP mode as one POST.
    pub fn send_raw(&mut self, body: &str) -> Result<()> {
        match self.mode {
            WireMode::Jsonl => {
                self.stream.write_all(body.as_bytes())?;
                self.stream.write_all(b"\n")?;
            }
            WireMode::Http => {
                let head = format!(
                    "POST /v1/generate HTTP/1.1\r\nhost: faar\r\n\
                     content-type: application/json\r\ncontent-length: {}\r\n\r\n",
                    body.len()
                );
                self.stream.write_all(head.as_bytes())?;
                self.stream.write_all(body.as_bytes())?;
            }
        }
        self.stream.flush()?;
        Ok(())
    }

    /// Read one terminal reply (completion or structured error). Fails
    /// on EOF, transport errors, or a token frame / event stream where
    /// a terminal reply was expected.
    pub fn read_reply(&mut self) -> Result<Reply> {
        match self.mode {
            WireMode::Jsonl => match self.read_line()? {
                Line::Reply(r) => Ok(r),
                Line::Frame(f) => bail!("expected a terminal reply, got token frame {f:?}"),
            },
            WireMode::Http => {
                let head = self.read_http_head()?;
                if head.sse {
                    bail!("expected a JSON response, got an SSE stream");
                }
                match parse_line(&self.read_http_body(&head)?)? {
                    Line::Reply(r) => Ok(r),
                    Line::Frame(f) => bail!("expected a terminal reply, got token frame {f:?}"),
                }
            }
        }
    }

    /// Round-trip one non-streaming request. The request is sent with
    /// `"stream": false` regardless of `req.stream` (the symmetric guard
    /// to [`Client::request_stream`]) — a streamed reply would leave
    /// frames buffered on the connection and desync every later read.
    pub fn request(&mut self, req: &ClientRequest) -> Result<Reply> {
        let req = ClientRequest { stream: false, ..req.clone() };
        self.send(&req)?;
        self.read_reply()
    }

    /// [`Client::request`] with automatic retry of pre-admission
    /// rejections: `overloaded` (queue shed) and `shutting_down` (drain
    /// refusal) are reissued after a capped exponential backoff that
    /// honors the server's `retry_after_ms` hint. Only those two codes
    /// retry — both are issued before the request ever reaches a decode
    /// slot, so a retry can never double-execute work. Transport errors
    /// are NOT retried (the original may be mid-decode server-side);
    /// they surface as `Err` for the caller to decide.
    pub fn request_with_retry(
        &mut self,
        req: &ClientRequest,
        policy: &RetryPolicy,
    ) -> Result<Reply> {
        let mut attempt = 0u32;
        loop {
            let reply = self.request(req)?;
            match &reply {
                Err(e) if e.is_pre_admission() && attempt < policy.max_retries => {
                    std::thread::sleep(policy.delay(attempt, e.retry_after_ms));
                    attempt += 1;
                }
                _ => return Ok(reply),
            }
        }
    }

    /// Round-trip one streaming request: returns the token frames (in
    /// order) and the terminal reply. The request is sent with
    /// `"stream": true` regardless of `req.stream`.
    pub fn request_stream(&mut self, req: &ClientRequest) -> Result<(Vec<StreamFrame>, Reply)> {
        let mut frames = Vec::new();
        let reply = self.request_stream_with(req, |f| frames.push(f.clone()))?;
        Ok((frames, reply))
    }

    /// [`Client::request_stream`] with a per-frame callback invoked the
    /// moment each token frame is read off the socket — the hook the
    /// serve bench uses to timestamp inter-token gaps as the client
    /// actually observes them, rather than after the whole stream landed.
    pub fn request_stream_with<F>(&mut self, req: &ClientRequest, mut on_frame: F) -> Result<Reply>
    where
        F: FnMut(&StreamFrame),
    {
        let req = ClientRequest { stream: true, ..req.clone() };
        self.send(&req)?;
        if self.mode == WireMode::Http {
            let head = self.read_http_head()?;
            if !head.sse {
                // a pre-stream rejection arrives as a plain JSON
                // response (the SSE preamble was never committed)
                return match parse_line(&self.read_http_body(&head)?)? {
                    Line::Reply(r) => Ok(r),
                    Line::Frame(f) => bail!("expected a reply, got token frame {f:?}"),
                };
            }
            loop {
                match self.read_sse_event()? {
                    Line::Frame(f) => on_frame(&f),
                    Line::Reply(r) => return Ok(r),
                }
            }
        }
        loop {
            match self.read_line()? {
                Line::Frame(f) => on_frame(&f),
                Line::Reply(r) => return Ok(r),
            }
        }
    }

    /// Ask the server to cancel request `seq` on this connection
    /// (`{"cancel": seq}` control frame). JSONL only — the control
    /// frame consumes no seq and gets no reply of its own; the
    /// cancelled request's slot answers with a structured `cancelled`
    /// error if it had not already completed. HTTP clients cancel by
    /// disconnecting instead.
    pub fn cancel(&mut self, seq: u64) -> Result<()> {
        if self.mode != WireMode::Jsonl {
            bail!("cancel frames are a JSONL-transport control message");
        }
        self.send_raw(&format!("{{\"cancel\":{seq}}}"))
    }

    /// Shut the connection down abruptly (disconnect-mid-decode tests).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    fn read_line(&mut self) -> Result<Line> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 || line.trim().is_empty() {
            bail!("server closed the connection");
        }
        parse_line(&line)
    }

    /// Read one HTTP response head, recording its status.
    fn read_http_head(&mut self) -> Result<HttpHead> {
        let mut status_line = String::new();
        if self.reader.read_line(&mut status_line)? == 0 {
            bail!("server closed the connection");
        }
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed HTTP status line {status_line:?}"))?;
        self.last_status = Some(status);
        let mut content_length = None;
        let mut sse = false;
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed inside a response head");
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                let value = value.trim();
                match name.trim().to_ascii_lowercase().as_str() {
                    "content-length" => {
                        content_length = Some(
                            value
                                .parse()
                                .with_context(|| format!("bad content-length {value:?}"))?,
                        );
                    }
                    "content-type" => sse = value.starts_with("text/event-stream"),
                    _ => {}
                }
            }
        }
        Ok(HttpHead { content_length, sse })
    }

    /// Read a content-length-framed response body as UTF-8 text.
    fn read_http_body(&mut self, head: &HttpHead) -> Result<String> {
        let n = head
            .content_length
            .ok_or_else(|| anyhow::anyhow!("response head carried no content-length"))?;
        let mut body = vec![0u8; n];
        self.reader.read_exact(&mut body).context("read response body")?;
        String::from_utf8(body).context("response body is not UTF-8")
    }

    /// Read the next `data:` event off an SSE stream.
    fn read_sse_event(&mut self) -> Result<Line> {
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("connection closed mid-stream");
            }
            let line = line.trim_end();
            if line.is_empty() {
                continue; // event separator
            }
            let Some(body) = line.strip_prefix("data: ") else {
                bail!("unexpected SSE line {line:?}");
            };
            return parse_line(body);
        }
    }
}

/// The response-head fields the client cares about.
struct HttpHead {
    content_length: Option<usize>,
    sse: bool,
}

enum Line {
    Frame(StreamFrame),
    Reply(Reply),
}

fn parse_line(line: &str) -> Result<Line> {
    let v = Json::parse(line).with_context(|| format!("response is not JSON: {line:?}"))?;
    if let Some(err) = v.get("error") {
        return Ok(Line::Reply(Err(ProtocolError {
            code: err.req("code")?.as_str()?.to_string(),
            message: err.req("message")?.as_str()?.to_string(),
            retry_after_ms: err
                .get("retry_after_ms")
                .and_then(|x| x.as_usize().ok())
                .map(|n| n as u64),
        })));
    }
    if let Some(t) = v.get("token") {
        return Ok(Line::Frame(StreamFrame {
            index: v.req("index")?.as_usize()?,
            token: t.as_f64()? as i32,
            text: v.req("text")?.as_str()?.to_string(),
        }));
    }
    let tokens = v
        .req("tokens")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_f64()? as i32))
        .collect::<Result<Vec<i32>>>()?;
    Ok(Line::Reply(Ok(Completion {
        tokens,
        text: v.req("text")?.as_str()?.to_string(),
        latency_ms: v.req("latency_ms")?.as_f64()?,
        queue_ms: v.req("queue_ms")?.as_f64()?,
    })))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_stay_v1_when_no_sampling_fields_set() {
        let line = ClientRequest::tokens(vec![1, 2]).max_tokens(4).to_line();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("params").is_none(), "default request must be a bare v1 line");
        assert!(v.get("stream").is_none());
        assert_eq!(v.req("tokens").unwrap().usize_arr().unwrap(), vec![1, 2]);
        assert_eq!(v.req("max_tokens").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn request_lines_carry_v2_params() {
        let req = ClientRequest::text("ba")
            .max_tokens(8)
            .sampled(0.8, 42)
            .top_k(5)
            .top_p(0.9)
            .repetition_penalty(1.1)
            .streaming();
        let v = Json::parse(&req.to_line()).unwrap();
        assert_eq!(v.req("prompt").unwrap().as_str().unwrap(), "ba");
        assert!(v.req("stream").unwrap().as_bool().unwrap());
        let p = v.req("params").unwrap();
        assert_eq!(p.req("temperature").unwrap().as_f64().unwrap(), 0.8);
        assert_eq!(p.req("top_k").unwrap().as_usize().unwrap(), 5);
        assert_eq!(p.req("top_p").unwrap().as_f64().unwrap(), 0.9);
        assert_eq!(p.req("seed").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn request_lines_carry_deadline() {
        let line = ClientRequest::tokens(vec![1]).deadline_ms(250).to_line();
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req("deadline_ms").unwrap().as_usize().unwrap(), 250);
        // and stays off the wire when unset
        let v = Json::parse(&ClientRequest::tokens(vec![1]).to_line()).unwrap();
        assert!(v.get("deadline_ms").is_none());
    }

    #[test]
    fn parse_line_reads_retry_after_hint() {
        let line = r#"{"error":{"code":"overloaded","message":"shed","retry_after_ms":120}}"#;
        match parse_line(line).unwrap() {
            Line::Reply(Err(e)) => {
                assert_eq!(e.code, "overloaded");
                assert_eq!(e.retry_after_ms, Some(120));
                assert!(e.is_pre_admission());
            }
            _ => panic!("expected an error"),
        }
        match parse_line(r#"{"error":{"code":"bad_json","message":"x"}}"#).unwrap() {
            Line::Reply(Err(e)) => {
                assert_eq!(e.retry_after_ms, None);
                assert!(!e.is_pre_admission());
            }
            _ => panic!("expected an error"),
        }
    }

    #[test]
    fn retry_policy_backoff_is_capped_jittered_and_hint_honoring() {
        let p = RetryPolicy { max_retries: 8, base_ms: 100, cap_ms: 1_000, seed: 7 };
        // a server hint wins over the schedule (capped)
        assert_eq!(p.delay(0, Some(120)), Duration::from_millis(120));
        assert_eq!(p.delay(0, Some(10_000)), Duration::from_millis(1_000));
        // deterministic for a fixed seed
        assert_eq!(p.delay(3, None), p.delay(3, None));
        // exponential-with-jitter stays within [0.75, 1.25] of base*2^n,
        // and the cap bounds late attempts
        for attempt in 0..8u32 {
            let exp = (100u64 << attempt).min(1_000);
            let d = p.delay(attempt, None).as_millis() as u64;
            assert!(
                d >= exp * 3 / 4 && d <= exp * 5 / 4,
                "attempt {attempt}: {d}ms outside jitter window of {exp}ms"
            );
        }
    }

    #[test]
    fn parse_line_distinguishes_frames_replies_and_errors() {
        match parse_line(r#"{"token":3,"index":0,"text":"fa"}"#).unwrap() {
            Line::Frame(f) => {
                assert_eq!(f, StreamFrame { index: 0, token: 3, text: "fa".into() })
            }
            _ => panic!("expected a frame"),
        }
        match parse_line(r#"{"tokens":[1,2],"text":"da fa","latency_ms":1.0,"queue_ms":0.1}"#)
            .unwrap()
        {
            Line::Reply(Ok(c)) => assert_eq!(c.tokens, vec![1, 2]),
            _ => panic!("expected a completion"),
        }
        match parse_line(r#"{"error":{"code":"bad_params","message":"nope"}}"#).unwrap() {
            Line::Reply(Err(e)) => assert_eq!(e.code, "bad_params"),
            _ => panic!("expected an error"),
        }
        assert!(parse_line("not json").is_err());
    }
}
