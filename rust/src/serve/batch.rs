//! Decode core shared by the sequential generator and the batched
//! scheduler.
//!
//! The generation API v2 contract is **logits-out**: a
//! [`StepBackend::step`] returns one raw `[vocab]` logits row per slot
//! and never selects a token. Selection — greedy argmax or sampled
//! through per-request [`GenParams`] — happens here, in
//! [`decode_step`], through the [`Sampler`] each [`DecodeSlot`] carries.
//! The sequential path ([`generate`] / [`generate_greedy`]), the
//! continuous-batching scheduler (`serve::scheduler`), the integration
//! tests, and the load-generator bench all run this one decode core, so
//! batched output is token-identical to sequential output for greedy
//! *and* seeded sampling alike:
//!
//! * [`DecodeSlot`] — one in-flight request: the `[T]` token window, the
//!   current position, the emitted tokens, the remaining budget, and the
//!   request's [`Sampler`] (selection state survives micro-batched
//!   scheduling unchanged). The window-slide rule (shift left by one
//!   when the buffer is full) is encoded once, here.
//! * [`argmax`] — NaN-safe greedy pick (`f32::total_cmp`, NaN logits are
//!   ignored rather than panicking the connection).
//! * [`RuntimeBackend`] — the deployed path: W4A4 logits through the
//!   `lm_logits_pos_aq` artifact, preferring a batched
//!   `lm_logits_pos_aq_b{B}` variant when the manifest lowered one, with
//!   the weight set resident on device via [`Runtime::prepare`].
//! * [`SyntheticBackend`] — a deterministic pure-rust stand-in with a
//!   configurable per-step cost model, so the serving engine is fully
//!   exercisable (tests, benches) without artifacts or a PJRT backend.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::sampling::{GenParams, Sampler};
use crate::runtime::{PreparedExec, Runtime, Value};
use crate::train::ParamSource;

/// The single-request artifact the deployed NVFP4 path decodes through.
pub const LOGITS_ARTIFACT: &str = "lm_logits_pos_aq";

/// NaN-safe greedy argmax: ignores NaN entries entirely (a NaN logit is
/// a model bug, not a reason to kill the connection), breaks ties toward
/// the later index via `total_cmp`, and returns 0 for an empty or all-NaN
/// row.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Process-unique slot-identity source (see [`DecodeSlot::id`]).
static NEXT_SLOT_ID: AtomicU64 = AtomicU64::new(1);

/// One in-flight decode: the fixed `[T]` token window, progress, and the
/// request's token-selection state. Construction rejects empty prompts —
/// decoding from a zeroed buffer is never meaningful output.
#[derive(Clone, Debug)]
pub struct DecodeSlot {
    /// process-unique slot identity, assigned at construction. Stateful
    /// backends key per-slot resources (the native backend's KV cache
    /// pages) on it; [`StepBackend::release`] frees them when the slot
    /// leaves the decode loop. Clones share the identity — a clone is the
    /// same logical request, not a new one.
    pub id: u64,
    /// token window, length = model seq_len
    pub buf: Vec<i32>,
    /// index of the last real token in `buf`
    pub pos: usize,
    /// tokens emitted so far
    pub out: Vec<i32>,
    remaining: usize,
    /// the request's selection state (greedy by default); lives in the
    /// slot so micro-batched scheduling carries it across steps exactly
    /// like sequential decoding does
    sampler: Sampler,
}

impl DecodeSlot {
    /// Seed a greedy slot from a prompt (keeps the last `seq_len` tokens).
    pub fn new(prompt: &[i32], max_tokens: usize, seq_len: usize) -> Result<DecodeSlot> {
        DecodeSlot::with_params(prompt, max_tokens, seq_len, GenParams::default())
    }

    /// Seed a slot with explicit generation parameters.
    pub fn with_params(
        prompt: &[i32],
        max_tokens: usize,
        seq_len: usize,
        params: GenParams,
    ) -> Result<DecodeSlot> {
        if prompt.is_empty() {
            bail!("empty prompt: nothing to condition the decode on");
        }
        if seq_len == 0 {
            bail!("model seq_len is 0");
        }
        params.validate()?;
        let mut buf = vec![0i32; seq_len];
        let plen = prompt.len().min(seq_len);
        buf[..plen].copy_from_slice(&prompt[prompt.len() - plen..]);
        Ok(DecodeSlot {
            id: NEXT_SLOT_ID.fetch_add(1, Ordering::Relaxed),
            buf,
            // plen >= 1, so this never underflows to a zeroed-buffer decode
            pos: plen - 1,
            out: Vec::with_capacity(max_tokens),
            remaining: max_tokens,
            sampler: Sampler::new(params),
        })
    }

    /// The generation parameters this slot decodes under.
    pub fn params(&self) -> &GenParams {
        self.sampler.params()
    }

    /// The visible token window: every real token up to and including
    /// the current position. This is the slice a backend must condition
    /// row `i`'s logits on — the native backend derives its KV-cache
    /// coherence (and its prefill/catch-up split) from exactly this
    /// view every step.
    pub fn window(&self) -> &[i32] {
        &self.buf[..=self.pos]
    }

    /// Select the next token from a logits row (greedy or sampled, per
    /// the slot's [`GenParams`]), apply the stop conditions, and advance
    /// the window. `vmax` clamps the selection to the backend vocab.
    ///
    /// Returns the emitted token, or `None` when a stop token ended the
    /// request without emitting — the speculative decoder compares this
    /// against the draft's proposal to decide whether the next verify
    /// row is still valid.
    pub fn accept(&mut self, logits: &[f32], vmax: i32) -> Option<i32> {
        debug_assert!(self.remaining > 0, "accept on a finished slot");
        let next = (self.sampler.select(logits, &self.buf[..=self.pos]) as i32).min(vmax);
        if self.sampler.params().is_stop_token(next) {
            // a stop token ends the request without being emitted
            self.remaining = 0;
            return None;
        }
        self.advance(next);
        if self.sampler.params().stops_output(&self.out) {
            // a matched stop sequence stays in the output, so streamed
            // token frames always concatenate to the final response
            self.remaining = 0;
        }
        Some(next)
    }

    /// Accept the next token: append to the output and advance the
    /// window (slide left by one once the buffer is full).
    pub fn advance(&mut self, next: i32) {
        debug_assert!(self.remaining > 0, "advance on a finished slot");
        self.out.push(next);
        self.remaining -= 1;
        let t = self.buf.len();
        if self.pos + 1 < t {
            self.pos += 1;
            self.buf[self.pos] = next;
        } else {
            self.buf.copy_within(1..t, 0);
            self.buf[t - 1] = next;
        }
    }

    /// Tokens this request may still emit before its budget is spent —
    /// the speculative decoder clamps its draft length to
    /// `remaining - 1` so the verify pass never computes rows the slot
    /// could not accept.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// True once the token budget is spent or a stop condition matched.
    pub fn done(&self) -> bool {
        self.remaining == 0
    }
}

/// Anything that can turn a micro-batch of decode slots into per-slot
/// logits rows — the **logits-out** contract of the generation API v2:
/// a backend computes raw logits and never selects tokens (selection is
/// [`decode_step`]'s job, through each slot's [`Sampler`]). The
/// invariant that makes batched output token-identical to sequential
/// output: **row `i` depends only on slot `i`** — never on the batch
/// composition. A backend is free to *compute* the rows jointly (the
/// native backend runs one fused `[B, ·]` pass over each packed layer
/// per step) as long as each row's value stays a function of its slot
/// alone.
pub trait StepBackend {
    /// Vocabulary size (logits row length).
    fn vocab(&self) -> usize;

    /// Model window length (slot buffer length).
    fn seq_len(&self) -> usize;

    /// One raw logits row (length = vocab) per slot, in slot order.
    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>>;

    /// Notification that `slot` has permanently left the decode loop —
    /// completed, cancelled (client disconnect), or failed. Stateful
    /// backends free per-slot resources keyed on [`DecodeSlot::id`] here
    /// (the native backend returns the slot's KV pages to the pool);
    /// stateless backends ignore it. Must be idempotent and safe for
    /// slots the backend never saw.
    fn release(&self, _slot: &DecodeSlot) {}

    /// Incrementally prefill at most `max_tokens` of `slot`'s prompt
    /// into the backend's per-slot cache, returning how many prompt
    /// tokens are **still missing** (0 = the slot is ready to decode at
    /// full cached speed). The scheduler's chunked-prefill loop calls
    /// this between decode steps so one long prompt cannot stall every
    /// streaming client's inter-token latency; chunking must never
    /// change tokens — the next [`Self::step`] simply finds more (or
    /// less) of the window already cached. The default (stateless or
    /// non-chunking backends) reports nothing missing, which makes
    /// chunked scheduling a no-op: `step` absorbs the whole prompt as
    /// before.
    fn prefill_chunk(&self, _slot: &DecodeSlot, _max_tokens: usize) -> Result<usize> {
        Ok(0)
    }

    /// Cache/pool counters for the serve stats (`None` when the backend
    /// has nothing to report — the default).
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }

    /// Bind `slot` to the model named in its request *before* its first
    /// prefill or step. Multi-model backends (`serve::spec::ModelRegistry`)
    /// record the route keyed on [`DecodeSlot::id`] and reject unknown
    /// names; single-model backends (the default) accept anything the
    /// protocol validation let through and route everything to
    /// themselves. Must be paired with [`Self::release`] — the registry
    /// drops the route there.
    fn bind_model(&self, _slot: &DecodeSlot, _model: Option<&str>) -> Result<()> {
        Ok(())
    }

    /// Take over one scheduler decode tick for the whole active
    /// micro-batch. `None` (the default) tells the scheduler to run the
    /// ordinary [`decode_step`]; `Some(result)` means the backend
    /// advanced the slots itself — the registry uses this to route
    /// same-model runs to their backends and to decode draft-paired
    /// models speculatively (several tokens per tick). Implementations
    /// must preserve the decode-core invariant: each slot's emitted
    /// stream is exactly what sequential [`decode_step`] ticks would
    /// have produced.
    fn spec_step(&self, _slots: &mut [DecodeSlot]) -> Option<Result<()>> {
        None
    }

    /// Speculative-decode counters for the serve stats (`None` when the
    /// backend never drafts — the default).
    fn spec_stats(&self) -> Option<super::spec::SpecStats> {
        None
    }

    /// Per-model admission/queue counters for the serve stats (empty
    /// when the backend hosts a single anonymous model — the default).
    fn model_queue_stats(&self) -> Vec<super::spec::ModelQueueStats> {
        Vec::new()
    }
}

/// Backend cache/pool counters surfaced into `SchedStats`, the serve
/// shutdown log, and `BENCH_serve.json` via [`StepBackend::cache_stats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// prefix-cache lookups (one per cold slot admission)
    pub prefix_lookups: u64,
    /// lookups that attached at least one cached page
    pub prefix_hits: u64,
    /// prompt tokens served from cached pages instead of prefill
    pub prefix_hit_tokens: u64,
    /// full pages currently held by the prefix trie
    pub prefix_pages: u64,
    /// peak KV pages outstanding over the backend's lifetime
    pub kv_pages_hwm: u64,
}

/// One decode step over a micro-batch: backend logits → per-slot
/// selection (greedy argmax or the slot's sampler) → stop conditions →
/// advance. Slots that are already done are left untouched (their logits
/// row is computed but discarded — the scheduler retires them before the
/// next step).
pub fn decode_step<B: StepBackend + ?Sized>(backend: &B, slots: &mut [DecodeSlot]) -> Result<()> {
    if slots.is_empty() {
        return Ok(());
    }
    let rows = backend.step(slots)?;
    if rows.len() != slots.len() {
        bail!("backend returned {} logits rows for {} slots", rows.len(), slots.len());
    }
    let vmax = backend.vocab() as i32 - 1;
    for (slot, row) in slots.iter_mut().zip(rows) {
        if slot.done() {
            continue;
        }
        let _ = slot.accept(&row, vmax);
    }
    Ok(())
}

/// Sequential decode of one prompt under explicit [`GenParams`] — the
/// reference path the batched scheduler must match token-for-token
/// (greedy and seeded sampling alike). Errors on an empty prompt (at
/// this layer, not just in the JSON protocol). The slot is released on
/// every exit path, so stateful backends never leak cache state to a
/// one-shot generation.
pub fn generate<B: StepBackend + ?Sized>(
    backend: &B,
    prompt: &[i32],
    max_tokens: usize,
    params: GenParams,
) -> Result<Vec<i32>> {
    let mut slot = DecodeSlot::with_params(prompt, max_tokens, backend.seq_len(), params)?;
    while !slot.done() {
        if let Err(e) = decode_step(backend, std::slice::from_mut(&mut slot)) {
            backend.release(&slot);
            return Err(e);
        }
    }
    backend.release(&slot);
    Ok(slot.out)
}

/// [`generate`] with default (greedy) parameters — token-identical to
/// the pre-v2 greedy decode path.
pub fn generate_greedy<B: StepBackend + ?Sized>(
    backend: &B,
    prompt: &[i32],
    max_tokens: usize,
) -> Result<Vec<i32>> {
    generate(backend, prompt, max_tokens, GenParams::default())
}

// ---------------------------------------------------------------------------
// RuntimeBackend: the deployed W4A4 path

/// Logits through the AOT artifacts, weights resident on device.
///
/// The full weight set is uploaded once per decode artifact via
/// [`Runtime::prepare`] at construction; each step marshals only tokens
/// + positions. A step's micro-batch is chunked greedily into the
/// largest lowered `lm_logits_pos_aq_b{B}` sizes, short tails are padded
/// (rows are independent; padded rows are discarded), and presets
/// without batched artifacts fall back to per-slot executions — still
/// one scheduler tick, still prefix-resident.
pub struct RuntimeBackend<'r> {
    rt: &'r Runtime,
    /// batch sizes with a lowered `lm_logits_pos_aq_b{B}` artifact, ascending
    batch_sizes: Vec<usize>,
    prepared: HashMap<String, PreparedExec>,
}

impl<'r> RuntimeBackend<'r> {
    /// Compiles and uploads every decode artifact (single-request plus
    /// all lowered batched variants) up front: the dense f32 weight set
    /// is materialized once, shipped to device, and dropped — the host
    /// keeps only the packed store, and the server fails fast (here, at
    /// startup) if an artifact cannot compile.
    pub fn new(rt: &'r Runtime, params: &dyn ParamSource) -> Result<RuntimeBackend<'r>> {
        let prefix = format!("{LOGITS_ARTIFACT}_b");
        let mut batch_sizes: Vec<usize> = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix).and_then(|s| s.parse().ok()))
            .filter(|&b| b > 1)
            .collect();
        batch_sizes.sort_unstable();
        // transient dense copy: dropped at the end of this function. All
        // decode artifacts share ONE uploaded device copy of the weights.
        let vals = params.values()?;
        let mut names = vec![LOGITS_ARTIFACT.to_string()];
        names.extend(batch_sizes.iter().map(|b| format!("{LOGITS_ARTIFACT}_b{b}")));
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let preps = rt.prepare_many(&name_refs, &vals)?;
        let prepared: HashMap<String, PreparedExec> = names.into_iter().zip(preps).collect();
        Ok(RuntimeBackend { rt, batch_sizes, prepared })
    }

    fn prepared(&self, name: &str) -> Result<&PreparedExec> {
        self.prepared.get(name).ok_or_else(|| anyhow!("artifact '{name}' not prepared"))
    }

    /// One single-request execution.
    fn logits_one(&self, slot: &DecodeSlot) -> Result<Vec<f32>> {
        let t = self.seq_len();
        let prep = self.prepared(LOGITS_ARTIFACT)?;
        let out = prep.exec(
            self.rt,
            &[Value::I32(slot.buf.clone(), vec![1, t]), Value::scalar_i32(slot.pos as i32)],
        )?;
        Ok(out[0].as_tensor()?.data.clone())
    }

    /// One `lm_logits_pos_aq_b{size}` execution over up to `size` slots,
    /// padding short chunks by repeating the first slot (padded rows are
    /// computed and discarded — each row depends only on its own slot, so
    /// padding never changes real outputs).
    fn logits_chunk(&self, size: usize, chunk: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        let (t, v) = (self.seq_len(), self.vocab());
        let prep = self.prepared(&format!("{LOGITS_ARTIFACT}_b{size}"))?;
        let mut toks = Vec::with_capacity(size * t);
        let mut pos = Vec::with_capacity(size);
        for i in 0..size {
            let s = chunk.get(i).unwrap_or(&chunk[0]);
            toks.extend_from_slice(&s.buf);
            pos.push(s.pos as i32);
        }
        let out = prep
            .exec(self.rt, &[Value::I32(toks, vec![size, t]), Value::I32(pos, vec![size])])?;
        let all = out[0].as_tensor()?;
        Ok(all.data.chunks(v).take(chunk.len()).map(|c| c.to_vec()).collect())
    }
}

impl StepBackend for RuntimeBackend<'_> {
    fn vocab(&self) -> usize {
        self.rt.config().vocab
    }

    fn seq_len(&self) -> usize {
        self.rt.config().seq_len
    }

    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        let b = slots.len();
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(b);
        let mut i = 0;
        while i < b {
            let rem = b - i;
            // largest lowered batch that fits; else (tail smaller than
            // every lowered size, but more than one slot left) pad up to
            // the smallest lowered batch; else single-request execution
            let size = self
                .batch_sizes
                .iter()
                .rev()
                .find(|&&s| s <= rem)
                .or_else(|| if rem > 1 { self.batch_sizes.first() } else { None })
                .copied();
            match size {
                Some(s) => {
                    let chunk = &slots[i..i + rem.min(s)];
                    rows.extend(self.logits_chunk(s, chunk)?);
                    i += chunk.len();
                }
                None => {
                    rows.push(self.logits_one(&slots[i])?);
                    i += 1;
                }
            }
        }
        Ok(rows)
    }
}

// ---------------------------------------------------------------------------
// SyntheticBackend: deterministic stand-in for tests and load benches

/// A pure-rust logits oracle: each row is a deterministic function of
/// (last token, position, seed) only, so batched and sequential decodes
/// agree by construction — exactly the invariant the scheduler must
/// preserve. The cost model (`fixed_cost` burned once per step,
/// `per_slot_cost` once per slot) mimics a real accelerator step, which
/// is what makes micro-batching measurably win in the load bench.
pub struct SyntheticBackend {
    vocab: usize,
    seq_len: usize,
    seed: u64,
    /// simulated per-step overhead (kernel launch, arg marshalling)
    pub fixed_cost: Duration,
    /// simulated per-slot compute
    pub per_slot_cost: Duration,
    /// simulated cost of prefilling ONE prompt token — paid either all
    /// at once inside the slot's first `step` (unchunked) or
    /// incrementally through `prefill_chunk` (chunked), so the serve
    /// bench can measure what chunked prefill buys without real kernels
    per_prefill_token: Duration,
    /// prompt tokens already prefilled, per slot id (only maintained
    /// when a prefill cost is configured). Locked with poison recovery:
    /// every critical section is a single map insert/lookup/remove, so
    /// a panicking holder cannot leave a half-updated ledger behind
    prefilled: Mutex<HashMap<u64, usize>>,
    /// fraction of (token, position) pairs whose argmax is
    /// deterministically flipped to a pseudo-random other token — turns
    /// this backend into an imperfect *draft* of the same-seed original
    /// with a tunable expected accept rate (see [`Self::with_divergence`])
    divergence: f32,
    /// salt for the divergence hash, so different drafts of one target
    /// disagree at different positions
    divergence_salt: u64,
}

impl SyntheticBackend {
    /// A zero-cost deterministic backend over `vocab` tokens.
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> SyntheticBackend {
        SyntheticBackend {
            vocab,
            seq_len,
            seed,
            fixed_cost: Duration::ZERO,
            per_slot_cost: Duration::ZERO,
            per_prefill_token: Duration::ZERO,
            prefilled: Mutex::new(HashMap::new()),
            divergence: 0.0,
            divergence_salt: 0,
        }
    }

    /// Attach a simulated per-step / per-slot cost model.
    pub fn with_costs(mut self, fixed: Duration, per_slot: Duration) -> SyntheticBackend {
        self.fixed_cost = fixed;
        self.per_slot_cost = per_slot;
        self
    }

    /// Make this backend an imperfect draft of the same-seed original:
    /// a deterministic `p` fraction of (last token, position) pairs get
    /// their argmax flipped to a pseudo-random other token, everything
    /// else stays bitwise identical. A speculative pairing of
    /// `new(v, t, s)` as target with `new(v, t, s).with_divergence(p, salt)`
    /// as draft therefore has an expected per-token accept rate of about
    /// `1 - p`, which is what the spec-decode bench dials.
    pub fn with_divergence(mut self, p: f32, salt: u64) -> SyntheticBackend {
        self.divergence = p;
        self.divergence_salt = salt;
        self
    }

    /// Attach a simulated per-prompt-token prefill cost (see
    /// [`Self::per_prefill_token`]).
    pub fn with_prefill_cost(mut self, per_token: Duration) -> SyntheticBackend {
        self.per_prefill_token = per_token;
        self
    }

    /// Prompt tokens of `slot` not yet paid for, given the current
    /// window (`window_len - 1` positions precede the decode token).
    fn missing_prefill(&self, slot: &DecodeSlot, done: usize) -> usize {
        slot.pos.saturating_sub(done)
    }

    pub(crate) fn row(&self, last: i32, pos: usize) -> Vec<f32> {
        let mut x = (last as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((pos as u64) << 32)
            ^ self.seed;
        let mut row: Vec<f32> = (0..self.vocab)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((x >> 33) as f32) / (u32::MAX as f32)
            })
            .collect();
        if self.divergence > 0.0 && !row.is_empty() {
            // splitmix-style avalanche over (last, pos, salt): the flip
            // decision and the flip target are both deterministic, so
            // repeated decodes of one stream disagree with the base
            // model at exactly the same positions every run
            let mut h = (last as u64).wrapping_mul(0xA24B_AED4_963E_E407)
                ^ ((pos as u64) << 1).wrapping_mul(0x9FB2_1C65_1E98_DF25)
                ^ self.divergence_salt;
            h ^= h >> 30;
            h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 27;
            if ((h >> 40) as f32) / ((1u64 << 24) as f32) < self.divergence {
                // base entries all lie in [0, 1): 2.0 is an unambiguous argmax
                let flip = (h as usize) % row.len();
                row[flip] = 2.0;
            }
        }
        row
    }
}

/// Busy-wait (rather than sleep) so simulated step costs in the tens of
/// microseconds stay accurate — OS sleep granularity is far coarser.
pub(crate) fn spin(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl StepBackend for SyntheticBackend {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        spin(self.fixed_cost);
        if !self.per_prefill_token.is_zero() {
            // pay for every prompt token not yet prefilled (the whole
            // prompt on an unchunked slot's first step), then mark the
            // decode token cached too — steady-state decode steps cost
            // only per_slot_cost, like the real cached path
            let mut prefilled = self.prefilled.lock().unwrap_or_else(|e| e.into_inner());
            for s in slots {
                let done = prefilled.get(&s.id).copied().unwrap_or(0);
                let missing = self.missing_prefill(s, done);
                spin(self.per_prefill_token * missing as u32);
                prefilled.insert(s.id, s.pos + 1);
            }
        }
        Ok(slots
            .iter()
            .map(|s| {
                spin(self.per_slot_cost);
                self.row(s.buf[s.pos], s.pos)
            })
            .collect())
    }

    fn prefill_chunk(&self, slot: &DecodeSlot, max_tokens: usize) -> Result<usize> {
        if self.per_prefill_token.is_zero() || max_tokens == 0 {
            return Ok(0);
        }
        let done = {
            let prefilled = self.prefilled.lock().unwrap_or_else(|e| e.into_inner());
            prefilled.get(&slot.id).copied().unwrap_or(0)
        };
        let missing = self.missing_prefill(slot, done);
        let give = missing.min(max_tokens);
        // spin OUTSIDE the lock: concurrent callers must not serialize
        // on the ledger while simulated prefill work burns
        spin(self.per_prefill_token * give as u32);
        self.prefilled.lock().unwrap_or_else(|e| e.into_inner()).insert(slot.id, done + give);
        Ok(missing - give)
    }

    fn release(&self, slot: &DecodeSlot) {
        if !self.per_prefill_token.is_zero() {
            self.prefilled.lock().unwrap_or_else(|e| e.into_inner()).remove(&slot.id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        // later index wins ties (matches max_by semantics of the old path)
        assert_eq!(argmax(&[1.0, 5.0, 5.0]), 2);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_nan_regression() {
        // the old `partial_cmp(..).unwrap()` panicked on exactly this row
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NAN, 2.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN, -1.0]), 2);
    }

    #[test]
    fn slot_rejects_empty_prompt() {
        assert!(DecodeSlot::new(&[], 4, 8).is_err());
        assert!(DecodeSlot::new(&[1], 4, 8).is_ok());
    }

    #[test]
    fn slot_window_slide() {
        // prompt shorter than the window: fills the head, pos on last token
        let mut s = DecodeSlot::new(&[5, 6], 4, 4).unwrap();
        assert_eq!(s.buf, vec![5, 6, 0, 0]);
        assert_eq!(s.pos, 1);
        s.advance(7);
        s.advance(8);
        assert_eq!(s.buf, vec![5, 6, 7, 8]);
        assert_eq!(s.pos, 3);
        // buffer full: slides left by one
        s.advance(9);
        assert_eq!(s.buf, vec![6, 7, 8, 9]);
        assert_eq!(s.pos, 3);
        s.advance(1);
        assert_eq!(s.buf, vec![7, 8, 9, 1]);
        assert_eq!(s.out, vec![7, 8, 9, 1]);
        assert!(s.done());
    }

    #[test]
    fn slot_long_prompt_keeps_tail() {
        let s = DecodeSlot::new(&[1, 2, 3, 4, 5, 6], 2, 4).unwrap();
        assert_eq!(s.buf, vec![3, 4, 5, 6]);
        assert_eq!(s.pos, 3);
    }

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        let b = SyntheticBackend::new(32, 8, 42);
        let out = generate_greedy(&b, &[1, 2, 3], 16).unwrap();
        assert_eq!(out.len(), 16);
        assert!(out.iter().all(|&t| t >= 0 && t < 32));
        assert_eq!(out, generate_greedy(&b, &[1, 2, 3], 16).unwrap());
        // different prompt, different continuation (overwhelmingly likely)
        assert_ne!(out, generate_greedy(&b, &[4, 5], 16).unwrap());
        // empty prompt errors at this layer, not just in the JSON protocol
        assert!(generate_greedy(&b, &[], 4).is_err());
    }

    #[test]
    fn batched_step_matches_sequential() {
        let b = SyntheticBackend::new(64, 8, 7);
        let prompts: Vec<Vec<i32>> = (0..5).map(|i| vec![i, i + 3, 2 * i]).collect();
        let sequential: Vec<Vec<i32>> =
            prompts.iter().map(|p| generate_greedy(&b, p, 12).unwrap()).collect();
        // decode all five interleaved in one micro-batch
        let mut slots: Vec<DecodeSlot> =
            prompts.iter().map(|p| DecodeSlot::new(p, 12, 8).unwrap()).collect();
        while slots.iter().any(|s| !s.done()) {
            decode_step(&b, &mut slots).unwrap();
        }
        for (slot, expect) in slots.iter().zip(&sequential) {
            assert_eq!(&slot.out, expect);
        }
    }

    struct NanBackend;

    impl StepBackend for NanBackend {
        fn vocab(&self) -> usize {
            4
        }

        fn seq_len(&self) -> usize {
            8
        }

        fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
            Ok(slots.iter().map(|_| vec![f32::NAN, 1.0, f32::NAN, 0.5]).collect())
        }
    }

    #[test]
    fn nan_logits_decode_without_panicking() {
        let out = generate_greedy(&NanBackend, &[1], 3).unwrap();
        assert_eq!(out, vec![1, 1, 1]);
    }

    #[test]
    fn sampled_generate_is_seed_reproducible_and_in_vocab() {
        let b = SyntheticBackend::new(48, 8, 5);
        let params = GenParams { temperature: 0.8, top_p: 0.9, seed: 11, ..GenParams::default() };
        let a = generate(&b, &[1, 2, 3], 16, params.clone()).unwrap();
        let c = generate(&b, &[1, 2, 3], 16, params.clone()).unwrap();
        assert_eq!(a, c, "same seed must reproduce the same continuation");
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&t| t >= 0 && t < 48));
        let d = generate(&b, &[1, 2, 3], 16, GenParams { seed: 12, ..params }).unwrap();
        assert_ne!(a, d, "different seeds should diverge");
    }

    #[test]
    fn sampled_batched_step_matches_sequential() {
        // the invariant greedy decode has always had, now for sampling:
        // the sampler lives in the slot, so batch composition cannot
        // perturb a request's token stream
        let b = SyntheticBackend::new(64, 8, 7);
        let params = |i: u64| GenParams {
            temperature: 1.1,
            top_k: 12,
            top_p: 0.95,
            repetition_penalty: 1.2,
            seed: 100 + i,
            ..GenParams::default()
        };
        let prompts: Vec<Vec<i32>> = (0..5).map(|i| vec![i, i + 3, 2 * i]).collect();
        let sequential: Vec<Vec<i32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| generate(&b, p, 12, params(i as u64)).unwrap())
            .collect();
        let mut slots: Vec<DecodeSlot> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| DecodeSlot::with_params(p, 12, 8, params(i as u64)).unwrap())
            .collect();
        while slots.iter().any(|s| !s.done()) {
            decode_step(&b, &mut slots).unwrap();
        }
        for (slot, expect) in slots.iter().zip(&sequential) {
            assert_eq!(&slot.out, expect, "sampled batched decode diverged from sequential");
        }
    }

    /// First index `k >= 1` whose token does not occur earlier in `out`
    /// (so a stop condition anchored at `k` cannot fire prematurely).
    fn first_fresh(out: &[i32]) -> usize {
        (1..out.len())
            .find(|&k| !out[..k].contains(&out[k]))
            .expect("greedy stream has no fresh token")
    }

    #[test]
    fn stop_token_ends_decode_without_emitting() {
        let b = SyntheticBackend::new(32, 8, 42);
        let greedy = generate_greedy(&b, &[1, 2, 3], 12).unwrap();
        let k = first_fresh(&greedy);
        // stop on the token greedy would emit at k: the continuation is
        // cut to the first k tokens, stop token excluded
        let params = GenParams { stop_tokens: vec![greedy[k]], ..GenParams::default() };
        let stopped = generate(&b, &[1, 2, 3], 12, params).unwrap();
        assert_eq!(stopped, &greedy[..k]);
    }

    #[test]
    fn stop_sequence_ends_decode_and_stays_in_output() {
        let b = SyntheticBackend::new(32, 8, 42);
        let greedy = generate_greedy(&b, &[1, 2, 3], 12).unwrap();
        let k = first_fresh(&greedy);
        // the pair ending at k first occurs at k (its tail token is fresh)
        let params = GenParams {
            stop_sequences: vec![greedy[k - 1..=k].to_vec()],
            ..GenParams::default()
        };
        let stopped = generate(&b, &[1, 2, 3], 12, params).unwrap();
        assert_eq!(stopped, &greedy[..=k], "matched stop sequence must stay in the output");
    }

    #[test]
    fn slot_rejects_invalid_params() {
        let bad = GenParams { temperature: f32::NAN, ..GenParams::default() };
        assert!(DecodeSlot::with_params(&[1], 4, 8, bad).is_err());
    }

    #[test]
    fn divergence_flips_argmax_at_roughly_the_dialed_rate() {
        let base = SyntheticBackend::new(64, 8, 7);
        let draft = SyntheticBackend::new(64, 8, 7).with_divergence(0.25, 99);
        let mut flipped = 0usize;
        let total = 4000usize;
        for i in 0..total {
            let (last, pos) = ((i % 64) as i32, i % 8);
            let a = argmax(&base.row(last, pos));
            let d = argmax(&draft.row(last, pos));
            if a != d {
                flipped += 1;
            }
        }
        let rate = flipped as f64 / total as f64;
        // p=0.25 minus the ~1/64 chance the flip target IS the argmax;
        // generous bounds — this pins the knob's order of magnitude
        assert!((0.12..=0.38).contains(&rate), "divergence rate {rate} out of range");
        // zero divergence stays bitwise identical to the base stream
        let plain = SyntheticBackend::new(64, 8, 7).with_divergence(0.0, 99);
        for i in 0..64 {
            assert_eq!(base.row(i as i32, i % 8), plain.row(i as i32, i % 8));
        }
    }

    #[test]
    fn synthetic_prefill_chunks_account_and_never_change_tokens() {
        let b = SyntheticBackend::new(32, 16, 9).with_prefill_cost(Duration::from_micros(1));
        let prompt: Vec<i32> = (0..10).collect();
        let reference = generate_greedy(&SyntheticBackend::new(32, 16, 9), &prompt, 5).unwrap();
        let mut slots = vec![DecodeSlot::new(&prompt, 5, 16).unwrap()];
        // 9 positions precede the decode token; drain them in 4s
        assert_eq!(b.prefill_chunk(&slots[0], 4).unwrap(), 5);
        assert_eq!(b.prefill_chunk(&slots[0], 4).unwrap(), 1);
        assert_eq!(b.prefill_chunk(&slots[0], 4).unwrap(), 0);
        while !slots[0].done() {
            decode_step(&b, &mut slots).unwrap();
        }
        assert_eq!(slots[0].out, reference, "prefill cost model changed the tokens");
        b.release(&slots[0]);
        // a cost-free backend's default hook reports nothing missing
        let plain = SyntheticBackend::new(32, 16, 9);
        let slot = DecodeSlot::new(&prompt, 5, 16).unwrap();
        assert_eq!(plain.prefill_chunk(&slot, 4).unwrap(), 0);
    }
}
