//! Deterministic fault injection for the serve stack.
//!
//! Production failure handling is only trustworthy if the failures are
//! *reproducible*: a chaos test that sometimes injects a panic and
//! sometimes does not cannot pin the recovery behaviour. This module
//! provides:
//!
//! * [`FaultPlan`] — a seeded, fully deterministic schedule of faults,
//!   parsed from a compact spec string (`--fault-plan` /
//!   `FAAR_FAULT_PLAN`), so the exact same chaos replays on every run.
//! * [`FaultBackend`] — a wrapper over any [`StepBackend`] that executes
//!   the plan: at scripted decode-tick indices it returns step errors,
//!   typed [`KvExhausted`] errors, added latency, or panics outright —
//!   exercising every unhappy path the scheduler claims to contain
//!   (structured `backend` / `backend_panic` errors, KV release on
//!   eviction, poisoned-lock recovery).
//! * [`torn_chunks`] — a deterministic splitter test clients use to
//!   simulate connection-level faults (torn writes, mid-frame stalls)
//!   against the incremental frame decoder.
//!
//! The wrapper never perturbs the happy path: a tick with no scheduled
//! fault forwards to the inner backend untouched, so bit-parity
//! invariants (batched == sequential) hold for every token that is
//! actually produced.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::batch::{spin, CacheStats, DecodeSlot, StepBackend};
use super::spec::{ModelQueueStats, SpecStats};
use crate::infer::kv::KvExhausted;
use crate::util::rng::Rng;

/// One scheduled fault at a decode-tick index.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// the backend call fails with an `anyhow` error (`backend` code)
    StepError,
    /// the backend call fails with a typed [`KvExhausted`] error — the
    /// same error class a real pool-budget miss raises, so downcast-based
    /// degrade paths fire exactly as they would in production
    KvExhausted,
    /// the backend call panics (`backend_panic` containment path)
    Panic,
    /// the backend call succeeds after busy-waiting this long (deadline
    /// and overload paths)
    Latency(Duration),
}

/// A deterministic, seeded fault schedule over decode-tick indices.
///
/// Parsed from a compact comma-separated spec, e.g.
/// `seed=7,step_err=3+11,panic=20,kv=5,prefill_err=2,latency=4:8,err_rate=0.01`:
///
/// | key           | value                    | effect at tick *i*                     |
/// |---------------|--------------------------|----------------------------------------|
/// | `seed`        | u64                      | seeds the `err_rate` draw (default 0)  |
/// | `step_err`    | `+`-separated tick list  | step returns an error                  |
/// | `kv`          | `+`-separated tick list  | step returns typed [`KvExhausted`]     |
/// | `panic`       | `+`-separated tick list  | step panics                            |
/// | `latency`     | `tick:ms` (+-separated)  | step busy-waits `ms` first             |
/// | `prefill_err` | `+`-separated call list  | the i-th `prefill_chunk` call errors   |
/// | `err_rate`    | probability in \[0, 1\]  | unscripted ticks error at this rate    |
///
/// The `err_rate` draw is a pure function of `(seed, tick)` — no global
/// RNG state — so the schedule is identical however the plan is queried.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// seed for the probabilistic `err_rate` draws
    pub seed: u64,
    /// probability that an unscripted tick fails with a step error
    pub err_rate: f64,
    step_errors: HashSet<u64>,
    kv_exhausted: HashSet<u64>,
    panics: HashSet<u64>,
    latency: HashMap<u64, u64>,
    prefill_errors: HashSet<u64>,
}

fn parse_ticks(key: &str, v: &str) -> Result<HashSet<u64>> {
    v.split('+')
        .map(|t| t.trim().parse::<u64>().with_context(|| format!("bad {key} tick '{t}'")))
        .collect()
}

impl FaultPlan {
    /// Parse a plan from its spec string (see the type docs for the
    /// grammar). An empty string parses to the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for field in spec.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (key, value) = field
                .split_once('=')
                .with_context(|| format!("fault-plan field '{field}' is not key=value"))?;
            match key.trim() {
                "seed" => plan.seed = value.trim().parse().context("bad seed")?,
                "err_rate" => {
                    let p: f64 = value.trim().parse().context("bad err_rate")?;
                    if !(0.0..=1.0).contains(&p) {
                        bail!("err_rate {p} outside [0, 1]");
                    }
                    plan.err_rate = p;
                }
                "step_err" => plan.step_errors = parse_ticks("step_err", value)?,
                "kv" => plan.kv_exhausted = parse_ticks("kv", value)?,
                "panic" => plan.panics = parse_ticks("panic", value)?,
                "prefill_err" => plan.prefill_errors = parse_ticks("prefill_err", value)?,
                "latency" => {
                    for item in value.split('+') {
                        let (tick, ms) = item
                            .split_once(':')
                            .with_context(|| format!("latency item '{item}' is not tick:ms"))?;
                        plan.latency.insert(
                            tick.trim().parse().context("bad latency tick")?,
                            ms.trim().parse().context("bad latency ms")?,
                        );
                    }
                }
                other => bail!("unknown fault-plan key '{other}'"),
            }
        }
        Ok(plan)
    }

    /// True when the plan schedules nothing (the wrapper is a pass-through).
    pub fn is_empty(&self) -> bool {
        self.err_rate == 0.0
            && self.step_errors.is_empty()
            && self.kv_exhausted.is_empty()
            && self.panics.is_empty()
            && self.latency.is_empty()
            && self.prefill_errors.is_empty()
    }

    /// The fault (if any) scheduled at decode tick `idx`. Scripted ticks
    /// win over the probabilistic `err_rate` draw; the draw itself is a
    /// pure function of `(seed, idx)`.
    pub fn fault_at(&self, idx: u64) -> Option<Fault> {
        if self.panics.contains(&idx) {
            return Some(Fault::Panic);
        }
        if self.kv_exhausted.contains(&idx) {
            return Some(Fault::KvExhausted);
        }
        if self.step_errors.contains(&idx) {
            return Some(Fault::StepError);
        }
        if let Some(&ms) = self.latency.get(&idx) {
            return Some(Fault::Latency(Duration::from_millis(ms)));
        }
        if self.err_rate > 0.0 && Rng::new(self.seed).fork(idx).bernoulli(self.err_rate) {
            return Some(Fault::StepError);
        }
        None
    }

    /// True when the `idx`-th `prefill_chunk` call is scheduled to fail.
    pub fn prefill_fault_at(&self, idx: u64) -> bool {
        self.prefill_errors.contains(&idx)
    }
}

/// Executes a [`FaultPlan`] over any inner [`StepBackend`].
///
/// Decode ticks are counted once per scheduler step, whether the tick is
/// served by the plain [`StepBackend::step`] path or a speculative
/// [`StepBackend::spec_step`] takeover, so one plan drives chaos against
/// single-model, multi-model, and draft-paired deployments alike. Every
/// non-faulted call — and *all* bookkeeping calls (`release`,
/// `bind_model`, stats) — forwards to the inner backend untouched, so KV
/// accounting stays exact across injected failures.
pub struct FaultBackend<B> {
    inner: B,
    plan: FaultPlan,
    steps: AtomicU64,
    prefills: AtomicU64,
}

impl<B: StepBackend> FaultBackend<B> {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: B, plan: FaultPlan) -> FaultBackend<B> {
        FaultBackend { inner, plan, steps: AtomicU64::new(0), prefills: AtomicU64::new(0) }
    }

    /// The wrapped backend (chaos tests probe its KV accounting through
    /// this after the server drains).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Decode ticks observed so far.
    pub fn ticks(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Raise the scheduled failure for tick `idx`, if any. Latency is
    /// paid here and reported as "no fault" so the caller proceeds.
    fn raise(&self, idx: u64) -> Result<()> {
        match self.plan.fault_at(idx) {
            None => Ok(()),
            Some(Fault::Latency(d)) => {
                spin(d);
                Ok(())
            }
            Some(Fault::StepError) => bail!("injected fault: step error at tick {idx}"),
            Some(Fault::KvExhausted) => {
                Err(anyhow::Error::new(KvExhausted { outstanding: 0 }))
                    .with_context(|| format!("injected fault: kv exhaustion at tick {idx}"))
            }
            Some(Fault::Panic) => panic!("injected fault: panic at tick {idx}"),
        }
    }
}

impl<B: StepBackend> StepBackend for FaultBackend<B> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        let idx = self.steps.fetch_add(1, Ordering::Relaxed);
        self.raise(idx)?;
        self.inner.step(slots)
    }

    fn prefill_chunk(&self, slot: &DecodeSlot, max_tokens: usize) -> Result<usize> {
        let idx = self.prefills.fetch_add(1, Ordering::Relaxed);
        if self.plan.prefill_fault_at(idx) {
            bail!("injected fault: prefill error at call {idx}");
        }
        self.inner.prefill_chunk(slot, max_tokens)
    }

    fn release(&self, slot: &DecodeSlot) {
        self.inner.release(slot);
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.inner.cache_stats()
    }

    fn bind_model(&self, slot: &DecodeSlot, model: Option<&str>) -> Result<()> {
        self.inner.bind_model(slot, model)
    }

    fn spec_step(&self, slots: &mut [DecodeSlot]) -> Option<Result<()>> {
        // a speculative tick consumes the same counter as a plain one,
        // but only if the inner backend actually takes the tick over —
        // otherwise the scheduler falls through to `step`, which counts
        // it (the scheduler thread is the only caller, so the
        // load/store pair cannot race)
        let idx = self.steps.load(Ordering::Relaxed);
        match self.plan.fault_at(idx) {
            Some(Fault::Latency(_)) | None => {}
            Some(_) => {
                self.steps.store(idx + 1, Ordering::Relaxed);
                return Some(self.raise(idx).map(|_| ()));
            }
        }
        let took = self.inner.spec_step(slots);
        if took.is_some() {
            self.steps.store(idx + 1, Ordering::Relaxed);
        }
        took
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        self.inner.spec_stats()
    }

    fn model_queue_stats(&self) -> Vec<ModelQueueStats> {
        self.inner.model_queue_stats()
    }
}

/// Split `bytes` into deterministic small chunks with per-chunk stall
/// durations — the connection-level fault model. A chaos client writes
/// each chunk, sleeps its stall, and writes the next, producing torn
/// frames and mid-frame stalls the incremental decoder must survive.
/// Chunk boundaries and stalls are pure functions of `seed`.
pub fn torn_chunks(bytes: &[u8], seed: u64) -> Vec<(Vec<u8>, Duration)> {
    let mut rng = Rng::new(seed ^ 0x7061_6c6c_6173);
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let n = (1 + rng.below(7)).min(bytes.len() - i);
        let stall = Duration::from_micros(rng.below(800) as u64);
        out.push((bytes[i..i + n].to_vec(), stall));
        i += n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batch::{generate_greedy, SyntheticBackend};

    #[test]
    fn plan_parses_and_schedules() {
        let plan =
            FaultPlan::parse("seed=7, step_err=3+11, panic=20, kv=5, latency=4:8, prefill_err=2")
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.fault_at(3), Some(Fault::StepError));
        assert_eq!(plan.fault_at(11), Some(Fault::StepError));
        assert_eq!(plan.fault_at(20), Some(Fault::Panic));
        assert_eq!(plan.fault_at(5), Some(Fault::KvExhausted));
        assert_eq!(plan.fault_at(4), Some(Fault::Latency(Duration::from_millis(8))));
        assert_eq!(plan.fault_at(6), None);
        assert!(plan.prefill_fault_at(2));
        assert!(!plan.prefill_fault_at(3));
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("err_rate=1.5").is_err());
        assert!(FaultPlan::parse("step_err=x").is_err());
    }

    #[test]
    fn err_rate_draw_is_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::parse("seed=9,err_rate=0.25").unwrap();
        let hits = (0..4000).filter(|&i| plan.fault_at(i).is_some()).count();
        let rate = hits as f64 / 4000.0;
        assert!((0.18..=0.32).contains(&rate), "err_rate draw off: {rate}");
        // pure function of (seed, idx): re-querying never changes the answer
        for i in 0..64 {
            assert_eq!(plan.fault_at(i), plan.fault_at(i));
        }
    }

    #[test]
    fn unfaulted_ticks_are_bit_transparent() {
        let base = SyntheticBackend::new(32, 8, 42);
        let wrapped =
            FaultBackend::new(SyntheticBackend::new(32, 8, 42), FaultPlan::default());
        let a = generate_greedy(&base, &[1, 2, 3], 12).unwrap();
        let b = generate_greedy(&wrapped, &[1, 2, 3], 12).unwrap();
        assert_eq!(a, b, "empty plan must not perturb tokens");
    }

    #[test]
    fn scripted_errors_fire_at_their_ticks() {
        let plan = FaultPlan::parse("step_err=1,kv=2").unwrap();
        let b = FaultBackend::new(SyntheticBackend::new(32, 8, 42), plan);
        let slot = crate::serve::batch::DecodeSlot::new(&[1], 8, 8).unwrap();
        assert!(b.step(std::slice::from_ref(&slot)).is_ok());
        assert!(b.step(std::slice::from_ref(&slot)).is_err());
        let kv_err = b.step(std::slice::from_ref(&slot)).unwrap_err();
        assert!(
            kv_err.downcast_ref::<KvExhausted>().is_some(),
            "kv fault must carry the typed error: {kv_err}"
        );
        assert!(b.step(std::slice::from_ref(&slot)).is_ok());
        assert_eq!(b.ticks(), 4);
    }

    #[test]
    #[should_panic(expected = "injected fault: panic at tick 0")]
    fn scripted_panic_panics() {
        let b = FaultBackend::new(
            SyntheticBackend::new(32, 8, 42),
            FaultPlan::parse("panic=0").unwrap(),
        );
        let slot = crate::serve::batch::DecodeSlot::new(&[1], 8, 8).unwrap();
        let _ = b.step(std::slice::from_ref(&slot));
    }

    #[test]
    fn torn_chunks_reassemble_exactly() {
        let payload: Vec<u8> = (0..257u32).map(|i| (i % 251) as u8).collect();
        let chunks = torn_chunks(&payload, 11);
        assert!(chunks.len() > payload.len() / 7, "chunks too coarse");
        let glued: Vec<u8> = chunks.iter().flat_map(|(c, _)| c.clone()).collect();
        assert_eq!(glued, payload);
        // deterministic: same seed, same schedule
        let again = torn_chunks(&payload, 11);
        assert_eq!(chunks.len(), again.len());
        assert!(chunks.iter().zip(&again).all(|(a, b)| a == b));
    }
}
