//! Transport-agnostic message framing for the serve protocol.
//!
//! The serving engine speaks newline-delimited JSON over raw TCP and
//! HTTP/1.1 (+ SSE for streaming), but the scheduler only ever sees
//! *frames*: complete JSON documents carved out of a byte stream. This
//! module owns that boundary with a [`FrameDecoder`] / [`FrameEncoder`]
//! trait pair, so transports decide how bytes move and codecs decide
//! where messages begin and end — neither duplicates protocol v2
//! semantics (validation, ordering, cancellation), which stay in the
//! scheduler.
//!
//! Two decoders implement the trait:
//!
//! * [`LineDecoder`] — the reference JSONL codec: buffer until `\n`,
//!   bound the line length, hand the whole line to the JSON parser.
//! * [`IncrementalDecoder`] — a structural streaming framer: it tracks
//!   string/escape state, container depth, and UTF-8 validity *as bytes
//!   arrive*, so a frame is recognized (or rejected) without ever
//!   buffering beyond the frame itself. Grammar validation is still
//!   [`crate::util::json::Json::parse`] on the completed frame — the
//!   scanner only rejects early on conditions the line codec also
//!   rejects (invalid UTF-8, nesting past [`crate::util::json::MAX_DEPTH`],
//!   oversized input), which is what keeps the two codecs in byte-for-byte
//!   agreement on every single-line input (pinned by
//!   `tests/conformance_protocol.rs` and the fuzz harness).
//!
//! Every failure is a [`DecodeEvent::Reject`] carrying a structured
//! [`ServeError`] — never a panic, never a silently dropped byte.

pub mod incremental;
pub mod line;

pub use incremental::IncrementalDecoder;
pub use line::LineDecoder;

use super::scheduler::{ServeError, ServeOptions};
use crate::util::json;

/// Which frame decoder a transport attaches to a connection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CodecKind {
    /// Reference JSONL codec: one fully buffered line per frame.
    #[default]
    Line,
    /// Streaming structural framer: no full-line buffering.
    Incremental,
}

impl CodecKind {
    /// Parses a `--codec` CLI value.
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s {
            "line" => Some(CodecKind::Line),
            "incremental" => Some(CodecKind::Incremental),
            _ => None,
        }
    }

    /// The CLI spelling of this codec.
    pub fn name(self) -> &'static str {
        match self {
            CodecKind::Line => "line",
            CodecKind::Incremental => "incremental",
        }
    }
}

/// Size/shape bounds a decoder enforces while framing.
///
/// The line codec can only enforce `max_frame_bytes` (it sees nothing
/// until the newline); the incremental decoder enforces all three as
/// bytes arrive. `max_depth` always equals [`json::MAX_DEPTH`] so the
/// scanner and the parser reject nesting at exactly the same level.
#[derive(Clone, Copy, Debug)]
pub struct CodecLimits {
    /// Upper bound on one frame (for JSONL: the line content, `\r`
    /// included, `\n` excluded), in bytes. Exceeding it is an
    /// `oversized` rejection.
    pub max_frame_bytes: usize,
    /// Maximum container nesting depth; deeper input is `bad_json`.
    pub max_depth: usize,
    /// Upper bound on a single string or key, in raw (encoded) bytes.
    /// At the default (`== max_frame_bytes`) the frame bound always
    /// trips first, so this only binds when configured tighter.
    pub max_string_bytes: usize,
}

impl Default for CodecLimits {
    fn default() -> CodecLimits {
        CodecLimits {
            max_frame_bytes: 64 * 1024,
            max_depth: json::MAX_DEPTH,
            max_string_bytes: 64 * 1024,
        }
    }
}

impl CodecLimits {
    /// Limits matching a server's [`ServeOptions`].
    pub fn from_options(opts: &ServeOptions) -> CodecLimits {
        CodecLimits {
            max_frame_bytes: opts.max_line_bytes,
            max_depth: json::MAX_DEPTH,
            max_string_bytes: opts.max_line_bytes,
        }
    }
}

/// What a decoder produced from the bytes fed so far.
#[derive(Clone, Debug, PartialEq)]
pub enum DecodeEvent {
    /// A complete frame: trimmed, non-empty text ready for
    /// `Json::parse`. The decoder guarantees valid UTF-8.
    Frame(String),
    /// The current frame is unsalvageable; the decoder has already
    /// resynchronized (for JSONL: discarded through the next newline).
    Reject(ServeError),
}

/// Incremental frame extraction from a byte stream.
///
/// Implementations are push-based state machines: `feed` consumes an
/// arbitrary chunk (any split, down to one byte at a time, yields the
/// same events) and appends zero or more [`DecodeEvent`]s; `finish`
/// flushes whatever an EOF terminates. Neither ever panics on any byte
/// sequence — that property is fuzzed in `tests/fuzz_protocol.rs`.
pub trait FrameDecoder: Send {
    /// Consumes `bytes`, appending completed frames/rejections to `out`.
    fn feed(&mut self, bytes: &[u8], out: &mut Vec<DecodeEvent>);
    /// Signals end-of-stream, flushing any trailing unterminated frame.
    fn finish(&mut self, out: &mut Vec<DecodeEvent>);
}

/// Boxes the decoder selected by `kind`.
pub fn decoder_for(kind: CodecKind, limits: CodecLimits) -> Box<dyn FrameDecoder> {
    match kind {
        CodecKind::Line => Box::new(LineDecoder::new(limits)),
        CodecKind::Incremental => Box::new(IncrementalDecoder::new(limits)),
    }
}

/// Serializes one outbound protocol frame for a transport.
pub trait FrameEncoder: Send {
    /// Appends the wire form of one frame body (a JSON document,
    /// newline-free) to `out`.
    fn encode(&self, body: &str, out: &mut Vec<u8>);
}

/// JSONL framing: the body followed by `\n`.
#[derive(Clone, Copy, Debug, Default)]
pub struct LineEncoder;

impl FrameEncoder for LineEncoder {
    fn encode(&self, body: &str, out: &mut Vec<u8>) {
        out.extend_from_slice(body.as_bytes());
        out.push(b'\n');
    }
}

/// Server-sent-events framing: `data: <body>\n\n`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SseEncoder;

impl FrameEncoder for SseEncoder {
    fn encode(&self, body: &str, out: &mut Vec<u8>) {
        out.extend_from_slice(b"data: ");
        out.extend_from_slice(body.as_bytes());
        out.extend_from_slice(b"\n\n");
    }
}

/// Trims exactly the JSON whitespace set (space, tab, CR, LF) from a
/// completed frame. Deliberately narrower than `str::trim`'s Unicode
/// set: bytes like vertical tab or NEL are *not* whitespace to the
/// parser or to the incremental scanner, so stripping them here would
/// make the two codecs disagree about frames they surround.
pub(crate) fn trim_frame(text: &str) -> &str {
    text.trim_matches(|c: char| matches!(c, ' ' | '\t' | '\r' | '\n'))
}

/// The rejection for a frame that outgrew `max_frame_bytes`. Shared by
/// both codecs so the differential harness can assert identical errors.
pub(crate) fn err_oversized(max: usize) -> ServeError {
    ServeError::new("oversized", format!("request line exceeds {max} bytes"))
}

/// The rejection for bytes that are not valid UTF-8.
pub(crate) fn err_bad_utf8() -> ServeError {
    ServeError::new("bad_json", "request is not valid UTF-8")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoders_frame_bodies() {
        let mut out = Vec::new();
        LineEncoder.encode("{\"a\":1}", &mut out);
        assert_eq!(out, b"{\"a\":1}\n");
        out.clear();
        SseEncoder.encode("{\"a\":1}", &mut out);
        assert_eq!(out, b"data: {\"a\":1}\n\n");
    }

    #[test]
    fn codec_kind_parses() {
        assert_eq!(CodecKind::parse("line"), Some(CodecKind::Line));
        assert_eq!(CodecKind::parse("incremental"), Some(CodecKind::Incremental));
        assert_eq!(CodecKind::parse("jsonl"), None);
        assert_eq!(CodecKind::Line.name(), "line");
        assert_eq!(CodecKind::Incremental.name(), "incremental");
    }

    #[test]
    fn limits_follow_options() {
        let opts = ServeOptions { max_line_bytes: 512, ..ServeOptions::default() };
        let lim = CodecLimits::from_options(&opts);
        assert_eq!(lim.max_frame_bytes, 512);
        assert_eq!(lim.max_depth, json::MAX_DEPTH);
    }
}
