//! The reference JSONL codec: one fully buffered line per frame.
//!
//! This is the original serve framing, re-expressed as a push-based
//! [`FrameDecoder`] so it can run behind any transport and be compared
//! byte-for-byte against the incremental decoder. Semantics are pinned
//! to the historical bounded line reader:
//!
//! * a frame is the bytes before `\n` (a trailing `\r` is trimmed with
//!   the rest of the surrounding JSON whitespace — space, tab, CR, LF
//!   only, so the verdict on exotic Unicode whitespace matches the
//!   parser's and the incremental scanner's);
//! * a line whose content exceeds `max_frame_bytes` is consumed whole
//!   and yields exactly one `oversized` rejection;
//! * a line that is not valid UTF-8 yields one `bad_json` rejection;
//! * blank (whitespace-only) lines are skipped without an event.
//!
//! The verdict depends only on the line's total content length, never
//! on how the bytes were chunked across `feed` calls — `feed` one byte
//! at a time and you get the same events (pinned in tests below and in
//! the conformance corpus).

use super::{err_bad_utf8, err_oversized, trim_frame, CodecLimits, DecodeEvent, FrameDecoder};

/// Push-based JSONL framing with a hard line-length bound.
#[derive(Debug)]
pub struct LineDecoder {
    limits: CodecLimits,
    /// content bytes of the line in progress (no `\n`)
    buf: Vec<u8>,
    /// the line in progress already outgrew `max_frame_bytes`; its
    /// remaining bytes are discarded and one rejection is emitted at
    /// the newline (or EOF)
    overflow: bool,
}

impl LineDecoder {
    /// A fresh decoder with the given limits.
    pub fn new(limits: CodecLimits) -> LineDecoder {
        LineDecoder { limits, buf: Vec::new(), overflow: false }
    }

    /// Accumulates content bytes, tripping `overflow` once the line
    /// cannot fit. `buf` holds every prior byte while `!overflow`, so
    /// the check is exact regardless of chunk boundaries.
    fn push(&mut self, bytes: &[u8]) {
        if self.overflow || bytes.is_empty() {
            return;
        }
        if self.buf.len() + bytes.len() > self.limits.max_frame_bytes {
            self.overflow = true;
            self.buf.clear();
        } else {
            self.buf.extend_from_slice(bytes);
        }
    }

    /// Emits the event for the completed line in `buf` and resets.
    fn complete_line(&mut self, out: &mut Vec<DecodeEvent>) {
        if self.overflow {
            out.push(DecodeEvent::Reject(err_oversized(self.limits.max_frame_bytes)));
        } else {
            match std::str::from_utf8(&self.buf) {
                Err(_) => out.push(DecodeEvent::Reject(err_bad_utf8())),
                Ok(text) => {
                    let text = trim_frame(text);
                    if !text.is_empty() {
                        out.push(DecodeEvent::Frame(text.to_string()));
                    }
                }
            }
        }
        self.buf.clear();
        self.overflow = false;
    }
}

impl FrameDecoder for LineDecoder {
    fn feed(&mut self, mut bytes: &[u8], out: &mut Vec<DecodeEvent>) {
        while let Some(i) = bytes.iter().position(|&b| b == b'\n') {
            self.push(&bytes[..i]);
            self.complete_line(out);
            bytes = &bytes[i + 1..];
        }
        self.push(bytes);
    }

    fn finish(&mut self, out: &mut Vec<DecodeEvent>) {
        if !self.buf.is_empty() || self.overflow {
            self.complete_line(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits(max: usize) -> CodecLimits {
        CodecLimits { max_frame_bytes: max, ..CodecLimits::default() }
    }

    fn run(dec: &mut LineDecoder, bytes: &[u8], eof: bool) -> Vec<DecodeEvent> {
        let mut out = Vec::new();
        dec.feed(bytes, &mut out);
        if eof {
            dec.finish(&mut out);
        }
        out
    }

    #[test]
    fn frames_lines_and_trims() {
        let mut d = LineDecoder::new(limits(64));
        let ev = run(&mut d, b"  {\"a\":1}\r\n\n{\"b\":2}", true);
        assert_eq!(
            ev,
            vec![
                DecodeEvent::Frame("{\"a\":1}".to_string()),
                DecodeEvent::Frame("{\"b\":2}".to_string()),
            ]
        );
    }

    #[test]
    fn verdict_is_chunking_invariant() {
        let input = b"{\"prompt\":\"abc\"}\nnot json\n{\"x\":";
        let mut whole = LineDecoder::new(limits(64));
        let expect = run(&mut whole, input, true);
        for chunk in 1..=input.len() {
            let mut d = LineDecoder::new(limits(64));
            let mut out = Vec::new();
            for piece in input.chunks(chunk) {
                d.feed(piece, &mut out);
            }
            d.finish(&mut out);
            assert_eq!(out, expect, "chunk size {chunk}");
        }
    }

    #[test]
    fn oversized_line_one_reject() {
        let mut d = LineDecoder::new(limits(8));
        let mut input = vec![b'x'; 40];
        input.push(b'\n');
        input.extend_from_slice(b"{\"a\":1}\n");
        let ev = run(&mut d, &input, true);
        assert_eq!(ev.len(), 2);
        match &ev[0] {
            DecodeEvent::Reject(e) => assert_eq!(e.code, "oversized"),
            other => panic!("expected oversized, got {other:?}"),
        }
        assert_eq!(ev[1], DecodeEvent::Frame("{\"a\":1}".to_string()));
    }

    #[test]
    fn exact_limit_fits_one_more_rejects() {
        let at = vec![b'y'; 8];
        let mut d = LineDecoder::new(limits(8));
        let mut ev = run(&mut d, &at, true);
        assert_eq!(ev, vec![DecodeEvent::Frame("y".repeat(8))]);
        let over = vec![b'y'; 9];
        let mut d = LineDecoder::new(limits(8));
        ev = run(&mut d, &over, true);
        match &ev[..] {
            [DecodeEvent::Reject(e)] => assert_eq!(e.code, "oversized"),
            other => panic!("expected one oversized, got {other:?}"),
        }
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut d = LineDecoder::new(limits(64));
        let ev = run(&mut d, b"{\"p\":\"\xff\xfe\"}\n", false);
        match &ev[..] {
            [DecodeEvent::Reject(e)] => assert_eq!(e.code, "bad_json"),
            other => panic!("expected one bad_json, got {other:?}"),
        }
    }

    #[test]
    fn blank_lines_skipped_trailing_line_flushed() {
        let mut d = LineDecoder::new(limits(64));
        let ev = run(&mut d, b"\n   \r\n\t\n{\"a\":1}", true);
        assert_eq!(ev, vec![DecodeEvent::Frame("{\"a\":1}".to_string())]);
    }
}
