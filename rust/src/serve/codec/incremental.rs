//! Streaming structural JSON framer: no full-line buffering.
//!
//! [`IncrementalDecoder`] recognizes frame boundaries *as bytes
//! arrive* by tracking exactly the state needed to know where a JSON
//! document ends — string/escape state, container depth, and an
//! incremental strict-UTF-8 validator — while deliberately deferring
//! all grammar validation (commas, colons, escape legality, number
//! syntax) to [`crate::util::json::Json::parse`] on the completed
//! frame. That split is what makes the decoder provably agree with the
//! reference [`super::LineDecoder`] on every single-line input: the
//! scanner only rejects early on conditions the line codec also
//! rejects —
//!
//! * invalid UTF-8 (`bad_json`, same as the line codec's whole-line
//!   check),
//! * nesting past [`crate::util::json::MAX_DEPTH`] (`bad_json`, the
//!   parser enforces the identical bound),
//! * input past `max_frame_bytes` (`oversized`, counted per line with
//!   the same accounting as the bounded line reader),
//! * a raw newline inside a string (`bad_json`; the line codec chops
//!   the line there and the parser rejects the fragment),
//! * trailing data after a complete document (`bad_json`, the parser
//!   rejects the same line).
//!
//! Beyond single lines the incremental decoder is strictly more
//! capable: a structural document may span multiple lines (newlines
//! between tokens are JSON whitespace), bounded by `max_frame_bytes`
//! over the whole document. After any rejection the decoder
//! resynchronizes at the next newline — one malformed frame costs
//! exactly one structured error, never a wedged connection.

use super::{err_bad_utf8, err_oversized, trim_frame, CodecLimits, DecodeEvent, FrameDecoder};
use crate::serve::scheduler::ServeError;

/// Where the scanner is between bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum State {
    /// between frames, skipping whitespace
    Idle,
    /// inside a `{…}` / `[…]` document
    Doc,
    /// inside a non-structural document (scalar like `123` or `"x"`,
    /// or garbage): buffered to the newline and handed to the parser,
    /// which reproduces the line codec exactly for such lines
    Blob,
    /// a structural document is complete; only whitespace may follow
    /// before the newline that releases the frame
    DocDone,
    /// an error was emitted; discarding bytes through the next newline
    Resync,
}

/// Incremental frame scanner with per-byte limits enforcement.
///
/// Feeding the same bytes in different chunkings yields identical
/// events — all state is byte-granular, so a multi-byte UTF-8 sequence
/// or a `\"`-escape split across two `feed` calls is handled the same
/// as one contiguous buffer (pinned by tests and the fuzz harness).
#[derive(Debug)]
pub struct IncrementalDecoder {
    limits: CodecLimits,
    state: State,
    /// bytes of the document in progress (the eventual frame text)
    doc: Vec<u8>,
    /// open containers; the document completes when this returns to 0
    depth: usize,
    in_str: bool,
    esc: bool,
    /// raw encoded bytes of the string literal in progress
    str_bytes: usize,
    /// continuation bytes still expected for the UTF-8 char in progress
    utf8_need: u8,
    /// allowed range for the next continuation byte (strict UTF-8:
    /// rejects overlong forms, surrogates, and values past U+10FFFF)
    utf8_lo: u8,
    utf8_hi: u8,
    /// bytes seen on the current input line (`\n` excluded, `\r`
    /// included) — the line codec's oversized accounting, kept so both
    /// codecs reject the same lines
    line_bytes: usize,
}

impl IncrementalDecoder {
    /// A fresh decoder with the given limits.
    pub fn new(limits: CodecLimits) -> IncrementalDecoder {
        IncrementalDecoder {
            limits,
            state: State::Idle,
            doc: Vec::new(),
            depth: 0,
            in_str: false,
            esc: false,
            str_bytes: 0,
            utf8_need: 0,
            utf8_lo: 0x80,
            utf8_hi: 0xBF,
            line_bytes: 0,
        }
    }

    /// Drops all in-progress state and discards until the next newline.
    fn enter_resync(&mut self) {
        self.state = State::Resync;
        self.doc.clear();
        self.depth = 0;
        self.in_str = false;
        self.esc = false;
        self.str_bytes = 0;
        self.utf8_need = 0;
    }

    fn reject(&mut self, err: ServeError, out: &mut Vec<DecodeEvent>) {
        out.push(DecodeEvent::Reject(err));
        self.enter_resync();
    }

    /// Appends one byte to the document, rejecting `oversized` if the
    /// document itself outgrows the frame bound (reachable only via
    /// multi-line documents; single lines trip the line counter first).
    fn push_doc(&mut self, c: u8, out: &mut Vec<DecodeEvent>) -> bool {
        if self.doc.len() >= self.limits.max_frame_bytes {
            self.reject(err_oversized(self.limits.max_frame_bytes), out);
            return false;
        }
        self.doc.push(c);
        true
    }

    /// Emits the completed structural document held in `doc`.
    fn emit_doc(&mut self, out: &mut Vec<DecodeEvent>) {
        match String::from_utf8(std::mem::take(&mut self.doc)) {
            Ok(text) => out.push(DecodeEvent::Frame(text)),
            // unreachable: the scanner validated every byte
            Err(_) => out.push(DecodeEvent::Reject(err_bad_utf8())),
        }
    }

    /// Completes a blob (or an EOF-truncated document) the way the
    /// line codec completes a line: whole-buffer UTF-8 check, trim,
    /// skip if empty.
    fn emit_blob(&mut self, out: &mut Vec<DecodeEvent>) {
        let bytes = std::mem::take(&mut self.doc);
        match std::str::from_utf8(&bytes) {
            Err(_) => out.push(DecodeEvent::Reject(err_bad_utf8())),
            Ok(text) => {
                let text = trim_frame(text);
                if !text.is_empty() {
                    out.push(DecodeEvent::Frame(text.to_string()));
                }
            }
        }
    }

    /// Handles a newline, which is a frame boundary in every state
    /// except inside a structural document (where it is whitespace).
    fn newline(&mut self, out: &mut Vec<DecodeEvent>) {
        match self.state {
            State::Idle => {}
            State::Resync => self.state = State::Idle,
            State::DocDone => {
                self.emit_doc(out);
                self.state = State::Idle;
            }
            State::Blob => {
                self.emit_blob(out);
                self.state = State::Idle;
            }
            State::Doc => {
                if self.utf8_need > 0 {
                    self.reject(err_bad_utf8(), out);
                    self.state = State::Idle;
                } else if self.in_str {
                    // the line codec chops the line here and the parser
                    // rejects the fragment; same code, one event
                    self.reject(
                        ServeError::new("bad_json", "raw newline inside string"),
                        out,
                    );
                    self.state = State::Idle;
                } else {
                    // incremental-only capability: documents may span
                    // lines; the newline is inter-token whitespace
                    if self.push_doc(b'\n', out) {
                        return; // still mid-document: not a line boundary
                    }
                    self.state = State::Idle; // overflowed at the newline
                }
            }
        }
        self.line_bytes = 0;
    }

    /// Consumes one non-newline byte.
    fn step(&mut self, c: u8, out: &mut Vec<DecodeEvent>) {
        self.line_bytes += 1;
        if self.state != State::Resync && self.line_bytes > self.limits.max_frame_bytes {
            // same verdict the bounded line reader gives this line; any
            // pending completed document on the line is discarded, as
            // the line codec would discard it
            self.reject(err_oversized(self.limits.max_frame_bytes), out);
            return;
        }
        match self.state {
            State::Resync => {}
            State::Idle => match c {
                b' ' | b'\t' | b'\r' => {}
                b'{' | b'[' => {
                    self.doc.clear();
                    self.doc.push(c);
                    self.depth = 1;
                    self.in_str = false;
                    self.esc = false;
                    self.utf8_need = 0;
                    self.state = State::Doc;
                }
                _ => {
                    self.doc.clear();
                    self.doc.push(c);
                    self.state = State::Blob;
                }
            },
            State::Blob => self.doc.push(c),
            State::DocDone => match c {
                b' ' | b'\t' | b'\r' => {}
                _ => {
                    // `{"a":1} x` — the parser rejects the whole line as
                    // trailing data, so the completed document must not
                    // survive either
                    self.doc.clear();
                    self.reject(
                        ServeError::new("bad_json", "trailing data after JSON document"),
                        out,
                    );
                }
            },
            State::Doc => self.step_doc(c, out),
        }
    }

    /// One byte of a structural document.
    fn step_doc(&mut self, c: u8, out: &mut Vec<DecodeEvent>) {
        // continuation of a multi-byte UTF-8 char
        if self.utf8_need > 0 {
            if (self.utf8_lo..=self.utf8_hi).contains(&c) {
                self.utf8_need -= 1;
                self.utf8_lo = 0x80;
                self.utf8_hi = 0xBF;
                if self.push_doc(c, out) && self.in_str {
                    self.bump_str(out);
                }
            } else {
                self.reject(err_bad_utf8(), out);
            }
            return;
        }
        // lead byte of a multi-byte char (strict: overlong forms,
        // surrogates, and > U+10FFFF rejected at the lead/first-cont)
        if c >= 0x80 {
            let (need, lo, hi) = match c {
                0xC2..=0xDF => (1, 0x80, 0xBF),
                0xE0 => (2, 0xA0, 0xBF),
                0xE1..=0xEC | 0xEE..=0xEF => (2, 0x80, 0xBF),
                0xED => (2, 0x80, 0x9F),
                0xF0 => (3, 0x90, 0xBF),
                0xF1..=0xF3 => (3, 0x80, 0xBF),
                0xF4 => (3, 0x80, 0x8F),
                _ => {
                    self.reject(err_bad_utf8(), out);
                    return;
                }
            };
            self.utf8_need = need;
            self.utf8_lo = lo;
            self.utf8_hi = hi;
            // a non-ASCII escape "target" consumes the escape; the
            // parser rejects the frame's bad escape either way
            self.esc = false;
            if self.push_doc(c, out) && self.in_str {
                self.bump_str(out);
            }
            return;
        }
        // ASCII
        if self.in_str {
            if self.esc {
                self.esc = false;
                if self.push_doc(c, out) {
                    self.bump_str(out);
                }
                return;
            }
            match c {
                b'"' => {
                    self.in_str = false;
                    self.push_doc(c, out);
                }
                b'\\' => {
                    self.esc = true;
                    if self.push_doc(c, out) {
                        self.bump_str(out);
                    }
                }
                // raw control chars ride along; the parser rejects the
                // completed frame with its own message
                _ => {
                    if self.push_doc(c, out) {
                        self.bump_str(out);
                    }
                }
            }
            return;
        }
        match c {
            b'{' | b'[' => {
                self.depth += 1;
                if self.depth > self.limits.max_depth {
                    // the parser enforces the identical bound on the
                    // full frame; rejecting here keeps memory flat
                    self.reject(
                        ServeError::new(
                            "bad_json",
                            format!("nesting deeper than {}", self.limits.max_depth),
                        ),
                        out,
                    );
                } else {
                    self.push_doc(c, out);
                }
            }
            b'}' | b']' => {
                // mismatched closers (`[1}`) are the parser's call; the
                // scanner only needs the balance point
                if self.push_doc(c, out) {
                    self.depth -= 1;
                    if self.depth == 0 {
                        self.state = State::DocDone;
                    }
                }
            }
            b'"' => {
                self.in_str = true;
                self.esc = false;
                self.str_bytes = 0;
                self.push_doc(c, out);
            }
            _ => {
                self.push_doc(c, out);
            }
        }
    }

    /// Counts one raw string byte, rejecting past the string bound.
    fn bump_str(&mut self, out: &mut Vec<DecodeEvent>) {
        self.str_bytes += 1;
        if self.str_bytes > self.limits.max_string_bytes {
            self.reject(
                ServeError::new(
                    "oversized",
                    format!("string exceeds {} bytes", self.limits.max_string_bytes),
                ),
                out,
            );
        }
    }
}

impl FrameDecoder for IncrementalDecoder {
    fn feed(&mut self, bytes: &[u8], out: &mut Vec<DecodeEvent>) {
        for &c in bytes {
            if c == b'\n' {
                self.newline(out);
            } else {
                self.step(c, out);
            }
        }
    }

    fn finish(&mut self, out: &mut Vec<DecodeEvent>) {
        match self.state {
            State::Idle | State::Resync => {}
            State::DocDone => self.emit_doc(out),
            // an EOF-truncated document gets the line codec's
            // treatment: UTF-8 check, then the parser rejects the
            // fragment with its own "unexpected end" message
            State::Doc | State::Blob => self.emit_blob(out),
        }
        self.enter_resync();
        self.state = State::Idle;
        self.line_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits(max: usize) -> CodecLimits {
        CodecLimits { max_frame_bytes: max, ..CodecLimits::default() }
    }

    fn run(input: &[u8], lim: CodecLimits, eof: bool) -> Vec<DecodeEvent> {
        let mut d = IncrementalDecoder::new(lim);
        let mut out = Vec::new();
        d.feed(input, &mut out);
        if eof {
            d.finish(&mut out);
        }
        out
    }

    fn frame(s: &str) -> DecodeEvent {
        DecodeEvent::Frame(s.to_string())
    }

    fn code(ev: &DecodeEvent) -> &str {
        match ev {
            DecodeEvent::Reject(e) => e.code,
            DecodeEvent::Frame(_) => "frame",
        }
    }

    #[test]
    fn frames_documents() {
        let ev = run(b"  {\"a\": 1}\r\n[1,2]\n 123 \ntrue\n", limits(64), true);
        assert_eq!(
            ev,
            vec![frame("{\"a\": 1}"), frame("[1,2]"), frame("123"), frame("true")]
        );
    }

    #[test]
    fn chunking_invariant() {
        let input: &[u8] =
            b"{\"p\":\"caf\xc3\xa9 \\\"x\\\"\"}\n[1,[2,[3]]]\nnot json\n{\"cut\":\"\xff\"}\n{\"s\":";
        let mut whole = IncrementalDecoder::new(limits(64));
        let mut expect = Vec::new();
        whole.feed(input, &mut expect);
        whole.finish(&mut expect);
        for chunk in 1..=7 {
            let mut d = IncrementalDecoder::new(limits(64));
            let mut out = Vec::new();
            for piece in input.chunks(chunk) {
                d.feed(piece, &mut out);
            }
            d.finish(&mut out);
            assert_eq!(out, expect, "chunk size {chunk}");
        }
    }

    #[test]
    fn split_escape_and_split_utf8_across_feeds() {
        let mut d = IncrementalDecoder::new(limits(64));
        let mut out = Vec::new();
        d.feed(b"{\"p\":\"a\\", &mut out);
        d.feed(b"\"b caf\xc3", &mut out);
        d.feed(b"\xa9\"}\n", &mut out);
        assert_eq!(out, vec![frame("{\"p\":\"a\\\"b caf\u{e9}\"}")]);
    }

    #[test]
    fn multiline_document_accepted() {
        let ev = run(b"{\n  \"a\": 1,\n  \"b\": [1,\n2]\n}\n", limits(64), false);
        assert_eq!(ev, vec![frame("{\n  \"a\": 1,\n  \"b\": [1,\n2]\n}")]);
    }

    #[test]
    fn raw_newline_inside_string_rejects_once() {
        let ev = run(b"{\"a\":\"x\ny\"}\n", limits(64), true);
        // line 1 rejects at the newline; `y"}` is a blob frame the
        // parser will reject, exactly like the line codec's two lines
        assert_eq!(ev.len(), 2);
        assert_eq!(code(&ev[0]), "bad_json");
        assert_eq!(ev[1], frame("y\"}"));
    }

    #[test]
    fn depth_limit_is_parser_aligned() {
        // 64 levels parse; 65 reject — the same boundary Json::parse
        // enforces (see util::json::MAX_DEPTH)
        let ok = format!("{}1{}\n", "[".repeat(64), "]".repeat(64));
        let ev = run(ok.as_bytes(), CodecLimits::default(), false);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "frame");
        assert!(crate::util::json::Json::parse(match &ev[0] {
            DecodeEvent::Frame(f) => f,
            _ => unreachable!(),
        })
        .is_ok());

        let over = format!("{}1{}\n", "[".repeat(65), "]".repeat(65));
        let ev = run(over.as_bytes(), CodecLimits::default(), false);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "bad_json");
    }

    #[test]
    fn oversized_line_rejects_and_resyncs() {
        let mut input = vec![b'{'; 1];
        input.extend_from_slice(&[b' '; 40]);
        input.push(b'\n');
        input.extend_from_slice(b"{\"a\":1}\n");
        let ev = run(&input, limits(8), false);
        assert_eq!(ev.len(), 2);
        assert_eq!(code(&ev[0]), "oversized");
        assert_eq!(ev[1], frame("{\"a\":1}"));
    }

    #[test]
    fn exact_limit_boundary() {
        // 8 content bytes at max 8: fits
        let ev = run(b"{\"aa\":1}\n", limits(8), false);
        assert_eq!(ev, vec![frame("{\"aa\":1}")]);
        // trailing \r makes it 9 content bytes: the line codec counts
        // the \r, so the incremental decoder must too
        let ev = run(b"{\"aa\":1}\r\n", limits(8), false);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "oversized");
    }

    #[test]
    fn multiline_document_bounded_by_frame_size() {
        // every line is short, but the document never ends: the frame
        // bound must still trip (then the decoder resyncs and treats
        // later lines as fresh input)
        let mut d = IncrementalDecoder::new(limits(32));
        let mut out = Vec::new();
        d.feed(b"[\n", &mut out);
        for _ in 0..40 {
            d.feed(b"1,\n", &mut out);
        }
        assert!(!out.is_empty());
        assert_eq!(code(&out[0]), "oversized");
        // a document made almost entirely of newlines exercises the
        // doc-buffer bound specifically (the per-line counter never
        // grows)
        let mut d = IncrementalDecoder::new(limits(32));
        let mut out = Vec::new();
        d.feed(b"[", &mut out);
        for _ in 0..64 {
            d.feed(b"\n", &mut out);
        }
        assert_eq!(out.len(), 1);
        assert_eq!(code(&out[0]), "oversized");
    }

    #[test]
    fn trailing_data_discards_document() {
        let ev = run(b"{\"a\":1} x\n{\"b\":2}\n", limits(64), false);
        assert_eq!(ev.len(), 2);
        assert_eq!(code(&ev[0]), "bad_json");
        assert_eq!(ev[1], frame("{\"b\":2}"));
    }

    #[test]
    fn trailing_whitespace_after_document_ok() {
        let ev = run(b"{\"a\":1} \t\r\n", limits(64), false);
        assert_eq!(ev, vec![frame("{\"a\":1}")]);
    }

    #[test]
    fn invalid_utf8_rejects_and_resyncs() {
        // bad lead byte mid-document
        let ev = run(b"{\"p\":\"\xff tail\"}\n{\"b\":2}\n", limits(64), false);
        assert_eq!(ev.len(), 2);
        assert_eq!(code(&ev[0]), "bad_json");
        assert_eq!(ev[1], frame("{\"b\":2}"));
        // overlong encoding (0xC0 0xAF) is rejected, strict UTF-8
        let ev = run(b"{\"p\":\"\xc0\xaf\"}\n", limits(64), true);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "bad_json");
        // surrogate range (0xED 0xA0 0x80) is rejected
        let ev = run(b"{\"p\":\"\xed\xa0\x80\"}\n", limits(64), true);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "bad_json");
    }

    #[test]
    fn eof_truncated_document_becomes_parser_food() {
        let ev = run(b"{\"a\":", limits(64), true);
        assert_eq!(ev, vec![frame("{\"a\":")]);
        // ... which the parser rejects, matching the line codec
        assert!(crate::util::json::Json::parse("{\"a\":").is_err());
    }

    #[test]
    fn string_limit_binds_when_tight() {
        let lim = CodecLimits { max_string_bytes: 4, ..limits(1024) };
        let ev = run(b"{\"key\":\"abcdefgh\"}\n", lim, false);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "oversized");
        // keys are strings too
        let ev = run(b"{\"longkey\":1}\n", lim, false);
        assert_eq!(ev.len(), 1);
        assert_eq!(code(&ev[0]), "oversized");
        let ev = run(b"{\"key\":\"abcd\"}\n", lim, false);
        assert_eq!(ev, vec![frame("{\"key\":\"abcd\"}")]);
    }
}
