//! Concurrent batched inference server over a quantized model.
//!
//! Two transports share one engine (`--transport tcp|http|auto`, see
//! DESIGN.md §14): newline-delimited JSON over raw TCP, and HTTP/1.1
//! (`POST /v1/generate`, streaming mapped to server-sent events). Both
//! feed the identical scheduler/admission loop through the
//! [`codec::FrameDecoder`] framing layer — protocol v2 semantics are
//! shared, not duplicated per transport.
//! Protocol **v2** (see DESIGN.md §10): a request line is
//!
//! ```json
//! {"prompt": "text...", "max_tokens": N,
//!  "params": {"temperature": 0.8, "top_k": 40, "top_p": 0.9,
//!             "repetition_penalty": 1.1, "seed": 7,
//!             "stop": ["text"], "stop_tokens": [3]},
//!  "stream": true}
//! ```
//!
//! where `params` and `stream` are optional — a bare v1 line
//! (`{"prompt": ..., "max_tokens": N}` or `"tokens": [...]`) still
//! parses and decodes greedily, token-identical to the v1 server. A
//! non-streaming response line is
//! `{"tokens": [...], "text": "...", "latency_ms": x, "queue_ms": y}`;
//! with `"stream": true` the server first emits one frame line
//! `{"token": t, "index": i, "text": "word"}` per decoded token, then
//! the same terminal response object (so the frames always concatenate
//! to the final `tokens`). Rejections are structured
//! `{"error": {"code": "...", "message": "..."}}` lines; sampling
//! parameters are validated at this boundary (code `bad_params`).
//! Responses on a connection always come back in request order, frames
//! ordered within their request.
//!
//! Overload protection (see DESIGN.md §16): an optional v2
//! `"deadline_ms"` field bounds a request's total time in the server
//! (expired slots are evicted with code `deadline_exceeded`), queue
//! waits past `--max-queue-wait-ms` shed at admission with code
//! `overloaded` and a `retry_after_ms` hint (HTTP 503 + `Retry-After`),
//! and SIGTERM flips the listener into a graceful drain (new requests
//! get `shutting_down`, in-flight ones finish up to
//! `--drain-timeout-ms`). `GET /healthz` / `GET /readyz` report
//! liveness/readiness on the HTTP front end.
//!
//! Architecture (see DESIGN.md §8):
//!
//! ```text
//!            ┌ reader thread ┐                       ┌ writer thread ┐
//!  conn 0 ──▶│ parse+validate│──┐                ┌──▶│ reorder+write │──▶ conn 0
//!  conn 1 ──▶│ (1 per conn)  │──┤  bounded queue │   │ (1 per conn)  │──▶ conn 1
//!   ...      └───────────────┘  ▼                │   └───────────────┘
//!                        ┌──────────────┐        │
//!                        │  scheduler   │────────┘
//!                        │ micro-batches│  per-conn bounded writer queues
//!                        └──────────────┘
//! ```
//!
//! The PJRT client is not `Send`, so the scheduler runs on the thread
//! that calls [`Generator::serve`] and owns every model execution;
//! concurrency comes from micro-batching decode steps over the `[B, T]`
//! token window (continuous batching: requests join and retire at step
//! boundaries). Readers validate and enqueue; the bounded request queue
//! and bounded per-connection writer queues provide backpressure instead
//! of unbounded buffering, and a client that stops reading its responses
//! is force-disconnected rather than allowed to stall the scheduler.
//! Greedy decode output is token-identical to the sequential
//! [`Generator::generate`] path: both run the `serve::batch` core, whose
//! backends compute each logits row from its own slot only (exact by
//! construction for `SyntheticBackend` and per-slot execution; verified
//! against the lowered batched artifacts by the artifact-gated
//! `serve_runtime_batched_matches_sequential` test).

pub mod batch;
pub mod client;
pub mod codec;
pub mod fault;
pub mod http;
pub mod sampling;
pub mod scheduler;
pub mod spec;

use std::collections::{BTreeMap, HashSet};
use std::io::{ErrorKind, Read as _, Write as _};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

pub use batch::{
    argmax, generate, generate_greedy, DecodeSlot, RuntimeBackend, StepBackend, SyntheticBackend,
};
pub use client::Client;
pub use codec::CodecKind;
pub use fault::{FaultBackend, FaultPlan};
pub use sampling::{GenParams, Sampler};
pub use scheduler::{Lifecycle, Registry, SchedStats, ServeError, ServeOptions, Transport};
pub use spec::{
    spec_generate, ModelEntry, ModelQueueStats, ModelRegistry, SpecDecoder, SpecModel, SpecStats,
};
use codec::{CodecLimits, DecodeEvent, FrameEncoder as _, LineEncoder, SseEncoder};
use scheduler::{DecodeRequest, Decoded, WriterMsg};

use crate::data::Tokenizer;
use crate::runtime::Runtime;
use crate::train::QuantParamStore;
use crate::util::json::Json;
use crate::util::threads::{spawn_named, WaitGroup};

/// A runtime + quantized model + tokenizer bundle: the XLA-backed
/// serving entry point (generate once, or serve over TCP).
pub struct Generator<'r> {
    /// the PJRT runtime the decode artifacts execute on
    pub rt: &'r Runtime,
    /// quantized layers held packed (~4.5 bits/weight); dequantized
    /// lazily on first forward and memoized for the process lifetime
    pub params: QuantParamStore,
    /// word-level tokenizer sized to the model vocab
    pub tokenizer: Tokenizer,
}

impl<'r> Generator<'r> {
    /// Bundle a runtime and a quantized store (logs the packed footprint).
    pub fn new(rt: &'r Runtime, params: QuantParamStore) -> Generator<'r> {
        let tokenizer = Tokenizer::new(rt.config().vocab);
        let packed = params.packed_payload_bytes();
        if packed > 0 {
            let dense = params.packed_dense_bytes();
            crate::info!(
                "model payload: {} quantized layers packed at {:.2} MiB ({:.2} MiB as fp32, \
                 {:.1}x smaller); dense copies are decoded lazily per layer and memoized",
                params.n_packed(),
                packed as f64 / (1 << 20) as f64,
                dense as f64 / (1 << 20) as f64,
                dense as f64 / packed as f64
            );
        }
        Generator { rt, params, tokenizer }
    }

    /// The deployed W4A4 decode backend (weights resident on device).
    pub fn backend(&self) -> Result<RuntimeBackend<'_>> {
        RuntimeBackend::new(self.rt, &self.params)
    }

    /// Greedy-decode `max_tokens` continuations of `prompt`. Errors on an
    /// empty prompt — decoding from a zeroed buffer is not a completion.
    pub fn generate(&self, prompt: &[i32], max_tokens: usize) -> Result<Vec<i32>> {
        self.generate_with(prompt, max_tokens, GenParams::default())
    }

    /// Decode under explicit generation parameters (temperature / top-k /
    /// top-p / repetition penalty / stops; seeded for reproducibility).
    pub fn generate_with(
        &self,
        prompt: &[i32],
        max_tokens: usize,
        params: GenParams,
    ) -> Result<Vec<i32>> {
        if prompt.is_empty() {
            bail!("empty prompt: nothing to condition the decode on");
        }
        generate(&self.backend()?, prompt, max_tokens, params)
    }

    /// Serve forever (or until `max_conns` connections, for tests) with
    /// default engine options.
    pub fn serve(&self, addr: &str, max_conns: Option<usize>) -> Result<()> {
        self.serve_with(addr, max_conns, ServeOptions::default()).map(|_| ())
    }

    /// Serve with explicit engine options; returns scheduler counters
    /// when the engine drains (test/max_conns mode).
    pub fn serve_with(
        &self,
        addr: &str,
        max_conns: Option<usize>,
        opts: ServeOptions,
    ) -> Result<SchedStats> {
        let listener = TcpListener::bind(addr)?;
        crate::info!(
            "serving on {} (model {}, max_batch {}, queue_depth {}, workers {})",
            listener.local_addr()?,
            self.rt.config().name,
            opts.max_batch,
            opts.queue_depth,
            opts.workers
        );
        serve_on(&self.backend()?, listener, max_conns, opts)
    }
}

// ---------------------------------------------------------------------------
// Protocol: request validation + response serialization

/// One fully validated v1/v2 request line, ready for the scheduler.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedRequest {
    /// validated prompt token ids
    pub prompt: Vec<i32>,
    /// tokens to decode, clamped to the server cap
    pub max_tokens: usize,
    /// generation parameters (server defaults merged with the request's
    /// `params` object)
    pub params: GenParams,
    /// emit incremental token frames while decoding
    pub stream: bool,
    /// validated model name from the v2 `"model"` field (`None` routes
    /// to the server's default model)
    pub model: Option<String>,
    /// total time budget in milliseconds, measured from enqueue: the
    /// request's `"deadline_ms"` field, or the server default when the
    /// field is absent (`None` = no deadline)
    pub deadline_ms: Option<u64>,
}

/// Parse and validate one request line (v1 bare lines or v2 with
/// `params` / `stream`). Every rejection is a structured [`ServeError`]
/// so clients can match on `code` instead of scraping message strings.
pub fn parse_request(
    line: &str,
    tok: &Tokenizer,
    vocab: usize,
    opts: &ServeOptions,
) -> std::result::Result<ParsedRequest, ServeError> {
    if line.len() > opts.max_line_bytes {
        return Err(ServeError::new(
            "oversized",
            format!("request line exceeds {} bytes", opts.max_line_bytes),
        ));
    }
    let req = Json::parse(line).map_err(|e| ServeError::new("bad_json", e.to_string()))?;
    let max_tokens = match req.get("max_tokens") {
        None => 16,
        Some(v) => v.as_usize().map_err(|_| {
            ServeError::new("bad_request", "'max_tokens' must be a non-negative integer")
        })?,
    };
    // clamp to the server cap rather than reject: the cap is an
    // operational limit, not a protocol violation
    let max_tokens = max_tokens.min(opts.max_tokens_cap);
    let prompt: Vec<i32> = if let Some(toks) = req.get("tokens") {
        let arr = toks
            .as_arr()
            .map_err(|_| ServeError::new("bad_request", "'tokens' must be an array"))?;
        parse_token_ids(arr, vocab, "bad_token", "token")?
    } else if let Some(text) = req.get("prompt") {
        let s = text
            .as_str()
            .map_err(|_| ServeError::new("bad_request", "'prompt' must be a string"))?;
        tok.encode(s)
    } else {
        return Err(ServeError::new("bad_request", "request needs 'prompt' or 'tokens'"));
    };
    if prompt.is_empty() {
        return Err(ServeError::new(
            "empty_prompt",
            "empty prompt: nothing to condition the decode on",
        ));
    }
    // a request WITHOUT a params object inherits the server defaults; a
    // request WITH one is self-contained, starting from the greedy
    // baseline — so `"params": {}` is the documented way to force greedy
    // on a server launched with sampling defaults (explicit
    // `"temperature": 0` stays rejected by contract)
    let params = match req.get("params") {
        None => opts.defaults.clone(),
        Some(p) => parse_params(p, tok, vocab)?,
    };
    let stream = match req.get("stream") {
        None => false,
        Some(v) => v
            .as_bool()
            .map_err(|_| ServeError::new("bad_request", "'stream' must be a boolean"))?,
    };
    // the "model" field routes to a registry entry; validated HERE so an
    // unknown name is a structured rejection (HTTP 404) before it can
    // occupy a scheduler slot
    let model = match req.get("model") {
        None => None,
        Some(v) => {
            let name = v
                .as_str()
                .map_err(|_| ServeError::new("bad_request", "'model' must be a string"))?;
            if !opts.models.iter().any(|m| m == name) {
                return Err(ServeError::new(
                    "unknown_model",
                    if opts.models.is_empty() {
                        format!("unknown model '{name}': this server hosts no named models")
                    } else {
                        format!("unknown model '{name}'; hosted: {}", opts.models.join(", "))
                    },
                ));
            }
            Some(name.to_string())
        }
    };
    // a request without the field inherits the server-wide default
    // deadline (0 = none); an explicit field must be a positive integer
    // — `"deadline_ms": 0` would be a request that can never complete
    let deadline_ms = match req.get("deadline_ms") {
        None => (opts.default_deadline_ms > 0).then_some(opts.default_deadline_ms),
        Some(v) => {
            let ms = v.as_usize().map_err(|_| {
                ServeError::new("bad_request", "'deadline_ms' must be a positive integer")
            })?;
            if ms == 0 {
                return Err(ServeError::new(
                    "bad_request",
                    "'deadline_ms' must be > 0 (omit it for no deadline)",
                ));
            }
            Some(ms as u64)
        }
    };
    Ok(ParsedRequest { prompt, max_tokens, params, stream, model, deadline_ms })
}

/// Parse a `{"cancel": N}` control frame (TCP transport): `N` is the
/// connection-local request sequence number to evict. Control frames
/// consume no sequence number and get no acknowledgement — the
/// cancelled request itself answers with a structured `cancelled`
/// error (or its normal response, if it won the race). Anything that
/// is not exactly a one-key `cancel` object is NOT a control frame and
/// flows on to request parsing.
pub fn parse_cancel(frame: &str) -> Option<u64> {
    let v = Json::parse(frame).ok()?;
    let obj = v.as_obj().ok()?;
    let [(key, val)] = obj else { return None };
    if key.as_str() != "cancel" {
        return None;
    }
    let x = val.as_f64().ok()?;
    (x.fract() == 0.0 && (0.0..=u64::MAX as f64).contains(&x)).then_some(x as u64)
}

/// Validate a JSON array of token ids (rejects non-integers, negatives,
/// and out-of-vocab ids). `code` is the structured error class for
/// rejections; `what` names the field in error messages.
fn parse_token_ids(
    arr: &[Json],
    vocab: usize,
    code: &'static str,
    what: &str,
) -> std::result::Result<Vec<i32>, ServeError> {
    let mut out = Vec::with_capacity(arr.len());
    for t in arr {
        let x = t
            .as_f64()
            .map_err(|_| ServeError::new(code, format!("{what} ids must be integers")))?;
        if x.fract() != 0.0 || x < 0.0 || x >= vocab as f64 {
            return Err(ServeError::new(code, format!("{what} id {x} outside [0, {vocab})")));
        }
        out.push(x as i32);
    }
    Ok(out)
}

/// Validate a v2 `params` object against the sampling contract: explicit
/// `temperature` must be finite and positive, `top_p` in (0, 1],
/// `top_k >= 1`, stop lists bounded, and no unknown keys (a typo'd knob
/// silently decoding greedily would be worse than a rejection). The
/// object is self-contained: fields it omits take their greedy-baseline
/// defaults, NOT the server's `--temperature ...` defaults — which makes
/// an empty `"params": {}` the explicit greedy opt-out on a server
/// launched with sampling defaults.
fn parse_params(
    obj: &Json,
    tok: &Tokenizer,
    vocab: usize,
) -> std::result::Result<GenParams, ServeError> {
    let bad = |msg: String| ServeError::new("bad_params", msg);
    let pairs = obj
        .as_obj()
        .map_err(|_| bad("'params' must be an object".into()))?;
    let mut p = GenParams::default();
    for (key, v) in pairs {
        match key.as_str() {
            "temperature" => {
                let t = v.as_f64().map_err(|_| bad("'temperature' must be a number".into()))?;
                if !t.is_finite() || t <= 0.0 {
                    return Err(bad(format!(
                        "'temperature' must be finite and > 0, got {t} (send an empty \
                         'params' object for greedy)"
                    )));
                }
                p.temperature = t as f32;
            }
            "top_k" => {
                let k = v
                    .as_usize()
                    .map_err(|_| bad("'top_k' must be a positive integer".into()))?;
                if k == 0 {
                    return Err(bad(
                        "'top_k' must be >= 1 (omit it to sample the full vocabulary)".into(),
                    ));
                }
                p.top_k = k;
            }
            "top_p" => {
                let x = v.as_f64().map_err(|_| bad("'top_p' must be a number".into()))?;
                if !(x > 0.0 && x <= 1.0) {
                    return Err(bad(format!("'top_p' must be in (0, 1], got {x}")));
                }
                p.top_p = x as f32;
            }
            "repetition_penalty" => {
                let x = v
                    .as_f64()
                    .map_err(|_| bad("'repetition_penalty' must be a number".into()))?;
                if !x.is_finite() || x <= 0.0 {
                    return Err(bad(format!(
                        "'repetition_penalty' must be finite and > 0, got {x}"
                    )));
                }
                p.repetition_penalty = x as f32;
            }
            "seed" => {
                let s = v
                    .as_usize()
                    .map_err(|_| bad("'seed' must be a non-negative integer".into()))?;
                p.seed = s as u64;
            }
            "stop_tokens" => {
                let arr = v
                    .as_arr()
                    .map_err(|_| bad("'stop_tokens' must be an array of token ids".into()))?;
                p.stop_tokens = parse_token_ids(arr, vocab, "bad_params", "stop token")?;
            }
            "stop" => {
                let arr = v
                    .as_arr()
                    .map_err(|_| bad("'stop' must be an array of strings".into()))?;
                let mut seqs = Vec::with_capacity(arr.len());
                for s in arr {
                    let text = s
                        .as_str()
                        .map_err(|_| bad("'stop' entries must be strings".into()))?;
                    let seq = tok.encode(text);
                    if seq.is_empty() {
                        return Err(bad("'stop' entries must encode to at least one token".into()));
                    }
                    seqs.push(seq);
                }
                p.stop_sequences = seqs;
            }
            other => {
                return Err(bad(format!("unknown sampling parameter '{other}'")));
            }
        }
    }
    // caps (stop-list sizes and the like) and cross-field invariants
    p.validate().map_err(|e| bad(e.to_string()))?;
    Ok(p)
}

/// One streaming token frame: `{"token": t, "index": i, "text": "word"}`.
fn format_frame(index: usize, token: i32, tok: &Tokenizer) -> String {
    Json::obj(vec![
        ("token", Json::num(token as f64)),
        ("index", Json::num(index as f64)),
        ("text", Json::str(tok.decode(&[token]))),
    ])
    .to_string()
}

fn format_response(result: &std::result::Result<Decoded, ServeError>, tok: &Tokenizer) -> String {
    match result {
        Ok(d) => Json::obj(vec![
            (
                "tokens",
                Json::Arr(d.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("text", Json::str(tok.decode(&d.tokens))),
            ("latency_ms", Json::Num(d.latency_ms)),
            ("queue_ms", Json::Num(d.queue_ms)),
        ])
        .to_string(),
        Err(e) => {
            let mut fields = vec![
                ("code", Json::str(e.code)),
                ("message", Json::str(e.message.as_str())),
            ];
            if let Some(ms) = e.retry_after_ms {
                // machine-readable backoff hint (mirrored as the HTTP
                // `Retry-After` header on that transport)
                fields.push(("retry_after_ms", Json::num(ms as f64)));
            }
            Json::obj(vec![("error", Json::obj(fields))]).to_string()
        }
    }
}

// ---------------------------------------------------------------------------
// Engine: acceptor + per-connection reader/writer threads around the
// scheduler. Generic over the backend so tests and benches drive the
// whole TCP path with `SyntheticBackend`.

/// Bind `addr` and run the serving engine over `backend` — the entry
/// point for backends that don't go through [`Generator`] (the native
/// pure-rust backend, the synthetic load backend). Returns the scheduler
/// counters once `max_conns` connections have drained; never returns
/// when `max_conns` is `None`.
pub fn serve_backend<B: StepBackend + ?Sized>(
    backend: &B,
    addr: &str,
    max_conns: Option<usize>,
    opts: ServeOptions,
) -> Result<SchedStats> {
    let listener = TcpListener::bind(addr)?;
    crate::info!(
        "serving on {} (vocab {}, seq_len {}, max_batch {}, queue_depth {}, workers {})",
        listener.local_addr()?,
        backend.vocab(),
        backend.seq_len(),
        opts.max_batch,
        opts.queue_depth,
        opts.workers
    );
    serve_on(backend, listener, max_conns, opts)
}

/// Run the serving engine on an already-bound listener. The calling
/// thread becomes the scheduler (the backend — and with it the PJRT
/// client — never crosses threads). Returns once `max_conns` connections
/// have been accepted and fully drained; never returns when
/// `max_conns` is `None`.
pub fn serve_on<B: StepBackend + ?Sized>(
    backend: &B,
    listener: TcpListener,
    max_conns: Option<usize>,
    opts: ServeOptions,
) -> Result<SchedStats> {
    opts.validate()?;
    // one tokenizer shared by every connection thread (vocab-sized build)
    let tok = Arc::new(Tokenizer::new(backend.vocab()));
    let registry = Arc::new(Registry::default());
    let (req_tx, req_rx) = sync_channel::<DecodeRequest>(opts.queue_depth.max(1));
    let wg = WaitGroup::new();
    let acceptor = {
        let registry = registry.clone();
        let opts = opts.clone();
        let wg = wg.clone();
        spawn_named("serve-acceptor".into(), move || {
            accept_loop(listener, req_tx, registry, wg, opts, max_conns, tok);
        })
    };
    let stats = scheduler::run(backend, req_rx, &registry, &opts)?;
    // the scheduler only exits once the acceptor and every reader dropped
    // their queue handles; wait for writers to flush in-flight responses
    let _ = acceptor.join();
    wg.wait();
    crate::info!(
        "serve drained: {} completed, {} cancelled, {} errors, {} steps ({} batched, peak batch {})",
        stats.completed,
        stats.cancelled,
        stats.errors,
        stats.steps,
        stats.batched_steps,
        stats.peak_batch
    );
    crate::info!(
        "serve cache: prefix hit rate {:.1}% ({}/{} lookups, {} tokens reused, {} trie pages), \
         kv pages high-water {}, prefill budget utilization {:.1}% ({} chunks)",
        stats.prefix_hit_rate() * 100.0,
        stats.cache.prefix_hits,
        stats.cache.prefix_lookups,
        stats.cache.prefix_hit_tokens,
        stats.cache.prefix_pages,
        stats.cache.kv_pages_hwm,
        stats.budget_utilization() * 100.0,
        stats.prefill_chunks
    );
    if stats.spec.rounds > 0 {
        crate::info!(
            "serve spec: {} drafted, {} accepted ({:.1}% accept rate), {} verify passes \
             over {} rounds",
            stats.spec.drafted,
            stats.spec.accepted,
            stats.spec.accept_rate() * 100.0,
            stats.spec.verify_passes,
            stats.spec.rounds
        );
    }
    for q in &stats.model_queues {
        crate::info!(
            "serve model '{}': {} admitted, {} completed, peak queue depth {}",
            q.name,
            q.admitted,
            q.completed,
            q.peak_depth
        );
    }
    Ok(stats)
}

fn accept_loop(
    listener: TcpListener,
    req_tx: SyncSender<DecodeRequest>,
    registry: Arc<Registry>,
    wg: WaitGroup,
    opts: ServeOptions,
    max_conns: Option<usize>,
    tok: Arc<Tokenizer>,
) {
    let mut served = 0usize;
    let mut next_conn = 0u64;
    // non-blocking accept so a drain signal can stop the acceptor even
    // when no new connection ever arrives (a blocked `accept` would
    // otherwise hold the request queue open past the drain deadline)
    if let Err(e) = listener.set_nonblocking(true) {
        crate::warn!("accept: set_nonblocking failed: {e}");
    }
    loop {
        if opts.lifecycle.draining() {
            crate::info!("acceptor: draining, no longer accepting connections");
            break;
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                crate::warn!("accept: {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        // the per-connection reader/writer threads use blocking reads
        // (with the configured read timeout), not the listener's mode
        if let Err(e) = stream.set_nonblocking(false) {
            crate::warn!("accept: set_blocking failed: {e}");
            continue;
        }
        // admission control: at most `workers` connections in flight
        registry.wait_below(opts.workers);
        let conn = next_conn;
        next_conn += 1;
        if opts.read_timeout_ms > 0 {
            let _ = stream.set_read_timeout(Some(Duration::from_millis(opts.read_timeout_ms)));
        }
        let _ = stream.set_nodelay(true);
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        // two extra handles: one for the writer thread, one kept in the
        // registry so the scheduler can force-disconnect a stalled client
        match (stream.try_clone(), stream.try_clone()) {
            (Ok(write_half), Ok(shutdown_half)) => {
                let (w_tx, w_rx) = sync_channel::<WriterMsg>(opts.queue_depth.max(1));
                registry.register(conn, w_tx.clone(), Some(shutdown_half));
                let progress = Arc::new(ConnProgress::default());
                {
                    let registry = registry.clone();
                    let wg = wg.clone();
                    let tok = tok.clone();
                    let progress = progress.clone();
                    let max_pending = opts.queue_depth;
                    spawn_named(format!("serve-writer-{conn}"), move || {
                        let w = ConnWriter::jsonl(write_half, tok);
                        writer_loop(w, conn, w_rx, &registry, &progress, max_pending);
                        drop(wg);
                    });
                }
                {
                    let req_tx = req_tx.clone();
                    let opts = opts.clone();
                    let wg = wg.clone();
                    let tok = tok.clone();
                    let registry = registry.clone();
                    spawn_named(format!("serve-reader-{conn}"), move || {
                        reader_loop(
                            stream, conn, &peer, req_tx, w_tx, &registry, &opts, &tok, &progress,
                        );
                        drop(wg);
                    });
                }
                served += 1;
            }
            (Err(e), _) | (_, Err(e)) => {
                crate::warn!("connection {peer}: clone failed: {e}");
            }
        }
        // checked even when the clone failed, so a failed connection can
        // never push the acceptor past max_conns
        if let Some(n) = max_conns {
            if served >= n {
                break;
            }
        }
    }
    // dropping our req_tx handle lets the scheduler drain and exit once
    // every reader is done
}

/// Shared per-connection progress counters: requests the reader has
/// issued vs responses the writer has written. At read-timeout time they
/// distinguish an *idle* connection (reap it) from one waiting on its
/// own decode (keep it). The writer stores `u64::MAX` into `written` on
/// exit so a reader never waits on a writer that is gone.
#[derive(Default)]
struct ConnProgress {
    issued: AtomicU64,
    written: AtomicU64,
}

/// Per-connection reader entry point: selects the transport (forced by
/// `--transport`, or sniffed from the first bytes under `auto`), then
/// runs the matching read loop. Both loops end by telling the writer
/// exactly how many responses it still owes.
#[allow(clippy::too_many_arguments)]
fn reader_loop(
    mut stream: TcpStream,
    conn: u64,
    peer: &str,
    req_tx: SyncSender<DecodeRequest>,
    w_tx: SyncSender<WriterMsg>,
    registry: &Registry,
    opts: &ServeOptions,
    tok: &Tokenizer,
    progress: &ConnProgress,
) {
    let (is_http, first) = match opts.transport {
        Transport::Tcp => (false, Vec::new()),
        Transport::Http => (true, Vec::new()),
        Transport::Auto => match sniff_transport(&mut stream) {
            Ok(x) => x,
            Err(_) => {
                // nothing was issued: release the writer immediately
                let _ = w_tx.send(WriterMsg::Done { next_seq: 0 });
                crate::debug!("connection {peer}: closed before transport sniff");
                return;
            }
        },
    };
    if is_http {
        // switch the writer to HTTP framing before any request can
        // reach the scheduler (writer-queue order is the causal fence)
        if w_tx.send(WriterMsg::Http).is_err() {
            return;
        }
        http::reader_loop(stream, first, conn, peer, &req_tx, &w_tx, opts, tok, progress);
    } else {
        jsonl_reader_loop(stream, first, conn, peer, &req_tx, &w_tx, registry, opts, tok, progress);
    }
}

/// Decide a connection's transport from its opening bytes: an HTTP
/// method token followed by a space selects HTTP; anything else
/// (JSON's `{`, whitespace, or garbage destined for a structured
/// error) is JSONL. `None` = the prefix read so far is still ambiguous.
fn sniff_decision(b: &[u8]) -> Option<bool> {
    const METHODS: [&[u8]; 7] = [
        b"GET ", b"POST ", b"PUT ", b"HEAD ", b"DELETE ", b"OPTIONS ", b"PATCH ",
    ];
    if b.is_empty() {
        return None;
    }
    let mut partial = false;
    for m in METHODS {
        if b.len() >= m.len() {
            if b.starts_with(m) {
                return Some(true);
            }
        } else if m.starts_with(b) {
            partial = true;
        }
    }
    if partial {
        None
    } else {
        Some(false)
    }
}

/// Read just enough of the stream to classify the transport; returns
/// the sniffed bytes so the selected reader replays them. A timeout or
/// error here means the connection died before sending anything useful.
fn sniff_transport(stream: &mut TcpStream) -> std::io::Result<(bool, Vec<u8>)> {
    let mut first: Vec<u8> = Vec::new();
    let mut buf = [0u8; 256];
    loop {
        if let Some(is_http) = sniff_decision(&first) {
            return Ok((is_http, first));
        }
        match stream.read(&mut buf) {
            // EOF while ambiguous (e.g. exactly "GE"): hand the bytes
            // to the JSONL path, which turns them into a structured
            // error like any other garbage
            Ok(0) => return Ok((false, first)),
            Ok(n) => first.extend_from_slice(&buf[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// JSONL read loop: raw chunk reads feed the connection's
/// [`codec::FrameDecoder`] (`--codec line|incremental`); completed
/// frames are validated and enqueued (the blocking send is the
/// backpressure point), rejections become structured error responses.
#[allow(clippy::too_many_arguments)]
fn jsonl_reader_loop(
    mut stream: TcpStream,
    first: Vec<u8>,
    conn: u64,
    peer: &str,
    req_tx: &SyncSender<DecodeRequest>,
    w_tx: &SyncSender<WriterMsg>,
    registry: &Registry,
    opts: &ServeOptions,
    tok: &Tokenizer,
    progress: &ConnProgress,
) {
    let vocab = tok.vocab();
    let mut decoder = codec::decoder_for(opts.codec, CodecLimits::from_options(opts));
    let mut events: Vec<DecodeEvent> = Vec::new();
    let mut seq = 0u64;
    let mut buf = [0u8; 4096];
    let mut open = true;
    decoder.feed(&first, &mut events);
    'conn: loop {
        for ev in events.drain(..) {
            let outcome = match ev {
                DecodeEvent::Frame(frame) => {
                    // control frames consume no sequence number: the
                    // cancellation is recorded against the connection and
                    // the scheduler evicts the slot at its next tick (or
                    // refuses admission, if the request is still queued)
                    if let Some(id) = parse_cancel(&frame) {
                        registry.request_cancel(conn, id);
                        continue;
                    }
                    parse_request(&frame, tok, vocab, opts)
                }
                DecodeEvent::Reject(e) => Err(e),
            };
            let this = seq;
            seq += 1;
            progress.issued.store(seq, Ordering::Release);
            match outcome {
                Ok(ParsedRequest { prompt, max_tokens, params, stream, model, deadline_ms }) => {
                    let req = DecodeRequest {
                        conn,
                        seq: this,
                        prompt,
                        max_tokens,
                        params,
                        stream,
                        model,
                        deadline_ms,
                        enqueued: Instant::now(),
                    };
                    if req_tx.send(req).is_err() {
                        // scheduler gone: this request will never be
                        // answered — don't make the writer wait for it
                        seq = this;
                        break 'conn;
                    }
                }
                Err(e) => {
                    if w_tx.send(WriterMsg::Resp { seq: this, result: Err(e) }).is_err() {
                        break 'conn;
                    }
                }
            }
        }
        if !open {
            break;
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                decoder.finish(&mut events);
                open = false;
            }
            Ok(n) => decoder.feed(&buf[..n], &mut events),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // the timeout reaps *idle* connections only: while
                // responses are still owed (issued > written, and the
                // writer is alive — written becomes MAX when it exits),
                // keep waiting; partial frame bytes stay in the decoder
                if progress.issued.load(Ordering::Acquire)
                    > progress.written.load(Ordering::Acquire)
                {
                    continue;
                }
                crate::debug!("connection {peer}: idle past read timeout, closing");
                break;
            }
            Err(_) => break,
        }
    }
    // tell the writer exactly how many responses to expect, then let it
    // flush whatever is still decoding
    let _ = w_tx.send(WriterMsg::Done { next_seq: seq });
    crate::debug!("connection {peer}: reader closed after {seq} requests");
}

/// One reorder-buffer entry: token frames buffered for a not-yet-current
/// request, plus its terminal response once the scheduler produced it —
/// either a decode result to format, or a pre-rendered raw body
/// (health-check responses bypass the protocol formatter but still ride
/// the reorder queue so they answer in request order).
#[derive(Default)]
struct PendingResp {
    frames: Vec<(usize, i32)>,
    result: Option<std::result::Result<Decoded, ServeError>>,
    raw: Option<String>,
}

/// How a connection's writer frames responses on the wire.
enum WireKind {
    /// one JSON line per frame/response (raw TCP)
    Jsonl,
    /// HTTP/1.1 responses; streaming requests become SSE event streams
    Http,
}

/// The write half of a connection: owns the socket clone and the
/// response framing. Starts in JSONL mode; [`WriterMsg::Http`] switches
/// it before the first byte is ever written (reader-queue order
/// guarantees that).
struct ConnWriter {
    stream: TcpStream,
    tok: Arc<Tokenizer>,
    wire: WireKind,
    /// seqs declared streaming by the HTTP reader ([`WriterMsg::Mode`])
    sse: HashSet<u64>,
    /// the SSE preamble for the current response has been written
    sse_open: bool,
}

impl ConnWriter {
    /// A JSONL writer (every connection starts here).
    fn jsonl(stream: TcpStream, tok: Arc<Tokenizer>) -> ConnWriter {
        ConnWriter { stream, tok, wire: WireKind::Jsonl, sse: HashSet::new(), sse_open: false }
    }

    /// Write one streaming token frame. Frames only reach the writer
    /// for the *current* request, so in HTTP mode this is always part
    /// of the current SSE stream (opening it on the first frame).
    fn write_frame(&mut self, index: usize, token: i32) -> std::io::Result<()> {
        let body = format_frame(index, token, &self.tok);
        let mut out = Vec::with_capacity(body.len() + 8);
        match self.wire {
            WireKind::Jsonl => LineEncoder.encode(&body, &mut out),
            WireKind::Http => {
                if !self.sse_open {
                    out.extend_from_slice(http::SSE_PREAMBLE);
                    self.sse_open = true;
                }
                SseEncoder.encode(&body, &mut out);
            }
        }
        self.stream.write_all(&out)?;
        self.stream.flush()
    }

    /// Write a pre-rendered response verbatim (health-check endpoints:
    /// the body is already a complete HTTP response).
    fn write_raw(&mut self, body: &str) -> std::io::Result<()> {
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()
    }

    /// Write request `seq`'s terminal response. Returns `false` when
    /// the connection must close afterwards (an SSE stream ends with
    /// `Connection: close`, mirroring the preamble's promise).
    fn write_terminal(
        &mut self,
        seq: u64,
        result: &std::result::Result<Decoded, ServeError>,
    ) -> std::io::Result<bool> {
        let body = format_response(result, &self.tok);
        match self.wire {
            WireKind::Jsonl => {
                let mut out = Vec::with_capacity(body.len() + 1);
                LineEncoder.encode(&body, &mut out);
                self.stream.write_all(&out)?;
                self.stream.flush()?;
                Ok(true)
            }
            WireKind::Http => {
                let streaming = self.sse.remove(&seq);
                if streaming && (self.sse_open || result.is_ok()) {
                    // terminal SSE event, then close (a pre-stream
                    // error instead falls through to a plain status
                    // response and keeps the connection alive)
                    let mut out = Vec::new();
                    if !self.sse_open {
                        out.extend_from_slice(http::SSE_PREAMBLE);
                        self.sse_open = true;
                    }
                    SseEncoder.encode(&body, &mut out);
                    self.stream.write_all(&out)?;
                    self.stream.flush()?;
                    let _ = self.stream.shutdown(Shutdown::Both);
                    Ok(false)
                } else {
                    let resp = http::terminal_response(result, &body);
                    self.stream.write_all(&resp)?;
                    self.stream.flush()?;
                    Ok(true)
                }
            }
        }
    }
}

/// Per-connection writer: responses arrive in completion order (the
/// scheduler retires short requests before long ones); a reorder buffer
/// restores per-connection request order before writing. Streaming
/// frames for the *current* request pass straight through; frames for a
/// later request buffer in its reorder entry and flush the moment it
/// becomes current — so frames stay in index order and always precede
/// their terminal response, while responses stay in request order. The
/// buffer is bounded by `max_pending` entries: a connection that racks
/// up that many buffered requests behind a missing sequence number (e.g.
/// error spam pipelined behind a long decode) is closed instead of
/// growing it.
fn writer_loop(
    mut w: ConnWriter,
    conn: u64,
    rx: Receiver<WriterMsg>,
    registry: &Registry,
    progress: &ConnProgress,
    max_pending: usize,
) {
    let mut pending: BTreeMap<u64, PendingResp> = BTreeMap::new();
    let mut next = 0u64;
    let mut end: Option<u64> = None;
    'conn: loop {
        if let Some(e) = end {
            if next >= e {
                break;
            }
        }
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        match msg {
            WriterMsg::Done { next_seq } => end = Some(next_seq),
            WriterMsg::Http => w.wire = WireKind::Http,
            WriterMsg::Mode { seq, sse } => {
                if sse {
                    w.sse.insert(seq);
                }
            }
            WriterMsg::Frame { seq, index, token } => {
                if seq == next {
                    // current request: stream the frame immediately (any
                    // earlier frames for `next` were flushed when it
                    // became current, so index order is preserved)
                    if w.write_frame(index, token).is_err() {
                        break 'conn;
                    }
                } else {
                    pending.entry(seq).or_default().frames.push((index, token));
                }
            }
            WriterMsg::Resp { seq, result } => {
                pending.entry(seq).or_default().result = Some(result);
            }
            WriterMsg::Raw { seq, body } => {
                pending.entry(seq).or_default().raw = Some(body);
            }
        }
        // drain everything that is now writable, flushing each entry's
        // buffered frames before its terminal response
        while let Some(entry) = pending.get_mut(&next) {
            for (index, token) in std::mem::take(&mut entry.frames) {
                if w.write_frame(index, token).is_err() {
                    break 'conn;
                }
            }
            if let Some(body) = entry.raw.take() {
                // pre-rendered terminal (health endpoint): verbatim
                pending.remove(&next);
                if w.write_raw(&body).is_err() {
                    break 'conn;
                }
                next += 1;
                progress.written.store(next, Ordering::Release);
                continue;
            }
            let Some(result) = entry.result.take() else {
                // frames flushed but the request is still
                // decoding: it is now current, future frames
                // pass straight through
                break;
            };
            pending.remove(&next);
            let keep = match w.write_terminal(next, &result) {
                Ok(keep) => keep,
                Err(_) => break 'conn,
            };
            next += 1;
            progress.written.store(next, Ordering::Release);
            if !keep {
                // the SSE contract closes the connection after
                // the stream's terminal event
                break 'conn;
            }
        }
        if pending.len() > max_pending.max(1) {
            crate::warn!(
                "connection {conn}: {} requests buffered out of order; closing",
                pending.len()
            );
            break;
        }
    }
    // the MAX sentinel stops the reader from waiting on us; unregistering
    // cancels our remaining slots at the next step boundary and closes
    // the channel so scheduler sends fail fast
    progress.written.store(u64::MAX, Ordering::Release);
    registry.unregister(conn);
    crate::debug!("connection {conn}: writer closed after {next} responses");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    fn opts() -> ServeOptions {
        ServeOptions { max_tokens_cap: 32, max_line_bytes: 256, ..ServeOptions::default() }
    }

    #[test]
    fn parse_valid_prompt_and_tokens() {
        let tok = Tokenizer::new(64);
        let o = opts();
        let text = tok.decode(&[3, 9, 2]);
        let r = parse_request(&format!(r#"{{"prompt":"{text}","max_tokens":4}}"#), &tok, 64, &o)
            .unwrap();
        assert_eq!(r.prompt, vec![3, 9, 2]);
        assert_eq!(r.max_tokens, 4);
        // a bare v1 line is greedy, non-streaming
        assert!(r.params.is_greedy());
        assert!(!r.stream);
        let r = parse_request(r#"{"tokens":[0,5,63]}"#, &tok, 64, &o).unwrap();
        assert_eq!(r.prompt, vec![0, 5, 63]);
        assert_eq!(r.max_tokens, 16); // default
    }

    #[test]
    fn parse_clamps_max_tokens_to_cap() {
        let tok = Tokenizer::new(64);
        let r =
            parse_request(r#"{"tokens":[1],"max_tokens":100000}"#, &tok, 64, &opts()).unwrap();
        assert_eq!(r.max_tokens, 32);
    }

    #[test]
    fn parse_v2_params_and_stream() {
        let tok = Tokenizer::new(64);
        let o = opts();
        let line = r#"{"tokens":[1,2],"max_tokens":4,"stream":true,
            "params":{"temperature":0.8,"top_k":5,"top_p":0.9,
                      "repetition_penalty":1.25,"seed":7,
                      "stop_tokens":[3]}}"#;
        let r = parse_request(line, &tok, 64, &o).unwrap();
        assert!(r.stream);
        assert_eq!(
            r.params,
            GenParams {
                temperature: 0.8,
                top_k: 5,
                top_p: 0.9,
                repetition_penalty: 1.25,
                seed: 7,
                stop_tokens: vec![3],
                ..GenParams::default()
            }
        );
        // text stop sequences are tokenized server-side
        let stop_text = tok.decode(&[4, 5]);
        let line = format!(r#"{{"tokens":[1],"params":{{"stop":["{stop_text}"]}}}}"#);
        let r = parse_request(&line, &tok, 64, &o).unwrap();
        assert_eq!(r.params.stop_sequences, vec![vec![4, 5]]);
        // params omitted entirely → server defaults flow in
        let with_defaults = ServeOptions {
            defaults: GenParams { temperature: 0.5, seed: 3, ..GenParams::default() },
            ..opts()
        };
        let r = parse_request(r#"{"tokens":[1]}"#, &tok, 64, &with_defaults).unwrap();
        assert_eq!(r.params.temperature, 0.5);
        assert_eq!(r.params.seed, 3);
        // ... but an explicit params object is self-contained: an empty
        // one is the greedy opt-out on a sampling-defaults server
        let r = parse_request(r#"{"tokens":[1],"params":{}}"#, &tok, 64, &with_defaults).unwrap();
        assert!(r.params.is_greedy());
        assert_eq!(r.params, GenParams::default());
    }

    #[test]
    fn parse_rejects_bad_params_with_codes() {
        let tok = Tokenizer::new(64);
        let o = opts();
        let code = |params: &str| {
            let line = format!(r#"{{"tokens":[1],"params":{params}}}"#);
            parse_request(&line, &tok, 64, &o).unwrap_err().code
        };
        // non-positive / non-finite temperature (1e999 parses to +inf)
        assert_eq!(code(r#"{"temperature":0}"#), "bad_params");
        assert_eq!(code(r#"{"temperature":-1}"#), "bad_params");
        assert_eq!(code(r#"{"temperature":1e999}"#), "bad_params");
        assert_eq!(code(r#"{"temperature":"hot"}"#), "bad_params");
        // top_p outside (0, 1]
        assert_eq!(code(r#"{"top_p":0}"#), "bad_params");
        assert_eq!(code(r#"{"top_p":1.5}"#), "bad_params");
        // top_k == 0 (omit it to keep the full vocabulary)
        assert_eq!(code(r#"{"top_k":0}"#), "bad_params");
        assert_eq!(code(r#"{"top_k":2.5}"#), "bad_params");
        // shaping knobs without temperature would be silently ignored by
        // greedy selection — rejected rather than carried
        assert_eq!(code(r#"{"top_k":5}"#), "bad_params");
        assert_eq!(code(r#"{"top_p":0.9}"#), "bad_params");
        assert_eq!(code(r#"{"repetition_penalty":1.5}"#), "bad_params");
        assert_eq!(code(r#"{"repetition_penalty":0}"#), "bad_params");
        assert_eq!(code(r#"{"seed":-1}"#), "bad_params");
        // oversized / invalid stop lists
        let many: Vec<String> = (0..20).map(|i| i.to_string()).collect();
        assert_eq!(code(&format!(r#"{{"stop_tokens":[{}]}}"#, many.join(","))), "bad_params");
        assert_eq!(code(r#"{"stop_tokens":[99]}"#), "bad_params"); // out of vocab
        let spam: Vec<String> = (0..9).map(|_| r#""ba""#.to_string()).collect();
        assert_eq!(code(&format!(r#"{{"stop":[{}]}}"#, spam.join(","))), "bad_params");
        assert_eq!(code(r#"{"stop":[""]}"#), "bad_params");
        // unknown keys are rejected, not silently ignored
        assert_eq!(code(r#"{"temprature":0.8}"#), "bad_params");
        // params must be an object; stream must be a boolean
        assert_eq!(
            parse_request(r#"{"tokens":[1],"params":3}"#, &tok, 64, &o).unwrap_err().code,
            "bad_params"
        );
        assert_eq!(
            parse_request(r#"{"tokens":[1],"stream":"yes"}"#, &tok, 64, &o).unwrap_err().code,
            "bad_request"
        );
    }

    #[test]
    fn parse_model_field_validates_against_hosted_names() {
        let tok = Tokenizer::new(64);
        let hosted = ServeOptions { models: vec!["base".into(), "alt".into()], ..opts() };
        let r = parse_request(r#"{"tokens":[1],"model":"alt"}"#, &tok, 64, &hosted).unwrap();
        assert_eq!(r.model.as_deref(), Some("alt"));
        // no model field → default routing
        let r = parse_request(r#"{"tokens":[1]}"#, &tok, 64, &hosted).unwrap();
        assert_eq!(r.model, None);
        // unknown name → structured unknown_model naming the hosted set
        let e = parse_request(r#"{"tokens":[1],"model":"nope"}"#, &tok, 64, &hosted).unwrap_err();
        assert_eq!(e.code, "unknown_model");
        assert!(e.message.contains("base"), "message should list hosted models: {e:?}");
        // any model name on a single-model server is unknown
        let e = parse_request(r#"{"tokens":[1],"model":"base"}"#, &tok, 64, &opts()).unwrap_err();
        assert_eq!(e.code, "unknown_model");
        // wrong type is a bad_request, not a routing miss
        let e = parse_request(r#"{"tokens":[1],"model":3}"#, &tok, 64, &hosted).unwrap_err();
        assert_eq!(e.code, "bad_request");
    }

    #[test]
    fn parse_deadline_field_and_server_default() {
        let tok = Tokenizer::new(64);
        let o = opts();
        let r = parse_request(r#"{"tokens":[1],"deadline_ms":250}"#, &tok, 64, &o).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        // absent field, no server default → no deadline
        let r = parse_request(r#"{"tokens":[1]}"#, &tok, 64, &o).unwrap();
        assert_eq!(r.deadline_ms, None);
        // absent field inherits the server default; an explicit field wins
        let with_default = ServeOptions { default_deadline_ms: 400, ..opts() };
        let r = parse_request(r#"{"tokens":[1]}"#, &tok, 64, &with_default).unwrap();
        assert_eq!(r.deadline_ms, Some(400));
        let r =
            parse_request(r#"{"tokens":[1],"deadline_ms":90}"#, &tok, 64, &with_default).unwrap();
        assert_eq!(r.deadline_ms, Some(90));
        // zero / negative / fractional / non-numeric are rejected
        for bad in [
            r#"{"tokens":[1],"deadline_ms":0}"#,
            r#"{"tokens":[1],"deadline_ms":-5}"#,
            r#"{"tokens":[1],"deadline_ms":1.5}"#,
            r#"{"tokens":[1],"deadline_ms":"soon"}"#,
        ] {
            let e = parse_request(bad, &tok, 64, &with_default).unwrap_err();
            assert_eq!(e.code, "bad_request", "line {bad} should be rejected");
        }
    }

    #[test]
    fn error_response_carries_retry_after_hint() {
        let tok = Tokenizer::new(64);
        let err = format_response(
            &Err(ServeError::new("overloaded", "queue full").with_retry_after(120)),
            &tok,
        );
        let v = Json::parse(&err).unwrap();
        let e = v.req("error").unwrap();
        assert_eq!(e.req("code").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(e.req("retry_after_ms").unwrap().as_usize().unwrap(), 120);
        // the hint is absent unless the rejection set one
        let err = format_response(&Err(ServeError::new("bad_json", "nope")), &tok);
        let v = Json::parse(&err).unwrap();
        assert!(v.req("error").unwrap().get("retry_after_ms").is_none());
    }

    #[test]
    fn parse_cancel_accepts_only_strict_control_frames() {
        assert_eq!(parse_cancel(r#"{"cancel":3}"#), Some(3));
        assert_eq!(parse_cancel(r#"{"cancel":0}"#), Some(0));
        // anything that is not exactly a one-key integer cancel object
        // must flow on to request parsing instead
        assert_eq!(parse_cancel(r#"{"cancel":3,"x":1}"#), None);
        assert_eq!(parse_cancel(r#"{"cancel":-1}"#), None);
        assert_eq!(parse_cancel(r#"{"cancel":1.5}"#), None);
        assert_eq!(parse_cancel(r#"{"cancel":"now"}"#), None);
        assert_eq!(parse_cancel(r#"{"tokens":[1]}"#), None);
        assert_eq!(parse_cancel("[3]"), None);
        assert_eq!(parse_cancel("not json"), None);
    }

    #[test]
    fn frame_shape() {
        let tok = Tokenizer::new(64);
        let f = Json::parse(&format_frame(2, 7, &tok)).unwrap();
        assert_eq!(f.req("token").unwrap().as_usize().unwrap(), 7);
        assert_eq!(f.req("index").unwrap().as_usize().unwrap(), 2);
        assert_eq!(f.req("text").unwrap().as_str().unwrap(), tok.decode(&[7]));
    }

    #[test]
    fn parse_rejects_bad_requests_with_codes() {
        let tok = Tokenizer::new(64);
        let o = opts();
        let code = |line: &str| parse_request(line, &tok, 64, &o).unwrap_err().code;
        assert_eq!(code("not json at all"), "bad_json");
        assert_eq!(code(r#"{"nothing":1}"#), "bad_request");
        assert_eq!(code(r#"{"tokens":"nope"}"#), "bad_request");
        assert_eq!(code(r#"{"tokens":[1],"max_tokens":-3}"#), "bad_request");
        assert_eq!(code(r#"{"tokens":[1],"max_tokens":1.5}"#), "bad_request");
        // out-of-vocab / negative / fractional ids are rejected, not truncated
        assert_eq!(code(r#"{"tokens":[64]}"#), "bad_token");
        assert_eq!(code(r#"{"tokens":[-1]}"#), "bad_token");
        assert_eq!(code(r#"{"tokens":[1.5]}"#), "bad_token");
        assert_eq!(code(r#"{"tokens":[null]}"#), "bad_token");
        assert_eq!(code(r#"{"tokens":[]}"#), "empty_prompt");
        assert_eq!(code(r#"{"prompt":""}"#), "empty_prompt");
        let long = format!(r#"{{"prompt":"{}"}}"#, "a".repeat(300));
        assert_eq!(code(&long), "oversized");
    }

    #[test]
    fn response_shapes() {
        let tok = Tokenizer::new(64);
        let ok = format_response(
            &Ok(Decoded { tokens: vec![1, 2], latency_ms: 1.5, queue_ms: 0.25 }),
            &tok,
        );
        let v = Json::parse(&ok).unwrap();
        assert_eq!(v.req("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.req("text").unwrap().as_str().unwrap(), tok.decode(&[1, 2]));
        assert!(v.req("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        let err = format_response(&Err(ServeError::new("bad_token", "nope")), &tok);
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.req("error").unwrap().req("code").unwrap().as_str().unwrap(), "bad_token");
    }

    #[test]
    fn writer_pending_cap_closes_connection() {
        use std::sync::mpsc::sync_channel;
        // real loopback socket pair so writer_loop has something to write to
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_stream, _) = listener.accept().unwrap();
        let registry = Registry::default();
        let (tx, rx) = sync_channel(16);
        registry.register(1, tx.clone(), None);
        let tok = Arc::new(Tokenizer::new(8));
        let progress = ConnProgress::default();
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                writer_loop(ConnWriter::jsonl(server_stream, tok), 1, rx, &registry, &progress, 2)
            });
            // responses 1..=4 arrive while seq 0 is still decoding: the
            // reorder buffer hits the cap (2) and the writer must close
            // the connection instead of buffering without bound
            for seq in [1u64, 2, 3, 4] {
                let _ = tx.send(WriterMsg::Resp {
                    seq,
                    result: Err(ServeError::new("bad_json", "spam")),
                });
            }
            h.join().unwrap();
        });
        assert!(!registry.contains(1));
        // the exit sentinel stops the reader from waiting on this writer
        assert_eq!(progress.written.load(Ordering::Acquire), u64::MAX);
        drop(client);
    }

    #[test]
    fn writer_buffers_frames_for_later_requests() {
        use std::sync::mpsc::sync_channel;
        // frames of request 1 arrive while request 0 is still decoding:
        // they must buffer and flush — in order, before request 1's
        // terminal response — once request 0's response is written
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_stream, _) = listener.accept().unwrap();
        let registry = Registry::default();
        let (tx, rx) = sync_channel(16);
        registry.register(1, tx.clone(), None);
        let tok = Arc::new(Tokenizer::new(16));
        let progress = ConnProgress::default();
        let lines = std::thread::scope(|s| {
            let h = s.spawn(|| {
                writer_loop(ConnWriter::jsonl(server_stream, tok), 1, rx, &registry, &progress, 8)
            });
            let ok = |tokens: Vec<i32>| {
                Ok(Decoded { tokens, latency_ms: 1.0, queue_ms: 0.5 })
            };
            tx.send(WriterMsg::Frame { seq: 1, index: 0, token: 4 }).unwrap();
            tx.send(WriterMsg::Frame { seq: 1, index: 1, token: 5 }).unwrap();
            tx.send(WriterMsg::Resp { seq: 1, result: ok(vec![4, 5]) }).unwrap();
            tx.send(WriterMsg::Resp { seq: 0, result: ok(vec![9]) }).unwrap();
            tx.send(WriterMsg::Done { next_seq: 2 }).unwrap();
            let mut reader = BufReader::new(client.try_clone().unwrap());
            let mut lines = vec![];
            for _ in 0..4 {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                lines.push(Json::parse(&line).unwrap());
            }
            h.join().unwrap();
            lines
        });
        // request 0's response, then request 1's frames, then its response
        assert_eq!(lines[0].req("tokens").unwrap().usize_arr().unwrap(), vec![9]);
        assert_eq!(lines[1].req("token").unwrap().as_usize().unwrap(), 4);
        assert_eq!(lines[2].req("token").unwrap().as_usize().unwrap(), 5);
        assert_eq!(lines[3].req("tokens").unwrap().usize_arr().unwrap(), vec![4, 5]);
        drop(client);
    }

    #[test]
    fn transport_sniffing() {
        // full method token + space → HTTP
        assert_eq!(sniff_decision(b"POST /v1/generate HTTP/1.1\r\n"), Some(true));
        assert_eq!(sniff_decision(b"GET / HTTP/1.1\r\n"), Some(true));
        // JSON and garbage → JSONL
        assert_eq!(sniff_decision(b"{\"prompt\":\"hi\"}"), Some(false));
        assert_eq!(sniff_decision(b"not json at all"), Some(false));
        // ambiguous prefixes of a method token → keep reading
        assert_eq!(sniff_decision(b"PO"), None);
        assert_eq!(sniff_decision(b"G"), None);
        assert_eq!(sniff_decision(b""), None);
        // a prefix that can no longer become a method decides JSONL
        assert_eq!(sniff_decision(b"POTATO"), Some(false));
    }
}
