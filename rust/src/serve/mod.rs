//! Minimal inference server over a quantized model.
//!
//! Line-delimited JSON over TCP (the offline image has no HTTP stack):
//! each request line is `{"prompt": "text...", "max_tokens": N}` (or
//! `"tokens": [...]`), each response line is
//! `{"tokens": [...], "text": "...", "latency_ms": x}`.
//!
//! Decoding is greedy through the `lm_logits_pos_aq` artifact (W4A4 —
//! the deployed NVFP4 path). The PJRT client is not Send, so the server
//! is a single accept loop; concurrency comes from XLA's intra-op pool.
//! Throughput numbers for EXPERIMENTS.md come from `bench_pipeline`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{anyhow, Result};

use crate::data::Tokenizer;
use crate::runtime::{Runtime, Value};
use crate::train::{ParamSource, QuantParamStore};
use crate::util::json::Json;

pub struct Generator<'r> {
    pub rt: &'r Runtime,
    /// quantized layers held packed (~4.5 bits/weight); dequantized
    /// lazily on first forward and memoized for the process lifetime
    pub params: QuantParamStore,
    pub tokenizer: Tokenizer,
}

impl<'r> Generator<'r> {
    pub fn new(rt: &'r Runtime, params: QuantParamStore) -> Generator<'r> {
        let tokenizer = Tokenizer::new(rt.config().vocab);
        let packed = params.packed_payload_bytes();
        if packed > 0 {
            let dense = params.packed_dense_bytes();
            crate::info!(
                "model payload: {} quantized layers packed at {:.2} MiB ({:.2} MiB as fp32, \
                 {:.1}x smaller); dense copies are decoded lazily per layer and memoized",
                params.n_packed(),
                packed as f64 / (1 << 20) as f64,
                dense as f64 / (1 << 20) as f64,
                dense as f64 / packed as f64
            );
        }
        Generator { rt, params, tokenizer }
    }

    /// Greedy-decode `max_tokens` continuations of `prompt`.
    pub fn generate(&self, prompt: &[i32], max_tokens: usize) -> Result<Vec<i32>> {
        let t = self.rt.config().seq_len;
        let vocab = self.rt.config().vocab as i32;
        let mut buf = vec![0i32; t];
        let plen = prompt.len().min(t);
        buf[..plen].copy_from_slice(&prompt[prompt.len() - plen..]);
        let mut pos = plen.saturating_sub(1);
        let mut out = Vec::with_capacity(max_tokens);

        let mut args = self.params.values()?;
        args.push(Value::I32(buf.clone(), vec![1, t]));
        args.push(Value::scalar_i32(pos as i32));
        let tok_idx = args.len() - 2;
        let pos_idx = args.len() - 1;

        for _ in 0..max_tokens {
            args[tok_idx] = Value::I32(buf.clone(), vec![1, t]);
            args[pos_idx] = Value::scalar_i32(pos as i32);
            let outv = self.rt.exec("lm_logits_pos_aq", &args)?;
            let logits = outv[0].as_tensor()?;
            let next = logits
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
                .min(vocab - 1);
            out.push(next);
            if pos + 1 < t {
                pos += 1;
                buf[pos] = next;
            } else {
                // slide the window left by one
                buf.copy_within(1..t, 0);
                buf[t - 1] = next;
            }
        }
        Ok(out)
    }

    fn handle_line(&self, line: &str) -> Result<String> {
        let req = Json::parse(line)?;
        let max_tokens = req.get("max_tokens").and_then(|v| v.as_usize().ok()).unwrap_or(16);
        let prompt: Vec<i32> = if let Some(toks) = req.get("tokens") {
            toks.as_arr()?
                .iter()
                .map(|t| Ok(t.as_f64()? as i32))
                .collect::<Result<Vec<_>>>()?
        } else if let Some(text) = req.get("prompt") {
            self.tokenizer.encode(text.as_str()?)
        } else {
            return Err(anyhow!("request needs 'prompt' or 'tokens'"));
        };
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let t0 = std::time::Instant::now();
        let tokens = self.generate(&prompt, max_tokens)?;
        let latency = t0.elapsed().as_secs_f64() * 1e3;
        Ok(Json::obj(vec![
            (
                "tokens",
                Json::Arr(tokens.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("text", Json::str(self.tokenizer.decode(&tokens))),
            ("latency_ms", Json::Num(latency)),
        ])
        .to_string())
    }

    fn handle_conn(&self, stream: TcpStream) {
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) if !l.trim().is_empty() => l,
                Ok(_) => continue,
                Err(_) => break,
            };
            let resp = match self.handle_line(&line) {
                Ok(r) => r,
                Err(e) => Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
            };
            if writer.write_all(resp.as_bytes()).is_err()
                || writer.write_all(b"\n").is_err()
            {
                break;
            }
        }
        crate::debug!("connection {peer} closed");
    }

    /// Serve forever (or until `max_conns` connections, for tests).
    pub fn serve(&self, addr: &str, max_conns: Option<usize>) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        crate::info!("serving on {} (model {})", listener.local_addr()?, self.rt.config().name);
        let mut served = 0usize;
        for stream in listener.incoming() {
            match stream {
                Ok(s) => self.handle_conn(s),
                Err(e) => crate::warn!("accept: {e}"),
            }
            served += 1;
            if let Some(n) = max_conns {
                if served >= n {
                    break;
                }
            }
        }
        Ok(())
    }
}
