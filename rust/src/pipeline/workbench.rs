//! Workbench: shared experiment context for the table harnesses and
//! examples — runtime + pretrained checkpoint (cached on disk) +
//! calibration, with evaluation helpers.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::calib::{capture, Calibration};
use crate::config::PipelineConfig;
use crate::data::{tasks::TaskKind, tasks::TaskSuite, Corpus};
use crate::eval::{self, FwdMode, LmMetrics};
use crate::runtime::Runtime;
use crate::train::{pretrain, ParamStore};

use super::methods::{quantize, Method, QuantOutcome};

/// Shared experiment context: runtime + checkpoint + calibration +
/// corpora, with per-method quantization memoization.
pub struct Workbench {
    /// the artifact runtime
    pub rt: Runtime,
    /// pipeline hyperparameters
    pub cfg: PipelineConfig,
    /// the frozen full-precision checkpoint
    pub fp: ParamStore,
    /// captured calibration activations
    pub calib: Calibration,
    /// the structured corpus (`synthwiki`)
    pub wiki: Corpus,
    /// the noisy corpus (`synthc4`)
    pub c4: Corpus,
    /// memoized quantization outcomes per method (tables reuse methods
    /// across metrics; FAAR+2FA costs minutes — never run it twice)
    cache: std::cell::RefCell<std::collections::HashMap<String, std::rc::Rc<QuantOutcome>>>,
}

impl Workbench {
    /// Open a workbench: loads the cached pretrained checkpoint if one
    /// exists for (model, seed, steps), otherwise pretrains and caches.
    pub fn open(cfg: PipelineConfig) -> Result<Workbench> {
        let rt = Runtime::load(Path::new(&cfg.artifact_root), &cfg.model)?;
        let vocab = rt.config().vocab;
        let wiki = Corpus::by_name("synthwiki", vocab).unwrap();
        let c4 = Corpus::by_name("synthc4", vocab).unwrap();

        let ckpt = Self::ckpt_path(&cfg);
        let fp = if ckpt.exists() {
            crate::info!("loading cached checkpoint {}", ckpt.display());
            let p = ParamStore::load(&ckpt)?;
            p.check_layout(&rt.manifest)?;
            p
        } else {
            crate::info!(
                "pretraining {} for {} steps (no cached checkpoint)",
                cfg.model,
                cfg.pretrain_steps
            );
            let init = ParamStore::init(&rt.manifest, cfg.seed);
            let (p, report) = pretrain(
                &rt,
                &[&wiki, &c4],
                init,
                cfg.pretrain_steps,
                cfg.pretrain_lr,
                cfg.pretrain_warmup,
                cfg.seed,
            )?;
            crate::info!(
                "pretrained: loss {:.4}, {:.0} tok/s, {:.1}s",
                report.final_loss,
                report.tokens_per_s,
                report.wall_s
            );
            crate::train::pretrain::save_loss_curve(
                &report,
                &PathBuf::from(&cfg.out_dir).join(format!("pretrain_{}.json", cfg.model)),
            )?;
            p.save(&ckpt)?;
            p
        };

        // calibration on the corpus mixture (mirrors the paper's general-text calibration set)
        let calib = capture(&rt, &[&wiki, &c4], &fp, cfg.calib_batches, rt.config().stage1_rows, cfg.seed)?;
        Ok(Workbench {
            rt,
            cfg,
            fp,
            calib,
            wiki,
            c4,
            cache: Default::default(),
        })
    }

    /// Checkpoint path for (model, seed, steps).
    pub fn ckpt_path(cfg: &PipelineConfig) -> PathBuf {
        PathBuf::from(&cfg.out_dir).join(format!(
            "models/{}_s{}_p{}.fwts",
            cfg.model, cfg.seed, cfg.pretrain_steps
        ))
    }

    /// Quantize with a method, memoized per method name.
    pub fn quantize(&self, method: Method) -> Result<std::rc::Rc<QuantOutcome>> {
        if let Some(out) = self.cache.borrow().get(&method.name()) {
            return Ok(out.clone());
        }
        let out = std::rc::Rc::new(self.quantize_with(method, &self.cfg)?);
        self.cache.borrow_mut().insert(method.name(), out.clone());
        Ok(out)
    }

    /// Quantize with explicit config (no memoization).
    pub fn quantize_with(&self, method: Method, cfg: &PipelineConfig) -> Result<QuantOutcome> {
        quantize(&self.rt, &self.fp, method, cfg, Some(&self.calib), Some(&[&self.wiki, &self.c4]))
    }

    /// A corpus by name (`wiki` / `c4`), panicking on unknown names.
    pub fn corpus(&self, name: &str) -> &Corpus {
        match name {
            "synthwiki" | "wiki" => &self.wiki,
            "synthc4" | "c4" => &self.c4,
            other => panic!("unknown corpus '{other}'"),
        }
    }

    fn mode_for(&self, method: Method) -> FwdMode {
        if method.w4a4() && self.cfg.act_quant_eval {
            FwdMode::ActQuant
        } else {
            FwdMode::Fp
        }
    }

    /// PPL + hidden-cosine of a quantized outcome on a corpus.
    pub fn lm_metrics(&self, outcome: &QuantOutcome, corpus: &str) -> Result<LmMetrics> {
        eval::lm_metrics(
            &self.rt,
            &self.fp,
            &outcome.params,
            self.corpus(corpus),
            self.mode_for(outcome.method),
            self.cfg.eval_batches,
            self.cfg.seed,
        )
    }

    /// Perplexity of a quantized outcome on one corpus.
    pub fn ppl(&self, outcome: &QuantOutcome, corpus: &str) -> Result<f64> {
        eval::perplexity(
            &self.rt,
            &outcome.params,
            self.corpus(corpus),
            self.mode_for(outcome.method),
            self.cfg.eval_batches,
            self.cfg.seed,
        )
    }

    /// Zero-shot accuracy (%) on one probe suite.
    pub fn task_accuracy(
        &self,
        outcome: &QuantOutcome,
        kind: TaskKind,
        n_probes: usize,
    ) -> Result<f64> {
        let prompt_len = (self.rt.config().seq_len / 2).min(24);
        let suite =
            TaskSuite::generate(kind, &self.wiki, n_probes, prompt_len, self.cfg.seed ^ 0x7A5);
        Ok(eval::task_accuracy(&self.rt, &outcome.params, &suite, self.mode_for(outcome.method))?
            * 100.0)
    }
}
