//! The quantization coordinator — the paper's pipeline (Figure 1).
//!
//! * [`methods`] — the method registry: every row of Tables 3/4/5 (RTN,
//!   lower/upper/stochastic, 4/6, strong baseline, GPTQ, MR-GPTQ,
//!   GPTQ+4/6, FAAR, FAAR+2FA) maps to one [`methods::Method`].
//! * [`faar`] — the learnable part: Stage-1 layer-wise adaptive rounding
//!   and Stage-2 full-model alignment, driven through the AOT step graphs
//!   with rust owning the β/λ schedules, the job order and the state.
//! * [`harden`] — continuous V → binary decisions → packed
//!   `QuantTensor`s (the canonical quantized representation; the eval
//!   graphs dequantize lazily through `train::QuantParamStore`).

pub mod faar;
pub mod harden;
pub mod methods;
pub mod workbench;

pub use faar::{stage1, stage2, FaarState};
pub use harden::{harden_to_params, load_packed, pack_model};
pub use methods::{quantize, Method, QuantOutcome};
pub use workbench::Workbench;
