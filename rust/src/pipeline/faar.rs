//! FAAR + 2FA: the learnable rounding optimization (paper §3.4–3.5).
//!
//! Stage 1 runs one job per (quantized linear, layer): the AOT
//! `stage1_step_<K>x<N>` graph performs soft-quant (Pallas kernel) →
//! reconstruction MSE + rounding regularizer → Adam-on-V → clip, all
//! fused; rust supplies the captured activations, the β annealing
//! schedule (log-linear 5→50), the λ_round warmup, and collects the loss
//! trajectory.
//!
//! Stage 2 assembles the full quantized model (all 7 V stacks at once)
//! and aligns it to the frozen fp model with KL(logits) + MSE(last
//! hidden) through `stage2_step`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::calib::{fit_rows, Calibration};
use crate::config::PipelineConfig;
use crate::data::{batcher::Split, Batcher, Corpus};
use crate::formats::codec::Prepared;
use crate::quant::scaling;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;
use crate::train::ParamStore;

/// Learned rounding state for all quantized linears.
pub struct FaarState {
    /// qlinear name → prepared context (stacked [L, K, N])
    pub prepared: BTreeMap<String, Prepared>,
    /// qlinear name → continuous rounding variables (stacked)
    pub v: BTreeMap<String, Tensor>,
    /// stage-1 per-job final losses, keyed "name[layer]"
    pub stage1_losses: BTreeMap<String, f64>,
    /// stage-2 loss trajectory (loss, kl, mse)
    pub stage2_log: Vec<(f64, f64, f64)>,
}

/// Prepare the interval context for every quantized linear under the
/// configured scale method and initialize V = v_init.
pub fn prepare_all(rt: &Runtime, params: &ParamStore, cfg: &PipelineConfig) -> Result<FaarState> {
    let mut prepared = BTreeMap::new();
    let mut v = BTreeMap::new();
    for q in &rt.manifest.qlinears {
        let w = params.get(&q.name)?;
        let p = scaling::prepare_with_method(w, cfg.scale_method);
        v.insert(q.name.clone(), p.v_init.clone());
        prepared.insert(q.name.clone(), p);
    }
    Ok(FaarState { prepared, v, stage1_losses: BTreeMap::new(), stage2_log: vec![] })
}

/// λ_round warmup: linear ramp over the first `frac` of the steps.
fn lam_at(step: usize, total: usize, lam: f32, frac: f32) -> f32 {
    let warm = ((total as f32) * frac).max(1.0);
    lam * ((step as f32 + 1.0) / warm).min(1.0)
}

/// Stage 1: layer-wise adaptive rounding for every (qlinear, layer) job.
pub fn stage1(
    rt: &Runtime,
    params: &ParamStore,
    calib: &Calibration,
    cfg: &PipelineConfig,
    state: &mut FaarState,
) -> Result<()> {
    let model_cfg = rt.config().clone();
    let steps = cfg.stage1_steps;
    if steps == 0 {
        return Ok(());
    }
    for q in rt.manifest.qlinears.clone() {
        let artifact = format!("stage1_step_{}x{}", q.k, q.n);
        let w_stacked = params.get(&q.name)?.clone();
        let p = state.prepared[&q.name].clone();
        let mut v_stacked = state.v[&q.name].clone();
        let cap = calib.set(&q.capture)?;

        for l in 0..model_cfg.n_layers {
            let x = fit_rows(&cap.rows[l], model_cfg.stage1_rows);
            let w = w_stacked.index0(l);
            let lo = p.lower.index0(l);
            let up = p.upper.index0(l);
            let sc = p.scale.index0(l);
            let mut v = v_stacked.index0(l);
            let mut m = Tensor::zeros(&v.shape);
            let mut a = Tensor::zeros(&v.shape);
            let mut last_loss = f64::NAN;

            for step in 0..steps {
                let t = step as f32 / (steps.max(2) - 1) as f32;
                let beta = cfg.beta.at(t);
                let lam = lam_at(step, steps, cfg.lam_round, cfg.lam_warmup_frac);
                let out = rt.exec(
                    &artifact,
                    &[
                        Value::F32(x.clone()),
                        Value::F32(w.clone()),
                        Value::F32(lo.clone()),
                        Value::F32(up.clone()),
                        Value::F32(sc.clone()),
                        Value::F32(v.clone()),
                        Value::F32(m.clone()),
                        Value::F32(a.clone()),
                        Value::scalar_f32(step as f32 + 1.0),
                        Value::scalar_f32(beta),
                        Value::scalar_f32(cfg.stage1_lr),
                        Value::scalar_f32(lam),
                    ],
                )?;
                v = out[0].as_tensor()?.clone();
                m = out[1].as_tensor()?.clone();
                a = out[2].as_tensor()?.clone();
                last_loss = out[3].as_f32_scalar()? as f64;
                if !last_loss.is_finite() {
                    bail!("stage1 diverged: {}[{l}] step {step}", q.name);
                }
            }
            v_stacked.set_index0(l, &v);
            state.stage1_losses.insert(format!("{}[{l}]", q.name), last_loss);
            crate::debug!("stage1 {}[{l}] final loss {last_loss:.3e}", q.name);
        }
        state.v.insert(q.name.clone(), v_stacked);
        crate::info!("stage1 done: {} ({} layers x {} steps)", q.name, model_cfg.n_layers, steps);
    }
    Ok(())
}

/// Stage 2: full-model alignment of all rounding variables jointly.
pub fn stage2(
    rt: &Runtime,
    params: &ParamStore,
    corpora: &[&Corpus],
    cfg: &PipelineConfig,
    state: &mut FaarState,
) -> Result<()> {
    let model_cfg = rt.config().clone();
    let steps = cfg.stage2_steps;
    if steps == 0 {
        return Ok(());
    }
    let spec = rt.manifest.artifact("stage2_step")?.clone();
    // qlinear order = manifest order (matches aot.py's model.QNAMES)
    let qnames: Vec<String> = rt.manifest.qlinears.iter().map(|q| q.name.clone()).collect();
    let nq = qnames.len();

    let mut m: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut a: BTreeMap<String, Tensor> = BTreeMap::new();
    for qn in &qnames {
        m.insert(qn.clone(), Tensor::zeros(&state.v[qn].shape));
        a.insert(qn.clone(), Tensor::zeros(&state.v[qn].shape));
    }

    // stage-2 data stream: calibration split of the corpus mixture,
    // distinct seed space from the capture batches
    let batchers: Vec<Batcher> = corpora
        .iter()
        .map(|c| {
            Batcher::new(c, Split::Calib, model_cfg.stage2_batch, model_cfg.seq_len,
                         cfg.seed ^ 0x5A5A)
        })
        .collect();

    let weights = params.values();
    for step in 0..steps {
        let t = step as f32 / (steps.max(2) - 1) as f32;
        let beta = cfg.beta.at(t);
        let lam = lam_at(step, steps, cfg.lam_round, cfg.lam_warmup_frac);

        let mut args = Vec::with_capacity(spec.inputs.len());
        args.extend(weights.iter().cloned());
        for qn in &qnames {
            let p = &state.prepared[qn];
            args.push(Value::F32(p.lower.clone()));
            args.push(Value::F32(p.upper.clone()));
            args.push(Value::F32(p.scale.clone()));
            args.push(Value::F32(state.v[qn].clone()));
            args.push(Value::F32(m[qn].clone()));
            args.push(Value::F32(a[qn].clone()));
        }
        args.push(batchers[step % batchers.len()].batch_at(step));
        args.push(Value::scalar_f32(step as f32 + 1.0));
        args.push(Value::scalar_f32(beta));
        args.push(Value::scalar_f32(cfg.stage2_lr));
        args.push(Value::scalar_f32(cfg.lam_kl));
        args.push(Value::scalar_f32(lam));
        args.push(Value::scalar_f32(cfg.tau));

        let out = rt.exec("stage2_step", &args)?;
        for (i, qn) in qnames.iter().enumerate() {
            state.v.insert(qn.clone(), out[i].as_tensor()?.clone());
            m.insert(qn.clone(), out[nq + i].as_tensor()?.clone());
            a.insert(qn.clone(), out[2 * nq + i].as_tensor()?.clone());
        }
        let loss = out[3 * nq].as_f32_scalar()? as f64;
        let kl = out[3 * nq + 1].as_f32_scalar()? as f64;
        let mse = out[3 * nq + 2].as_f32_scalar()? as f64;
        if !loss.is_finite() {
            bail!("stage2 diverged at step {step}");
        }
        state.stage2_log.push((loss, kl, mse));
        if step % 25 == 0 || step + 1 == steps {
            crate::info!("stage2 step {step}/{steps} loss {loss:.4e} kl {kl:.3e} mse {mse:.3e}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lam_warmup_ramps() {
        assert!(lam_at(0, 100, 0.01, 0.2) < 0.001);
        assert!((lam_at(19, 100, 0.01, 0.2) - 0.01).abs() < 1e-6);
        assert_eq!(lam_at(50, 100, 0.01, 0.2), 0.01);
        // degenerate: frac 0 → full strength immediately
        assert_eq!(lam_at(0, 100, 0.01, 0.0), 0.01);
    }
}
