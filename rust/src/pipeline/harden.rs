//! Hardening (paper §3.5, eq. 7): continuous V → binary decisions →
//! final packed NVFP4 weights. The result is a [`QuantParamStore`] — the
//! quantized linears stay packed (the deployable form) and dequantize
//! lazily when the PJRT eval graphs ask for f32.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::formats::codec::{FormatCodec, QuantTensor};
use crate::formats::nvfp4::Nvfp4;
use crate::runtime::Runtime;
use crate::train::{ParamStore, QuantParamStore};

use super::faar::FaarState;

/// Encode every quantized linear from its learned decisions into a
/// packed store (non-quantized tensors carried over dense).
pub fn harden_to_params(
    rt: &Runtime,
    params: &ParamStore,
    state: &FaarState,
) -> Result<QuantParamStore> {
    let mut packed = BTreeMap::new();
    for q in &rt.manifest.qlinears {
        let w = params.get(&q.name)?;
        packed.insert(
            q.name.clone(),
            Nvfp4.encode(w, &state.prepared[&q.name], &state.v[&q.name]),
        );
    }
    Ok(QuantParamStore::from_store(params, packed))
}

/// Write every quantized linear of an already-quantized store as a
/// packed `.nvfp4` payload file (no re-encoding — the store's payloads
/// are serialized as-is); returns the total payload bytes (the paper's
/// memory-footprint claim).
pub fn pack_model(rt: &Runtime, store: &QuantParamStore, dir: &Path) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut total = 0usize;
    for q in &rt.manifest.qlinears {
        let packed = store
            .packed(&q.name)
            .ok_or_else(|| anyhow!("qlinear '{}' is not held packed in this store", q.name))?;
        total += packed.payload_bytes();
        let fname = format!("{}.nvfp4", q.name.replace('.', "_"));
        std::fs::write(dir.join(fname), packed.to_bytes())?;
    }
    Ok(total)
}

/// Load a packed model directory into a quantized store — packed stays
/// packed; dequantization happens lazily at eval. This is the serving
/// path's cold-start.
pub fn load_packed(rt: &Runtime, base: &ParamStore, dir: &Path) -> Result<QuantParamStore> {
    let mut packed = BTreeMap::new();
    for q in &rt.manifest.qlinears {
        let fname = format!("{}.nvfp4", q.name.replace('.', "_"));
        let bytes = std::fs::read(dir.join(&fname))?;
        packed.insert(q.name.clone(), QuantTensor::from_bytes(&bytes)?);
    }
    Ok(QuantParamStore::from_store(base, packed))
}
