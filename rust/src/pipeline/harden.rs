//! Hardening (paper §3.5, eq. 7): continuous V → binary decisions →
//! final NVFP4 weights, as both dequantized f32 tensors (for the PJRT
//! eval graphs) and true packed `.nvfp4` payloads (the deployable form).

use std::path::Path;

use anyhow::Result;

use crate::formats::nvfp4::{hard_quant, PackedTensor};
use crate::runtime::Runtime;
use crate::train::ParamStore;

use super::faar::FaarState;

/// Replace every quantized linear in `params` with its hardened NVFP4
/// dequantization. Returns the new store (non-quantized tensors shared).
pub fn harden_to_params(
    rt: &Runtime,
    params: &ParamStore,
    state: &FaarState,
) -> Result<ParamStore> {
    let mut out = params.clone();
    for q in &rt.manifest.qlinears {
        let w = params.get(&q.name)?;
        let p = &state.prepared[&q.name];
        let v = &state.v[&q.name];
        out.set(&q.name, hard_quant(w, p, v))?;
    }
    Ok(out)
}

/// Write every quantized linear as a packed `.nvfp4` file; returns the
/// total payload bytes (the paper's memory-footprint claim).
pub fn pack_model(
    rt: &Runtime,
    params: &ParamStore,
    state: &FaarState,
    dir: &Path,
) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut total = 0usize;
    for q in &rt.manifest.qlinears {
        let w = params.get(&q.name)?;
        let p = &state.prepared[&q.name];
        let v = &state.v[&q.name];
        let packed = PackedTensor::pack(w, p, v);
        total += packed.payload_bytes();
        let fname = format!("{}.nvfp4", q.name.replace('.', "_"));
        std::fs::write(dir.join(fname), packed.to_bytes())?;
    }
    Ok(total)
}

/// Load a packed model directory back into a param store (dequantized) —
/// the serving path's cold-start.
pub fn load_packed(
    rt: &Runtime,
    base: &ParamStore,
    dir: &Path,
) -> Result<ParamStore> {
    let mut out = base.clone();
    for q in &rt.manifest.qlinears {
        let fname = format!("{}.nvfp4", q.name.replace('.', "_"));
        let bytes = std::fs::read(dir.join(&fname))?;
        let packed = PackedTensor::from_bytes(&bytes)?;
        out.set(&q.name, packed.unpack())?;
    }
    Ok(out)
}
