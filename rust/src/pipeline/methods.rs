//! Method registry: every quantization method the paper's tables compare.
//!
//! `quantize` is the single entry point: (frozen fp params, calibration)
//! → a [`QuantParamStore`] holding every quantized linear as a packed
//! [`crate::formats::QuantTensor`], ready for the W4A4 eval graphs
//! (which dequantize lazily, per layer). Every method routes through the
//! [`crate::formats::FormatCodec`] trait, so formats are one axis of the
//! registry rather than copy-pasted code paths — `Method::Mxfp4` is RTN
//! through the MXFP4 codec, the Four-over-Six family is RTN through
//! NVFP4 with a different scale chooser, and so on.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::calib::Calibration;
use crate::config::{PipelineConfig, ScaleMethod};
use crate::data::Corpus;
use crate::formats::codec::{self, codec_for, FormatCodec, FormatKind};
use crate::gptq::{gptq_quantize_stacked_with_scales, GptqOptions};
use crate::quant::rounding::RoundingScheme;
use crate::runtime::Runtime;
use crate::train::{ParamStore, QuantParamStore};

use super::faar::{prepare_all, stage1, stage2, FaarState};
use super::harden::harden_to_params;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Every quantization method the tables compare.
pub enum Method {
    /// unquantized reference
    Bf16,
    /// plain RTN with standard amax/6 scales
    Rtn,
    /// always-lower / always-upper rounding (Table 1)
    Lower,
    /// always-upper rounding (Table 1)
    Upper,
    /// stochastic rounding trial (Table 1)
    Stochastic(u64),
    /// "4/6" adaptive block scaling + RTN (paper baseline [23])
    FourSix,
    /// RTN + MSE-optimal block-scale search (paper "strong baseline")
    StrongBaseline,
    /// RTN through the MXFP4 codec (format-ablation row)
    Mxfp4,
    /// GPTQ on the NVFP4 grid (standard scales)
    Gptq,
    /// MR-GPTQ: GPTQ with per-block scale re-optimization ([22])
    MrGptq,
    /// GPTQ on 4/6 scales (paper "GPTQ+4/6")
    GptqFourSix,
    /// FAAR stage-1 only (ablation Table 6)
    Faar,
    /// full method: FAAR + 2FA
    Faar2fa,
}

impl Method {
    /// Canonical method name (table row labels).
    pub fn name(&self) -> String {
        match self {
            Method::Bf16 => "bf16".into(),
            Method::Rtn => "rtn".into(),
            Method::Lower => "lower".into(),
            Method::Upper => "upper".into(),
            Method::Stochastic(s) => format!("stochastic[{s}]"),
            Method::FourSix => "4/6".into(),
            Method::StrongBaseline => "strong-baseline".into(),
            Method::Mxfp4 => "mxfp4".into(),
            Method::Gptq => "gptq".into(),
            Method::MrGptq => "mr-gptq".into(),
            Method::GptqFourSix => "gptq+4/6".into(),
            Method::Faar => "faar".into(),
            Method::Faar2fa => "faar+2fa".into(),
        }
    }

    /// Parse a method name (accepts the aliases the CLI documents).
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "bf16" | "fp" => Method::Bf16,
            "rtn" => Method::Rtn,
            "lower" => Method::Lower,
            "upper" => Method::Upper,
            "4/6" | "foursix" => Method::FourSix,
            "strong-baseline" | "strong" => Method::StrongBaseline,
            "mxfp4" => Method::Mxfp4,
            "gptq" => Method::Gptq,
            "mr-gptq" | "mrgptq" => Method::MrGptq,
            "gptq+4/6" | "gptq46" => Method::GptqFourSix,
            "faar" => Method::Faar,
            "faar+2fa" | "faar2fa" | "ours" => Method::Faar2fa,
            _ => {
                if let Some(seed) = s.strip_prefix("stochastic:") {
                    Method::Stochastic(seed.parse()?)
                } else {
                    bail!("unknown method '{s}'")
                }
            }
        })
    }

    /// Does this method need calibration activations?
    pub fn needs_calibration(&self) -> bool {
        matches!(
            self,
            Method::Gptq | Method::MrGptq | Method::GptqFourSix | Method::Faar | Method::Faar2fa
        )
    }

    /// Is the result evaluated through the act-quant (W4A4) graph?
    pub fn w4a4(&self) -> bool {
        !matches!(self, Method::Bf16)
    }

    /// The element format this method quantizes into.
    pub fn format(&self) -> FormatKind {
        match self {
            Method::Mxfp4 => FormatKind::Mxfp4,
            _ => FormatKind::Nvfp4,
        }
    }
}

/// Result of quantizing a model with a method.
pub struct QuantOutcome {
    /// the quantized model: packed layers + dense passthrough
    pub params: QuantParamStore,
    /// which method produced it
    pub method: Method,
    /// quantization wall time
    pub wall_s: f64,
    /// FAAR-family state (for packing / inspection); None for baselines
    pub faar: Option<FaarState>,
}

/// Quantize `fp_params` with `method`. `calib`/`corpus` may be None for
/// training-free methods that don't need them (enforced).
pub fn quantize(
    rt: &Runtime,
    fp_params: &ParamStore,
    method: Method,
    cfg: &PipelineConfig,
    calib: Option<&Calibration>,
    corpora: Option<&[&Corpus]>,
) -> Result<QuantOutcome> {
    let t0 = std::time::Instant::now();
    if method.needs_calibration() && calib.is_none() {
        bail!("method {} requires calibration data", method.name());
    }

    let params = match method {
        Method::Bf16 => QuantParamStore::dense_only(fp_params.clone()),
        Method::Rtn => round_all(rt, fp_params, method, ScaleMethod::Standard, RoundingScheme::Rtn)?,
        Method::Lower => {
            round_all(rt, fp_params, method, ScaleMethod::Standard, RoundingScheme::Lower)?
        }
        Method::Upper => {
            round_all(rt, fp_params, method, ScaleMethod::Standard, RoundingScheme::Upper)?
        }
        Method::Stochastic(seed) => round_all(
            rt,
            fp_params,
            method,
            ScaleMethod::Standard,
            RoundingScheme::Stochastic(seed),
        )?,
        Method::FourSix => {
            round_all(rt, fp_params, method, ScaleMethod::FourSix, RoundingScheme::Rtn)?
        }
        Method::StrongBaseline => {
            round_all(rt, fp_params, method, ScaleMethod::Search, RoundingScheme::Rtn)?
        }
        Method::Mxfp4 => {
            round_all(rt, fp_params, method, ScaleMethod::Standard, RoundingScheme::Rtn)?
        }
        Method::Gptq => gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::Standard, false, cfg)?,
        Method::MrGptq => gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::Standard, true, cfg)?,
        Method::GptqFourSix => {
            gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::FourSix, false, cfg)?
        }
        Method::Faar | Method::Faar2fa => {
            let mut state = prepare_all(rt, fp_params, cfg)?;
            stage1(rt, fp_params, calib.unwrap(), cfg, &mut state)?;
            if method == Method::Faar2fa {
                let corpora = corpora
                    .ok_or_else(|| anyhow::anyhow!("faar+2fa requires the calibration corpora"))?;
                stage2(rt, fp_params, corpora, cfg, &mut state)?;
            }
            let hardened = harden_to_params(rt, fp_params, &state)?;
            return Ok(QuantOutcome {
                params: hardened,
                method,
                wall_s: t0.elapsed().as_secs_f64(),
                faar: Some(state),
            });
        }
    };

    Ok(QuantOutcome { params, method, wall_s: t0.elapsed().as_secs_f64(), faar: None })
}

/// Training-free path: scale selection + rounding scheme on every
/// qlinear, through the method's codec; each layer lands packed.
fn round_all(
    rt: &Runtime,
    fp_params: &ParamStore,
    method: Method,
    scale_method: ScaleMethod,
    scheme: RoundingScheme,
) -> Result<QuantParamStore> {
    let kind = method.format();
    let codec = codec_for(kind);
    let mut packed = BTreeMap::new();
    for (i, q) in rt.manifest.qlinears.iter().enumerate() {
        let w = fp_params.get(&q.name)?;
        // a clean error (not a codec assert) when the layer shape doesn't
        // fit this format's block: manifests only guarantee NVFP4's 16
        let block = codec.block_size();
        if block > 0 && q.k % block != 0 {
            bail!(
                "method {} ({}): qlinear '{}' K={} is not a multiple of the {}-element block",
                method.name(),
                codec.name(),
                q.name,
                q.k,
                block
            );
        }
        // NVFP4 exposes pluggable block-scale choosers (standard / 4-6 /
        // search); other codecs use their native recipe
        let p = if kind == FormatKind::Nvfp4 {
            crate::quant::scaling::prepare_with_method(w, scale_method)
        } else {
            codec.prepare(w)
        };
        // per-tensor seed variation for stochastic trials
        let scheme_i = match scheme {
            RoundingScheme::Stochastic(s) => {
                RoundingScheme::Stochastic(s.wrapping_mul(31).wrapping_add(i as u64))
            }
            other => other,
        };
        let v = scheme_i.decisions(&p);
        packed.insert(q.name.clone(), codec.encode(w, &p, &v));
    }
    Ok(QuantParamStore::from_store(fp_params, packed))
}

/// GPTQ path: per-layer Hessians from calibration, column solve per
/// slice, result re-encoded on-grid into a packed `QuantTensor`.
fn gptq_all(
    rt: &Runtime,
    fp_params: &ParamStore,
    calib: &Calibration,
    scale_method: ScaleMethod,
    mr_scales: bool,
    cfg: &PipelineConfig,
) -> Result<QuantParamStore> {
    let mut packed = BTreeMap::new();
    for q in &rt.manifest.qlinears {
        let w = fp_params.get(&q.name)?;
        let (scale, s_global) = crate::quant::scaling::scales_for(w, scale_method);
        let hessians = &calib.set(&q.capture)?.hessians;
        let (wq, scales_final) = gptq_quantize_stacked_with_scales(
            w,
            hessians,
            &scale,
            &s_global,
            GptqOptions { damp: cfg.gptq_damp, mr_scales },
        )?;
        packed.insert(q.name.clone(), codec::encode_nvfp4_on_grid(&wq, &scales_final, &s_global));
        crate::debug!("gptq done: {}", q.name);
    }
    Ok(QuantParamStore::from_store(fp_params, packed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names_roundtrip() {
        for m in [
            Method::Bf16,
            Method::Rtn,
            Method::Lower,
            Method::Upper,
            Method::FourSix,
            Method::StrongBaseline,
            Method::Mxfp4,
            Method::Gptq,
            Method::MrGptq,
            Method::GptqFourSix,
            Method::Faar,
            Method::Faar2fa,
        ] {
            let parsed = Method::parse(&m.name()).unwrap();
            assert_eq!(parsed, m, "{}", m.name());
        }
        assert_eq!(Method::parse("stochastic:7").unwrap(), Method::Stochastic(7));
        assert!(Method::parse("awq").is_err());
    }

    #[test]
    fn calibration_requirements() {
        assert!(!Method::Rtn.needs_calibration());
        assert!(Method::Gptq.needs_calibration());
        assert!(Method::Faar2fa.needs_calibration());
        assert!(!Method::Bf16.w4a4());
        assert!(Method::Rtn.w4a4());
        assert!(Method::Mxfp4.w4a4());
    }

    #[test]
    fn format_axis() {
        assert_eq!(Method::Rtn.format(), FormatKind::Nvfp4);
        assert_eq!(Method::Gptq.format(), FormatKind::Nvfp4);
        assert_eq!(Method::Mxfp4.format(), FormatKind::Mxfp4);
    }
}
