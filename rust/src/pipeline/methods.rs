//! Method registry: every quantization method the paper's tables compare.
//!
//! `quantize` is the single entry point: (frozen fp params, calibration)
//! → dequantized quantized-weight store, ready for the W4A4 eval graphs.

use anyhow::{bail, Result};

use crate::calib::Calibration;
use crate::config::{PipelineConfig, ScaleMethod};
use crate::data::Corpus;
use crate::formats::nvfp4;
use crate::gptq::{gptq_quantize_stacked, GptqOptions};
use crate::quant::rounding::RoundingScheme;
use crate::runtime::Runtime;
use crate::train::ParamStore;

use super::faar::{prepare_all, stage1, stage2, FaarState};
use super::harden::harden_to_params;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// unquantized reference
    Bf16,
    /// plain RTN with standard amax/6 scales
    Rtn,
    /// always-lower / always-upper rounding (Table 1)
    Lower,
    Upper,
    /// stochastic rounding trial (Table 1)
    Stochastic(u64),
    /// "4/6" adaptive block scaling + RTN (paper baseline [23])
    FourSix,
    /// RTN + MSE-optimal block-scale search (paper "strong baseline")
    StrongBaseline,
    /// GPTQ on the NVFP4 grid (standard scales)
    Gptq,
    /// MR-GPTQ: GPTQ with per-block scale re-optimization ([22])
    MrGptq,
    /// GPTQ on 4/6 scales (paper "GPTQ+4/6")
    GptqFourSix,
    /// FAAR stage-1 only (ablation Table 6)
    Faar,
    /// full method: FAAR + 2FA
    Faar2fa,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Bf16 => "bf16".into(),
            Method::Rtn => "rtn".into(),
            Method::Lower => "lower".into(),
            Method::Upper => "upper".into(),
            Method::Stochastic(s) => format!("stochastic[{s}]"),
            Method::FourSix => "4/6".into(),
            Method::StrongBaseline => "strong-baseline".into(),
            Method::Gptq => "gptq".into(),
            Method::MrGptq => "mr-gptq".into(),
            Method::GptqFourSix => "gptq+4/6".into(),
            Method::Faar => "faar".into(),
            Method::Faar2fa => "faar+2fa".into(),
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "bf16" | "fp" => Method::Bf16,
            "rtn" => Method::Rtn,
            "lower" => Method::Lower,
            "upper" => Method::Upper,
            "4/6" | "foursix" => Method::FourSix,
            "strong-baseline" | "strong" => Method::StrongBaseline,
            "gptq" => Method::Gptq,
            "mr-gptq" | "mrgptq" => Method::MrGptq,
            "gptq+4/6" | "gptq46" => Method::GptqFourSix,
            "faar" => Method::Faar,
            "faar+2fa" | "faar2fa" | "ours" => Method::Faar2fa,
            _ => {
                if let Some(seed) = s.strip_prefix("stochastic:") {
                    Method::Stochastic(seed.parse()?)
                } else {
                    bail!("unknown method '{s}'")
                }
            }
        })
    }

    /// Does this method need calibration activations?
    pub fn needs_calibration(&self) -> bool {
        matches!(
            self,
            Method::Gptq | Method::MrGptq | Method::GptqFourSix | Method::Faar | Method::Faar2fa
        )
    }

    /// Is the result evaluated through the act-quant (W4A4) graph?
    pub fn w4a4(&self) -> bool {
        !matches!(self, Method::Bf16)
    }
}

/// Result of quantizing a model with a method.
pub struct QuantOutcome {
    pub params: ParamStore,
    pub method: Method,
    pub wall_s: f64,
    /// FAAR-family state (for packing / inspection); None for baselines
    pub faar: Option<FaarState>,
}

/// Quantize `fp_params` with `method`. `calib`/`corpus` may be None for
/// training-free methods that don't need them (enforced).
pub fn quantize(
    rt: &Runtime,
    fp_params: &ParamStore,
    method: Method,
    cfg: &PipelineConfig,
    calib: Option<&Calibration>,
    corpora: Option<&[&Corpus]>,
) -> Result<QuantOutcome> {
    let t0 = std::time::Instant::now();
    if method.needs_calibration() && calib.is_none() {
        bail!("method {} requires calibration data", method.name());
    }

    let params = match method {
        Method::Bf16 => fp_params.clone(),
        Method::Rtn => round_all(rt, fp_params, ScaleMethod::Standard, RoundingScheme::Rtn)?,
        Method::Lower => round_all(rt, fp_params, ScaleMethod::Standard, RoundingScheme::Lower)?,
        Method::Upper => round_all(rt, fp_params, ScaleMethod::Standard, RoundingScheme::Upper)?,
        Method::Stochastic(seed) => round_all(
            rt,
            fp_params,
            ScaleMethod::Standard,
            RoundingScheme::Stochastic(seed),
        )?,
        Method::FourSix => round_all(rt, fp_params, ScaleMethod::FourSix, RoundingScheme::Rtn)?,
        Method::StrongBaseline => {
            round_all(rt, fp_params, ScaleMethod::Search, RoundingScheme::Rtn)?
        }
        Method::Gptq => gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::Standard, false, cfg)?,
        Method::MrGptq => gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::Standard, true, cfg)?,
        Method::GptqFourSix => {
            gptq_all(rt, fp_params, calib.unwrap(), ScaleMethod::FourSix, false, cfg)?
        }
        Method::Faar | Method::Faar2fa => {
            let mut state = prepare_all(rt, fp_params, cfg)?;
            stage1(rt, fp_params, calib.unwrap(), cfg, &mut state)?;
            if method == Method::Faar2fa {
                let corpora = corpora
                    .ok_or_else(|| anyhow::anyhow!("faar+2fa requires the calibration corpora"))?;
                stage2(rt, fp_params, corpora, cfg, &mut state)?;
            }
            let hardened = harden_to_params(rt, fp_params, &state)?;
            return Ok(QuantOutcome {
                params: hardened,
                method,
                wall_s: t0.elapsed().as_secs_f64(),
                faar: Some(state),
            });
        }
    };

    Ok(QuantOutcome { params, method, wall_s: t0.elapsed().as_secs_f64(), faar: None })
}

/// Training-free path: scales + rounding scheme on every qlinear.
fn round_all(
    rt: &Runtime,
    fp_params: &ParamStore,
    scale_method: ScaleMethod,
    scheme: RoundingScheme,
) -> Result<ParamStore> {
    let mut out = fp_params.clone();
    for (i, q) in rt.manifest.qlinears.iter().enumerate() {
        let w = fp_params.get(&q.name)?;
        let (scale, s_global) = crate::quant::scaling::scales_for(w, scale_method);
        let p = nvfp4::prepare_with_scales(w, scale, s_global);
        // per-tensor seed variation for stochastic trials
        let scheme_i = match scheme {
            RoundingScheme::Stochastic(s) => {
                RoundingScheme::Stochastic(s.wrapping_mul(31).wrapping_add(i as u64))
            }
            other => other,
        };
        out.set(&q.name, crate::quant::round_with(w, &p, scheme_i))?;
    }
    Ok(out)
}

/// GPTQ path: per-layer Hessians from calibration, column solve per slice.
fn gptq_all(
    rt: &Runtime,
    fp_params: &ParamStore,
    calib: &Calibration,
    scale_method: ScaleMethod,
    mr_scales: bool,
    cfg: &PipelineConfig,
) -> Result<ParamStore> {
    let mut out = fp_params.clone();
    for q in &rt.manifest.qlinears {
        let w = fp_params.get(&q.name)?;
        let (scale, s_global) = crate::quant::scaling::scales_for(w, scale_method);
        let hessians = &calib.set(&q.capture)?.hessians;
        let wq = gptq_quantize_stacked(
            w,
            hessians,
            &scale,
            &s_global,
            GptqOptions { damp: cfg.gptq_damp, mr_scales },
        )?;
        out.set(&q.name, wq)?;
        crate::debug!("gptq done: {}", q.name);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names_roundtrip() {
        for m in [
            Method::Bf16,
            Method::Rtn,
            Method::Lower,
            Method::Upper,
            Method::FourSix,
            Method::StrongBaseline,
            Method::Gptq,
            Method::MrGptq,
            Method::GptqFourSix,
            Method::Faar,
            Method::Faar2fa,
        ] {
            let parsed = Method::parse(&m.name()).unwrap();
            assert_eq!(parsed, m, "{}", m.name());
        }
        assert_eq!(Method::parse("stochastic:7").unwrap(), Method::Stochastic(7));
        assert!(Method::parse("awq").is_err());
    }

    #[test]
    fn calibration_requirements() {
        assert!(!Method::Rtn.needs_calibration());
        assert!(Method::Gptq.needs_calibration());
        assert!(Method::Faar2fa.needs_calibration());
        assert!(!Method::Bf16.w4a4());
        assert!(Method::Rtn.w4a4());
    }
}
