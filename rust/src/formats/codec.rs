//! The format-codec layer: one trait, three codecs, one canonical packed
//! representation.
//!
//! The paper's premise is that the 4-bit grid's *shape* must be a
//! first-class object. This module makes the whole format pluggable:
//!
//! * [`FormatCodec`] — `block_size` / `grid` / `prepare` / `encode` /
//!   `decode`, implemented by [`nvfp4::Nvfp4`] (16-elem E4M3 block scales
//!   + fp32 global), [`mxfp4::Mxfp4`] (32-elem power-of-two scales) and
//!   the plain [`E2m1`] (one fp32 scale per leading slice, no blocks).
//! * [`QuantTensor`] — the format-tagged packed payload (two 4-bit codes
//!   per byte + block-scale bytes + global scales) that the rest of the
//!   stack carries around instead of dequantized `f32` tensors. It
//!   serializes to the `FAQ1` container (and reads legacy `NVF4` files),
//!   validates every length against the header *before* slicing, and
//!   dequantizes through [`codec_for`].
//! * [`Prepared`] — the elementwise interval context (lower/upper node,
//!   effective scale, v_init) shared by all three codecs: they differ
//!   only in how the effective-scale tensor is built, not in the E2M1
//!   element grid itself.
//!
//! Encode/decode are block-parallel ([`threads::par_map`]) above
//! [`PAR_THRESHOLD`] elements; `bench_formats` records the scalar-vs-
//! parallel comparison in `BENCH_formats.json`.

use anyhow::{bail, Result};

use super::{e2m1, e4m3, mxfp4, nvfp4};
use crate::tensor::Tensor;
use crate::util::threads;

// ---------------------------------------------------------------------------
// Prepared interval context (format-agnostic given an effective scale)

/// Elementwise quantization context for FAAR / baselines: lower/upper
/// nodes, effective scale, and the paper's v_init. Built only inside
/// `formats/` — pipeline code obtains one through a codec's `prepare` or
/// `quant::scaling::prepare_with_method`.
#[derive(Clone, Debug)]
pub struct Prepared {
    /// lower enclosing node per element (normalized magnitude)
    pub lower: Tensor,
    /// upper enclosing node per element
    pub upper: Tensor,
    /// elementwise effective scale
    pub scale: Tensor,
    /// relative position of each element inside its interval
    pub v_init: Tensor,
    /// per leading-slice global scale (1.0 placeholders for formats
    /// without a global level)
    pub s_global: Vec<f32>,
}

/// Full interval preparation from raw weights and a precomputed
/// elementwise effective-scale tensor (ref.quant_prepare's op order).
pub fn prepare_with_scales(w: &Tensor, scale: Tensor, s_global: Vec<f32>) -> Prepared {
    let mut lower = vec![0.0f32; w.numel()];
    let mut upper = vec![0.0f32; w.numel()];
    let mut v_init = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let s = scale.data[i];
        let wt = if s > 0.0 {
            (w.data[i].abs() / s.max(1e-30)).clamp(0.0, e2m1::FP4_MAX)
        } else {
            0.0
        };
        let (lo, up) = e2m1::interval(wt);
        lower[i] = lo;
        upper[i] = up;
        let width = up - lo;
        v_init[i] = if width > 0.0 { (wt - lo) / width.max(1e-30) } else { 0.5 };
    }
    Prepared {
        lower: Tensor::new(lower, w.shape.clone()),
        upper: Tensor::new(upper, w.shape.clone()),
        scale,
        v_init: Tensor::new(v_init, w.shape.clone()),
        s_global,
    }
}

/// Dequantized weights for hardened binary decisions `v` (>= 0.5 → upper).
pub fn hard_quant(w: &Tensor, p: &Prepared, v: &Tensor) -> Tensor {
    assert_eq!(w.shape, v.shape);
    let mut out = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let node = if v.data[i] >= 0.5 { p.upper.data[i] } else { p.lower.data[i] };
        out[i] = sign(w.data[i]) * node * p.scale.data[i];
    }
    Tensor::new(out, w.shape.clone())
}

/// Dequantized RTN weights (nearest node, ties → lower). Equivalent to
/// hardening `v_init > 0.5`.
pub fn rtn_quant(w: &Tensor, p: &Prepared) -> Tensor {
    let mut out = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let up = p.v_init.data[i] > 0.5;
        let node = if up { p.upper.data[i] } else { p.lower.data[i] };
        out[i] = sign(w.data[i]) * node * p.scale.data[i];
    }
    Tensor::new(out, w.shape.clone())
}

#[inline]
/// Sign as ±1.0 (0.0 for exact zero) — the paper's sign convention.
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Binary RTN decisions for a prepared context (`v_init > 0.5` → upper).
pub fn rtn_decisions(p: &Prepared) -> Tensor {
    p.v_init.map(|v| if v > 0.5 { 1.0 } else { 0.0 })
}

// ---------------------------------------------------------------------------
// Format identity

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// Identity tag for the three 4-bit formats.
pub enum FormatKind {
    /// 16-elem blocks, FP8-E4M3 block scales, fp32 global scale
    Nvfp4,
    /// 32-elem blocks, E8M0 (power-of-two) block scales, no global
    Mxfp4,
    /// no blocks: one fp32 scale per leading slice
    E2m1,
}

impl FormatKind {
    /// Canonical lowercase format name.
    pub fn name(self) -> &'static str {
        match self {
            FormatKind::Nvfp4 => "nvfp4",
            FormatKind::Mxfp4 => "mxfp4",
            FormatKind::E2m1 => "e2m1",
        }
    }

    /// Parse a format name (`nvfp4|mxfp4|e2m1`).
    pub fn parse(s: &str) -> Result<FormatKind> {
        match s {
            "nvfp4" => Ok(FormatKind::Nvfp4),
            "mxfp4" => Ok(FormatKind::Mxfp4),
            "e2m1" => Ok(FormatKind::E2m1),
            _ => bail!("unknown format '{s}' (nvfp4|mxfp4|e2m1)"),
        }
    }

    fn tag(self) -> u32 {
        match self {
            FormatKind::Nvfp4 => 1,
            FormatKind::Mxfp4 => 2,
            FormatKind::E2m1 => 3,
        }
    }

    fn from_tag(t: u32) -> Result<FormatKind> {
        match t {
            1 => Ok(FormatKind::Nvfp4),
            2 => Ok(FormatKind::Mxfp4),
            3 => Ok(FormatKind::E2m1),
            _ => bail!("unknown format tag {t}"),
        }
    }
}

/// A 4-bit block-format codec. All implementations share the E2M1
/// element grid; they differ in scale granularity and storage.
///
/// The round trip — `prepare` → `encode` → `decode` — is the canonical
/// way in and out of the packed representation:
///
/// ```
/// use nvfp4_faar::formats::codec::{codec_for, rtn_decisions, FormatKind};
/// use nvfp4_faar::tensor::Tensor;
///
/// // a [K=16, N=4] weight matrix (K must tile the format's block size)
/// let w = Tensor::new((0..64).map(|i| (i as f32 - 32.0) / 40.0).collect(), vec![16, 4]);
/// let codec = codec_for(FormatKind::Nvfp4);
/// let prepared = codec.prepare(&w);
/// let q = codec.encode(&w, &prepared, &rtn_decisions(&prepared));
/// assert_eq!(q.numel(), 64);
/// assert_eq!(q.codes.len(), 32); // two 4-bit codes per byte
///
/// let back = codec.decode(&q).unwrap();
/// assert_eq!(back.shape, w.shape);
/// // worst-case absolute grid error: one half-gap at the top of the
/// // grid, i.e. ~amax/6 per element (plus E4M3 scale rounding slack)
/// for (a, b) in back.data.iter().zip(&w.data) {
///     assert!((a - b).abs() <= 0.15, "{a} vs {b}");
/// }
/// ```
pub trait FormatCodec: Sync {
    /// The format this codec packs and decodes.
    fn kind(&self) -> FormatKind;

    /// Canonical lowercase format name.
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Elements sharing one block scale along K (0 = per-slice only).
    fn block_size(&self) -> usize;

    /// The non-negative element node grid, strictly increasing from 0.
    fn grid(&self) -> &'static [f32] {
        &e2m1::NODES
    }

    /// Interval context under this format's default scale recipe.
    fn prepare(&self, w: &Tensor) -> Prepared;

    /// Pack `w` into codes + scales given a prepared context and binary
    /// decisions `v` (>= 0.5 → upper node). `p` must come from this
    /// codec (or an equivalent scale recipe for it).
    fn encode(&self, w: &Tensor, p: &Prepared, v: &Tensor) -> QuantTensor;

    /// Dequantize a packed tensor of this format to f32.
    fn decode(&self, q: &QuantTensor) -> Result<Tensor>;
}

/// The codec registry: every format the pipeline can route through.
pub fn codec_for(kind: FormatKind) -> &'static dyn FormatCodec {
    match kind {
        FormatKind::Nvfp4 => &nvfp4::Nvfp4,
        FormatKind::Mxfp4 => &mxfp4::Mxfp4,
        FormatKind::E2m1 => &E2m1,
    }
}

/// Every registered codec, NVFP4 first.
pub fn all_codecs() -> [&'static dyn FormatCodec; 3] {
    [
        codec_for(FormatKind::Nvfp4),
        codec_for(FormatKind::Mxfp4),
        codec_for(FormatKind::E2m1),
    ]
}

// ---------------------------------------------------------------------------
// QuantTensor: the canonical packed representation

/// A quantized tensor in true packed form: 4-bit E2M1 codes two per byte,
/// format-specific block-scale bytes, per-slice global scales, and the
/// format tag. This is what `pipeline::methods::quantize` produces, what
/// `train::QuantParamStore` / `serve` hold in memory, and what
/// `harden::pack_model` writes to disk.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    /// which codec packed (and can decode) this payload
    pub format: FormatKind,
    /// logical tensor shape (`[..., K, N]`)
    pub shape: Vec<usize>,
    /// packed E2M1 codes, two per byte (low nibble first), row-major
    pub codes: Vec<u8>,
    /// block-scale bytes (E4M3 for NVFP4, E8M0 for MXFP4, empty for E2M1)
    pub scales: Vec<u8>,
    /// per leading-slice fp32 scales (empty for MXFP4)
    pub s_global: Vec<f32>,
}

/// [lead, K, N] geometry of a `[..., K, N]` weight shape.
pub(crate) struct Geometry {
    pub lead: usize,
    pub k: usize,
    pub n: usize,
}

pub(crate) fn geometry(shape: &[usize]) -> Result<Geometry> {
    if shape.len() < 2 {
        bail!("quantized tensors must be rank >= 2, got {shape:?}");
    }
    let k = shape[shape.len() - 2];
    let n = shape[shape.len() - 1];
    let lead = shape[..shape.len() - 2].iter().product::<usize>().max(1);
    Ok(Geometry { lead, k, n })
}

const MAGIC: &[u8; 4] = b"FAQ1";
const LEGACY_MAGIC: &[u8; 4] = b"NVF4";

impl QuantTensor {
    /// Logical element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes of the packed payload (codes + scales + globals) — the real
    /// memory footprint of this layer.
    pub fn payload_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + self.s_global.len() * 4
    }

    /// Payload bits per logical weight (≈4.5 for NVFP4).
    pub fn bits_per_weight(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / self.numel().max(1) as f64
    }

    /// Dequantize through the codec registry.
    pub fn dequantize(&self) -> Result<Tensor> {
        codec_for(self.format).decode(self)
    }

    /// Expected (scale-byte count, global count) for format + shape.
    fn expected_lens(&self) -> Result<(usize, usize)> {
        let g = geometry(&self.shape)?;
        match self.format {
            FormatKind::Nvfp4 => {
                if g.k % nvfp4::BLOCK != 0 {
                    bail!("nvfp4: K={} not a multiple of {}", g.k, nvfp4::BLOCK);
                }
                Ok((g.lead * (g.k / nvfp4::BLOCK) * g.n, g.lead))
            }
            FormatKind::Mxfp4 => {
                if g.k % mxfp4::BLOCK != 0 {
                    bail!("mxfp4: K={} not a multiple of {}", g.k, mxfp4::BLOCK);
                }
                Ok((g.lead * (g.k / mxfp4::BLOCK) * g.n, 0))
            }
            FormatKind::E2m1 => Ok((0, g.lead)),
        }
    }

    /// Validate payload lengths against the shape — a corrupted container
    /// must error, never panic or slice out of bounds.
    pub fn validate(&self) -> Result<()> {
        let (ns, ng) = self.expected_lens()?;
        let nc = self.numel().div_ceil(2);
        if self.codes.len() != nc {
            bail!(
                "{}: {} code bytes for {} elements (expected {nc})",
                self.format.name(),
                self.codes.len(),
                self.numel()
            );
        }
        if self.scales.len() != ns {
            bail!("{}: {} scale bytes, expected {ns}", self.format.name(), self.scales.len());
        }
        if self.s_global.len() != ng {
            bail!("{}: {} global scales, expected {ng}", self.format.name(), self.s_global.len());
        }
        Ok(())
    }

    /// Serialize to the `FAQ1` container: magic, format tag, rank, dims,
    /// globals, scales, codes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.payload_bytes() + 64);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&self.format.tag().to_le_bytes());
        buf.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(self.s_global.len() as u32).to_le_bytes());
        for &g in &self.s_global {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        buf.extend_from_slice(&(self.scales.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.scales);
        buf.extend_from_slice(&(self.codes.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.codes);
        buf
    }

    /// Parse a `FAQ1` container (or a legacy `NVF4` payload, which has
    /// the same layout minus the format tag). Every length is validated
    /// against the remaining buffer and the declared shape.
    ///
    /// ```
    /// use nvfp4_faar::formats::codec::{codec_for, rtn_decisions, FormatKind, QuantTensor};
    /// use nvfp4_faar::tensor::Tensor;
    ///
    /// let w = Tensor::new(vec![0.5; 64], vec![16, 4]);
    /// let codec = codec_for(FormatKind::Nvfp4);
    /// let p = codec.prepare(&w);
    /// let q = codec.encode(&w, &p, &rtn_decisions(&p));
    ///
    /// let bytes = q.to_bytes();
    /// let back = QuantTensor::from_bytes(&bytes).unwrap();
    /// assert_eq!(back, q);
    /// // truncated or corrupted payloads error instead of panicking
    /// assert!(QuantTensor::from_bytes(&bytes[..10]).is_err());
    /// assert!(QuantTensor::from_bytes(b"not a container").is_err());
    /// ```
    pub fn from_bytes(buf: &[u8]) -> Result<QuantTensor> {
        let mut r = Reader { buf, off: 0 };
        let magic = r.take(4)?;
        let format = if magic == MAGIC {
            FormatKind::from_tag(r.u32()?)?
        } else if magic == LEGACY_MAGIC {
            FormatKind::Nvfp4
        } else {
            bail!("not a FAQ1/NVF4 payload");
        };
        let rank = r.u32()? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u64()? as usize);
        }
        // guard the element count before any size arithmetic: a lying
        // header must error, not overflow (panic in debug, wrap-and-pass
        // length checks in release)
        let mut numel = 1usize;
        for &d in &shape {
            numel = match numel.checked_mul(d) {
                Some(v) => v,
                None => bail!("implausible shape {shape:?}"),
            };
        }
        if numel.div_ceil(2) > buf.len() {
            bail!("shape {shape:?} implies more code bytes than the payload holds");
        }
        let ng = r.u32()? as usize;
        if ng.saturating_mul(4) > buf.len() {
            bail!("implausible global-scale count {ng}");
        }
        let mut s_global = Vec::with_capacity(ng);
        for _ in 0..ng {
            s_global.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        let ns = r.u64()? as usize;
        let scales = r.take(ns)?.to_vec();
        let nc = r.u64()? as usize;
        let codes = r.take(nc)?.to_vec();
        let q = QuantTensor { format, shape, codes, scales, s_global };
        q.validate()?;
        Ok(q)
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() < self.off.saturating_add(n) {
            bail!("truncated payload at byte {}", self.off);
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

// ---------------------------------------------------------------------------
// Block-parallel pack / unpack machinery

/// Minimum element count before encode/decode fans out across threads.
pub const PAR_THRESHOLD: usize = 1 << 16;
const MIN_CHUNK: usize = 1 << 14;

#[derive(Clone, Copy, Debug)]
/// Threading policy for encode/decode.
pub enum Parallelism {
    /// single-threaded reference path
    Scalar,
    /// threads when the tensor is big enough (the default)
    Auto,
    /// exactly this many workers (benchmarking)
    Workers(usize),
}

impl Parallelism {
    fn workers_for(self, n: usize) -> usize {
        match self {
            Parallelism::Scalar => 1,
            Parallelism::Workers(w) => w.max(1),
            Parallelism::Auto => {
                if n >= PAR_THRESHOLD {
                    threads::default_workers()
                } else {
                    1
                }
            }
        }
    }
}

/// Even-aligned chunk ranges: each chunk starts on a nibble-pair
/// boundary, so chunks pack/unpack independently.
fn chunk_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let target = (n / (workers * 4).max(1)).max(MIN_CHUNK);
    let target = (target + 1) & !1;
    let mut out = vec![];
    let mut start = 0;
    while start < n {
        let end = (start + target).min(n);
        out.push((start, end));
        start = end;
    }
    out
}

/// The one chunk fan-out: run `per_range(start, end)` over even-aligned
/// chunks of `[0, n)` — inline for one worker, `par_map` otherwise — and
/// concatenate the pieces in order.
fn chunked<R: Send>(
    n: usize,
    par: Parallelism,
    per_range: &(dyn Fn(usize, usize) -> Vec<R> + Sync),
) -> Vec<R> {
    let workers = par.workers_for(n);
    if workers <= 1 {
        return per_range(0, n);
    }
    let parts = threads::par_map(chunk_ranges(n, workers), workers, |(s, e)| per_range(s, e));
    let total = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for part in parts {
        out.extend(part);
    }
    out
}

/// Pack `n` elements into nibble codes via an arbitrary per-element code
/// function, chunk-parallel when allowed.
fn pack_with(code_of: &(dyn Fn(usize) -> u8 + Sync), n: usize, par: Parallelism) -> Vec<u8> {
    chunked(n, par, &|start, end| {
        let mut out = Vec::with_capacity((end - start).div_ceil(2));
        let mut i = start;
        while i < end {
            let lo = code_of(i) & 0x0F;
            let hi = if i + 1 < end { code_of(i + 1) & 0x0F } else { 0 };
            out.push(lo | (hi << 4));
            i += 2;
        }
        out
    })
}

#[inline]
fn code_at(w: f32, s: f32, v: f32) -> u8 {
    let wt = if s > 0.0 { (w.abs() / s.max(1e-30)).clamp(0.0, e2m1::FP4_MAX) } else { 0.0 };
    let x = if w < 0.0 { -wt } else { wt };
    e2m1::encode_choice(x, v >= 0.5)
}

#[inline]
fn rtn_code_at(w: f32, s: f32) -> u8 {
    if s > 0.0 {
        let wt = (w.abs() / s.max(1e-30)).min(e2m1::FP4_MAX);
        let x = if w < 0.0 { -wt } else { wt };
        e2m1::encode_rtn(x)
    } else {
        0
    }
}

/// Pack elementwise decisions into nibble codes (shared by all codecs).
pub fn pack_codes(w: &Tensor, p: &Prepared, v: &Tensor, par: Parallelism) -> Vec<u8> {
    assert_eq!(w.shape, v.shape);
    let (wd, sd, vd) = (&w.data, &p.scale.data, &v.data);
    pack_with(&|i| code_at(wd[i], sd[i], vd[i]), w.numel(), par)
}

/// Dequantize packed nibbles with a per-element effective scale,
/// chunk-parallel when allowed.
pub fn unpack_elems(
    codes: &[u8],
    n: usize,
    scale_of: &(dyn Fn(usize) -> f32 + Sync),
    par: Parallelism,
) -> Vec<f32> {
    chunked(n, par, &|start, end| {
        let mut out = Vec::with_capacity(end - start);
        for i in start..end {
            let byte = codes[i / 2];
            let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            out.push(e2m1::decode(code) * scale_of(i));
        }
        out
    })
}

/// Block-scale bytes for any block-scaled format: one byte per
/// (slice, block-row, column), emitted `[lead, K/block, N]` row-major.
/// `byte_of(s_eff, slice)` is the format's scale encoder.
pub(crate) fn block_scale_bytes(
    scale: &Tensor,
    block: usize,
    byte_of: &dyn Fn(f32, usize) -> u8,
) -> Vec<u8> {
    let g = geometry(&scale.shape).expect("quantized weights must be rank >= 2");
    assert_eq!(g.k % block, 0, "K={} not a multiple of {block}", g.k);
    let slice_len = g.k * g.n;
    let mut out = Vec::with_capacity(g.lead * (g.k / block) * g.n);
    for l in 0..g.lead {
        for kb in 0..g.k / block {
            for col in 0..g.n {
                out.push(byte_of(scale.data[l * slice_len + kb * block * g.n + col], l));
            }
        }
    }
    out
}

/// E4M3 block-scale bytes for an NVFP4 effective-scale tensor.
pub(crate) fn nvfp4_scale_bytes(scale: &Tensor, s_global: &[f32]) -> Vec<u8> {
    block_scale_bytes(scale, nvfp4::BLOCK, &|s_eff, l| e4m3::encode(s_eff / s_global[l]))
}

/// Dequantize a block-scaled packed tensor without per-element div/mod:
/// each chunk decomposes its start index once, then walks (slice, row,
/// column) incrementally. `s_eff_of(byte, slice)` decodes one scale byte.
pub(crate) fn unpack_block_scaled(
    codes: &[u8],
    shape: &[usize],
    block: usize,
    scales: &[u8],
    s_eff_of: &(dyn Fn(u8, usize) -> f32 + Sync),
    par: Parallelism,
) -> Result<Vec<f32>> {
    let g = geometry(shape)?;
    let (k, n) = (g.k, g.n);
    let slice_len = k * n;
    let sc_rows = k / block;
    let numel: usize = shape.iter().product();
    if numel == 0 {
        return Ok(vec![]);
    }
    Ok(chunked(numel, par, &|start, end| {
        let mut out = Vec::with_capacity(end - start);
        let mut l = start / slice_len;
        let rem = start % slice_len;
        let mut row = rem / n;
        let mut col = rem % n;
        let mut brow = row / block;
        for i in start..end {
            let byte = codes[i / 2];
            let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            let sb = scales[(l * sc_rows + brow) * n + col];
            out.push(e2m1::decode(code) * s_eff_of(sb, l));
            col += 1;
            if col == n {
                col = 0;
                row += 1;
                if row == k {
                    row = 0;
                    brow = 0;
                    l += 1;
                } else if row % block == 0 {
                    brow += 1;
                }
            }
        }
        out
    }))
}

// ---------------------------------------------------------------------------
// BlockDecode: zero-copy block-wise decode view for fused kernels

/// A zero-copy, block-wise decode view over a packed [`QuantTensor`],
/// built for kernels that dequantize *inside* their inner loop (the
/// native inference backend's fused dequant-GEMM) instead of
/// materializing the full f32 tensor first.
///
/// The view pre-builds two lookup tables — the signed E2M1 element grid
/// (16 entries) and the raw block-scale factor per scale byte (256
/// entries) — so the per-element cost in a GEMM loop is two table reads
/// and a multiply. Rows are exposed as packed nibble bytes
/// ([`Self::code_row`]) plus per-column effective scales
/// ([`Self::scale_row_into`]), which is exactly the granularity a
/// row-major `y += x[row] * W[row, :]` update consumes.
///
/// Formats without block structure (plain E2M1) are presented as a
/// single block spanning all of K, so callers need no per-format
/// branches.
pub struct BlockDecode<'a> {
    q: &'a QuantTensor,
    /// signed element value per 4-bit code
    elem: [f32; 16],
    /// raw block-scale factor per scale byte (unused entries stay 1.0)
    scale_byte: [f32; 256],
    lead: usize,
    k: usize,
    n: usize,
    /// rows sharing one scale row (all of K when the format is unblocked)
    block: usize,
}

/// Precomputed decode LUTs for one format: the signed E2M1 element grid
/// (16 entries) and the per-byte block-scale factors (256 entries).
/// Build once — e.g. per packed layer at model construction — and pass
/// to [`QuantTensor::block_decode_cached`], so per-call view setup in a
/// GEMM hot loop is a memcpy instead of 272 float decodes.
#[derive(Clone, Copy)]
pub struct DecodeTables {
    kind: FormatKind,
    elem: [f32; 16],
    scale_byte: [f32; 256],
}

impl std::fmt::Debug for DecodeTables {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecodeTables").field("kind", &self.kind).finish_non_exhaustive()
    }
}

impl FormatKind {
    /// Precompute the decode tables for this format.
    pub fn decode_tables(self) -> DecodeTables {
        let mut elem = [0.0f32; 16];
        for (c, e) in elem.iter_mut().enumerate() {
            *e = e2m1::decode(c as u8);
        }
        let mut scale_byte = [1.0f32; 256];
        match self {
            FormatKind::Nvfp4 => {
                for (b, s) in scale_byte.iter_mut().enumerate() {
                    *s = e4m3::decode(b as u8);
                }
            }
            FormatKind::Mxfp4 => {
                for (b, s) in scale_byte.iter_mut().enumerate() {
                    *s = mxfp4::e8m0_decode(b as u8);
                }
            }
            // no block-scale bytes; the 1.0 fill is never indexed
            FormatKind::E2m1 => {}
        }
        DecodeTables { kind: self, elem, scale_byte }
    }
}

impl QuantTensor {
    /// Build a [`BlockDecode`] view over this payload.
    ///
    /// Validates the payload first and errors when the trailing dimension
    /// is odd (rows would straddle nibble-pair byte boundaries); callers
    /// fall back to [`Self::dequantize`] in that case. Hot loops that
    /// build views repeatedly should precompute the tables once with
    /// [`FormatKind::decode_tables`] and use [`Self::block_decode_cached`].
    pub fn block_decode(&self) -> Result<BlockDecode<'_>> {
        self.block_decode_cached(&self.format.decode_tables())
    }

    /// [`Self::block_decode`] reusing precomputed tables (errors when
    /// `tables` was built for a different format).
    pub fn block_decode_cached(&self, tables: &DecodeTables) -> Result<BlockDecode<'_>> {
        if tables.kind != self.format {
            bail!(
                "decode tables for {} fed a {} tensor",
                tables.kind.name(),
                self.format.name()
            );
        }
        self.validate()?;
        let g = geometry(&self.shape)?;
        if g.n % 2 != 0 {
            bail!("block_decode: trailing dim {} is odd (rows not byte-aligned)", g.n);
        }
        let block = match self.format {
            FormatKind::Nvfp4 => nvfp4::BLOCK,
            FormatKind::Mxfp4 => mxfp4::BLOCK,
            // one block spanning all of K (per-slice scale only)
            FormatKind::E2m1 => g.k.max(1),
        };
        Ok(BlockDecode {
            q: self,
            elem: tables.elem,
            scale_byte: tables.scale_byte,
            lead: g.lead,
            k: g.k,
            n: g.n,
            block,
        })
    }
}

impl BlockDecode<'_> {
    /// Leading (stacked) slices.
    pub fn lead(&self) -> usize {
        self.lead
    }

    /// Contraction rows per slice.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns per slice.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Rows sharing one scale row (`k` for unblocked formats).
    pub fn block(&self) -> usize {
        self.block
    }

    /// Block rows per slice (`k / block`).
    pub fn block_rows(&self) -> usize {
        self.k / self.block
    }

    /// Per-slice global scale factor (1.0 for formats without one).
    fn s_global(&self, l: usize) -> f32 {
        match self.q.format {
            FormatKind::Nvfp4 | FormatKind::E2m1 => self.q.s_global[l],
            FormatKind::Mxfp4 => 1.0,
        }
    }

    /// Decoded element value for a 4-bit code (sign bit included).
    #[inline]
    pub fn elem(&self, code: u8) -> f32 {
        self.elem[(code & 0x0F) as usize]
    }

    /// The full 16-entry element LUT (code → signed E2M1 value), for
    /// vector kernels that gather several codes per instruction instead
    /// of calling [`Self::elem`] one nibble at a time. Entry 8 is `-0.0`
    /// — SIMD lookups must preserve the bit pattern, not just the value.
    #[inline]
    pub fn elem_table(&self) -> &[f32; 16] {
        &self.elem
    }

    /// Fill `out` (length `n`) with the effective per-column scales of
    /// block-row `kb` in slice `l`.
    pub fn scale_row_into(&self, l: usize, kb: usize, out: &mut [f32]) {
        self.scale_range_into(l, kb, 0, self.n, out);
    }

    /// Fill `out` (length `c1 - c0`) with the effective scales of columns
    /// `[c0, c1)` of block-row `kb` in slice `l` — the column-parallel
    /// kernels decode only their own chunk instead of the full row.
    pub fn scale_range_into(&self, l: usize, kb: usize, c0: usize, c1: usize, out: &mut [f32]) {
        assert_eq!(out.len(), c1 - c0, "scale range buffer length");
        let sg = self.s_global(l);
        if self.q.scales.is_empty() {
            out.fill(sg);
            return;
        }
        let base = (l * self.block_rows() + kb) * self.n;
        for (o, &b) in out.iter_mut().zip(&self.q.scales[base + c0..base + c1]) {
            *o = self.scale_byte[b as usize] * sg;
        }
    }

    /// Packed nibble codes of row `row` in slice `l` (`n / 2` bytes, low
    /// nibble first).
    #[inline]
    pub fn code_row(&self, l: usize, row: usize) -> &[u8] {
        let e = (l * self.k + row) * self.n;
        &self.q.codes[e / 2..e / 2 + self.n / 2]
    }

    /// Decode one full row into `out` (length `n`), given that row's
    /// block scales from [`Self::scale_row_into`].
    pub fn decode_row_into(&self, l: usize, row: usize, scales: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.n, "row buffer length");
        assert_eq!(scales.len(), self.n, "scale row length");
        for (j2, &b) in self.code_row(l, row).iter().enumerate() {
            let j = 2 * j2;
            out[j] = self.elem[(b & 0x0F) as usize] * scales[j];
            out[j + 1] = self.elem[(b >> 4) as usize] * scales[j + 1];
        }
    }
}

/// Re-encode an on-grid dequantized tensor (e.g. a GPTQ solution) into a
/// packed NVFP4 `QuantTensor`, given the effective scales it was
/// quantized with. Every element already sits on a `node * scale` point,
/// so RTN recovers the exact codes.
pub fn encode_nvfp4_on_grid(wq: &Tensor, scale: &Tensor, s_global: &[f32]) -> QuantTensor {
    assert_eq!(wq.shape, scale.shape);
    let (wd, sd) = (&wq.data, &scale.data);
    QuantTensor {
        format: FormatKind::Nvfp4,
        shape: wq.shape.clone(),
        codes: pack_with(&|i| rtn_code_at(wd[i], sd[i]), wq.numel(), Parallelism::Auto),
        scales: nvfp4_scale_bytes(scale, s_global),
        s_global: s_global.to_vec(),
    }
}

// ---------------------------------------------------------------------------
// The plain E2M1 codec (no block scales) — the "format floor" that the
// block-scaled formats improve on.

/// Plain E2M1: one fp32 scale per leading slice (amax → top node), no
/// block structure at all.
pub struct E2m1;

impl FormatCodec for E2m1 {
    fn kind(&self) -> FormatKind {
        FormatKind::E2m1
    }

    fn block_size(&self) -> usize {
        0
    }

    fn prepare(&self, w: &Tensor) -> Prepared {
        let g = geometry(&w.shape).expect("quantized weights must be rank >= 2");
        let slice_len = g.k * g.n;
        let mut s_global = Vec::with_capacity(g.lead);
        let mut scale = vec![0.0f32; w.numel()];
        for l in 0..g.lead {
            let ws = &w.data[l * slice_len..(l + 1) * slice_len];
            let amax = ws.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let s = if amax == 0.0 { 0.0 } else { amax / e2m1::FP4_MAX };
            s_global.push(s);
            scale[l * slice_len..(l + 1) * slice_len].fill(s);
        }
        prepare_with_scales(w, Tensor::new(scale, w.shape.clone()), s_global)
    }

    fn encode(&self, w: &Tensor, p: &Prepared, v: &Tensor) -> QuantTensor {
        QuantTensor {
            format: FormatKind::E2m1,
            shape: w.shape.clone(),
            codes: pack_codes(w, p, v, Parallelism::Auto),
            scales: vec![],
            s_global: p.s_global.clone(),
        }
    }

    fn decode(&self, q: &QuantTensor) -> Result<Tensor> {
        if q.format != FormatKind::E2m1 {
            bail!("e2m1 codec fed a {} tensor", q.format.name());
        }
        q.validate()?;
        let g = geometry(&q.shape)?;
        let slice_len = g.k * g.n;
        let s_global = &q.s_global;
        let scale_of = move |i: usize| s_global[i / slice_len];
        let data = unpack_elems(&q.codes, q.numel(), &scale_of, Parallelism::Auto);
        Ok(Tensor::new(data, q.shape.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    #[test]
    fn registry_covers_all_kinds() {
        for codec in all_codecs() {
            assert_eq!(codec_for(codec.kind()).kind(), codec.kind());
            assert!(!codec.name().is_empty());
            assert_eq!(FormatKind::parse(codec.name()).unwrap(), codec.kind());
        }
        assert!(FormatKind::parse("fp37").is_err());
    }

    #[test]
    fn e2m1_codec_roundtrip() {
        let w = rand_w(&[2, 32, 8], 1, 0.1);
        let c = codec_for(FormatKind::E2m1);
        let p = c.prepare(&w);
        let v = rtn_decisions(&p);
        let q = c.encode(&w, &p, &v);
        assert_eq!(q.scales.len(), 0);
        assert_eq!(q.s_global.len(), 2);
        let expect = hard_quant(&w, &p, &v);
        let deq = q.dequantize().unwrap();
        for i in 0..w.numel() {
            assert!(
                (deq.data[i] - expect.data[i]).abs() <= 1e-6 * expect.data[i].abs().max(1e-6),
                "i={i}: {} vs {}",
                deq.data[i],
                expect.data[i]
            );
        }
        // bits/weight: 4 bits + one f32 per slice
        assert!(q.bits_per_weight() < 4.3, "bits {}", q.bits_per_weight());
    }

    #[test]
    fn parallel_matches_scalar() {
        // large enough to split into several chunks
        let w = rand_w(&[4, 256, 64], 2, 0.1);
        let nv = nvfp4::Nvfp4;
        let p = FormatCodec::prepare(&nv, &w);
        let v = rtn_decisions(&p);
        let a = nv.encode_mode(&w, &p, &v, Parallelism::Scalar);
        let b = nv.encode_mode(&w, &p, &v, Parallelism::Workers(4));
        assert_eq!(a, b);
        let da = nv.decode_mode(&a, Parallelism::Scalar).unwrap();
        let db = nv.decode_mode(&a, Parallelism::Workers(4)).unwrap();
        assert_eq!(da.data, db.data);
    }

    #[test]
    fn container_roundtrip_and_legacy() {
        let w = rand_w(&[32, 16], 3, 0.05);
        for codec in all_codecs() {
            let p = codec.prepare(&w);
            let q = codec.encode(&w, &p, &rtn_decisions(&p));
            let back = QuantTensor::from_bytes(&q.to_bytes()).unwrap();
            assert_eq!(back, q, "{} container roundtrip", codec.name());
        }
        // legacy NVF4 container parses as an nvfp4 QuantTensor
        let p = nvfp4::prepare(&w);
        let packed = nvfp4::PackedTensor::pack(&w, &p, &p.v_init);
        let q = QuantTensor::from_bytes(&packed.to_bytes()).unwrap();
        assert_eq!(q.format, FormatKind::Nvfp4);
        assert_eq!(q.codes, packed.codes);
        assert_eq!(q.dequantize().unwrap().data, packed.unpack().data);
    }

    #[test]
    fn validation_rejects_inconsistent_payloads() {
        let w = rand_w(&[32, 16], 4, 0.05);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let mut q = c.encode(&w, &p, &rtn_decisions(&p));
        assert!(q.validate().is_ok());
        q.codes.pop();
        assert!(q.validate().is_err());
        let mut q2 = c.encode(&w, &p, &rtn_decisions(&p));
        q2.scales.push(0);
        assert!(q2.validate().is_err());
        let mut q3 = c.encode(&w, &p, &rtn_decisions(&p));
        q3.shape = vec![16]; // rank 1
        assert!(q3.validate().is_err());
    }

    #[test]
    fn from_bytes_never_panics_on_truncation() {
        let w = rand_w(&[32, 16], 5, 0.05);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let bytes = c.encode(&w, &p, &rtn_decisions(&p)).to_bytes();
        for cut in 0..bytes.len() {
            assert!(QuantTensor::from_bytes(&bytes[..cut]).is_err(), "cut {cut} accepted");
        }
        assert!(QuantTensor::from_bytes(b"junkjunkjunk").is_err());
    }

    #[test]
    fn from_bytes_rejects_lying_dimensions() {
        // header claiming dims whose product overflows usize must error,
        // never panic or wrap into a passing length check
        let mut buf = Vec::new();
        buf.extend_from_slice(b"FAQ1");
        buf.extend_from_slice(&1u32.to_le_bytes()); // nvfp4 tag
        buf.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        buf.extend_from_slice(&(1u64 << 62).to_le_bytes());
        buf.extend_from_slice(&(1u64 << 62).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // ng
        buf.extend_from_slice(&0u64.to_le_bytes()); // ns
        buf.extend_from_slice(&0u64.to_le_bytes()); // nc
        assert!(QuantTensor::from_bytes(&buf).is_err());
        // huge-but-not-overflowing dims with a tiny payload also error
        let mut buf2 = Vec::new();
        buf2.extend_from_slice(b"FAQ1");
        buf2.extend_from_slice(&1u32.to_le_bytes());
        buf2.extend_from_slice(&2u32.to_le_bytes());
        buf2.extend_from_slice(&(1u64 << 20).to_le_bytes());
        buf2.extend_from_slice(&(1u64 << 20).to_le_bytes());
        buf2.extend_from_slice(&0u32.to_le_bytes());
        buf2.extend_from_slice(&0u64.to_le_bytes());
        buf2.extend_from_slice(&0u64.to_le_bytes());
        assert!(QuantTensor::from_bytes(&buf2).is_err());
    }

    #[test]
    fn chunks_cover_range_and_stay_even() {
        for n in [0usize, 1, 2, 15, (1 << 14) + 1, 100_000, (1 << 20) + 3] {
            let chunks = chunk_ranges(n, 8);
            let mut expect = 0;
            for (i, &(s, e)) in chunks.iter().enumerate() {
                assert_eq!(s, expect);
                assert!(e > s);
                assert_eq!(s % 2, 0, "chunk {i} starts on odd index");
                expect = e;
            }
            assert_eq!(expect, n);
            if n == 0 {
                assert!(chunks.is_empty());
            }
        }
    }

    #[test]
    fn block_decode_rows_match_dequantize() {
        // the fused-kernel view must reproduce the reference decode
        // exactly, row by row, for every format
        let w = rand_w(&[2, 32, 8], 9, 0.1);
        for codec in all_codecs() {
            let p = codec.prepare(&w);
            let q = codec.encode(&w, &p, &rtn_decisions(&p));
            let full = q.dequantize().unwrap();
            let dec = q.block_decode().unwrap();
            assert_eq!(dec.lead(), 2);
            assert_eq!(dec.k(), 32);
            assert_eq!(dec.n(), 8);
            assert_eq!(dec.block_rows() * dec.block(), dec.k());
            let mut scales = vec![0.0f32; dec.n()];
            let mut row = vec![0.0f32; dec.n()];
            for l in 0..dec.lead() {
                for kb in 0..dec.block_rows() {
                    dec.scale_row_into(l, kb, &mut scales);
                    for r in 0..dec.block() {
                        let ri = kb * dec.block() + r;
                        dec.decode_row_into(l, ri, &scales, &mut row);
                        let base = (l * 32 + ri) * 8;
                        assert_eq!(
                            &row[..],
                            &full.data[base..base + 8],
                            "{}: slice {l} row {ri}",
                            codec.name()
                        );
                    }
                }
            }
        }
        // odd trailing dim: view construction errors, decode still works
        let odd = rand_w(&[16, 3], 10, 0.1);
        let c = codec_for(FormatKind::E2m1);
        let p = c.prepare(&odd);
        let q = c.encode(&odd, &p, &rtn_decisions(&p));
        assert!(q.block_decode().is_err());
        assert!(q.dequantize().is_ok());

        // precomputed tables: same rows as the self-built view, and a
        // format mismatch is rejected
        let w2 = rand_w(&[32, 4], 11, 0.1);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w2);
        let q = c.encode(&w2, &p, &rtn_decisions(&p));
        let tables = FormatKind::Nvfp4.decode_tables();
        let cached = q.block_decode_cached(&tables).unwrap();
        let fresh = q.block_decode().unwrap();
        let mut scales = vec![0.0f32; 4];
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        for kb in 0..cached.block_rows() {
            cached.scale_row_into(0, kb, &mut scales);
            for r in 0..cached.block() {
                cached.decode_row_into(0, kb * cached.block() + r, &scales, &mut a);
                fresh.decode_row_into(0, kb * fresh.block() + r, &scales, &mut b);
                assert_eq!(a, b);
            }
        }
        assert!(q.block_decode_cached(&FormatKind::Mxfp4.decode_tables()).is_err());
    }

    #[test]
    fn on_grid_reencode_matches_source() {
        // RTN-dequantized weights re-encode to the same values
        let w = rand_w(&[64, 32], 6, 0.05);
        let p = nvfp4::prepare(&w);
        let wq = rtn_quant(&w, &p);
        let q = encode_nvfp4_on_grid(&wq, &p.scale, &p.s_global);
        let deq = q.dequantize().unwrap();
        for i in 0..wq.numel() {
            assert!(
                (deq.data[i] - wq.data[i]).abs() <= 1e-6 * wq.data[i].abs().max(1e-6),
                "i={i}: {} vs {}",
                deq.data[i],
                wq.data[i]
            );
        }
    }
}
