//! MXFP4 (OCP Microscaling) codec — the format the paper's related work
//! compares NVFP4 against ([21], MR-GPTQ's other target).
//!
//! Same E2M1 element grid as NVFP4, but:
//!   * blocks of **32** elements (vs 16),
//!   * block scales are **E8M0** (power-of-two only, 8-bit biased
//!     exponent) instead of FP8-E4M3 — no mantissa, so the scale itself
//!     quantizes much more coarsely,
//!   * no FP32 global scale.
//!
//! Exposed for the format-ablation experiment (`faar eval
//! --scale-method ...` comparisons and the `formats_ablation` harness):
//! it demonstrates *why* the paper targets NVFP4 — finer scale
//! granularity halves the block-quantization error for LLM-like weight
//! distributions.

use anyhow::{bail, Result};

use crate::formats::codec::{self, FormatKind, Parallelism, Prepared, QuantTensor};
use crate::formats::e2m1;
use crate::tensor::Tensor;

/// MXFP4 block size along the contraction axis (the OCP spec fixes 32).
pub const BLOCK: usize = 32;

/// Encode a positive raw scale to E8M0: the nearest power of two that
/// does not clip the block (ceil of log2), clamped to the E8M0 range
/// [2^-127, 2^127]. Returns (byte, decoded scale).
pub fn e8m0_encode_ceil(raw: f32) -> (u8, f32) {
    if raw <= 0.0 || !raw.is_finite() {
        // zero block: smallest scale, decodes fine since elements are 0
        return (0, 2.0f32.powi(-127));
    }
    let e = raw.log2().ceil();
    // guard numeric boundary: 2^(e-1) >= raw means e overshot by one
    let mut e = e as i32;
    if 2.0f32.powi(e - 1) >= raw {
        e -= 1;
    }
    let e = e.clamp(-127, 127);
    ((e + 127) as u8, 2.0f32.powi(e))
}

/// Decode an E8M0 byte: `2^(byte - 127)`.
pub fn e8m0_decode(byte: u8) -> f32 {
    2.0f32.powi(byte as i32 - 127)
}

/// Elementwise effective MXFP4 scales for `w[..., K, N]` (blocks of 32
/// along K, per column). Mirrors `nvfp4::standard_scales`' layout so the
/// two formats drop into the same quantizers.
pub fn mxfp4_scales(w: &Tensor) -> Tensor {
    let (k, n) = w.mat_dims().expect("rank >= 2");
    assert_eq!(k % BLOCK, 0, "K={k} not a multiple of {BLOCK}");
    let lead = w.lead();
    let slice_len = k * n;
    let mut scale = vec![0.0f32; w.numel()];
    for l in 0..lead {
        let ws = &w.data[l * slice_len..(l + 1) * slice_len];
        let out = &mut scale[l * slice_len..(l + 1) * slice_len];
        for kb in 0..k / BLOCK {
            for col in 0..n {
                let mut amax = 0.0f32;
                for r in 0..BLOCK {
                    amax = amax.max(ws[(kb * BLOCK + r) * n + col].abs());
                }
                let raw = amax / e2m1::FP4_MAX;
                let (_, s) = e8m0_encode_ceil(raw);
                let s = if amax == 0.0 { 0.0 } else { s };
                for r in 0..BLOCK {
                    out[(kb * BLOCK + r) * n + col] = s;
                }
            }
        }
    }
    Tensor::new(scale, w.shape.clone())
}

/// RTN fake-quant in MXFP4 (for the format-ablation comparison).
pub fn mxfp4_rtn_quant(w: &Tensor) -> Tensor {
    let scale = mxfp4_scales(w);
    let mut out = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let s = scale.data[i];
        if s > 0.0 {
            let wt = (w.data[i].abs() / s).min(e2m1::FP4_MAX);
            out[i] = crate::formats::nvfp4::sign(w.data[i])
                * e2m1::decode(e2m1::encode_rtn(wt))
                * s;
        }
    }
    Tensor::new(out, w.shape.clone())
}

// ---------------------------------------------------------------------------
// The MXFP4 FormatCodec implementation

/// The MXFP4 codec: 32-element E8M0 (power-of-two) block scales, no
/// global scale.
pub struct Mxfp4;

impl codec::FormatCodec for Mxfp4 {
    fn kind(&self) -> FormatKind {
        FormatKind::Mxfp4
    }

    fn block_size(&self) -> usize {
        BLOCK
    }

    fn prepare(&self, w: &Tensor) -> Prepared {
        let scale = mxfp4_scales(w);
        // no global scale level: 1.0 placeholders keep Prepared uniform
        let s_global = vec![1.0; w.lead()];
        codec::prepare_with_scales(w, scale, s_global)
    }

    fn encode(&self, w: &Tensor, p: &Prepared, v: &Tensor) -> QuantTensor {
        // scales were snapped by `mxfp4_scales`, so the one E8M0 mapping
        // (`e8m0_encode_ceil`) recovers each byte exactly; zero blocks
        // get byte 0 with all-zero codes
        QuantTensor {
            format: FormatKind::Mxfp4,
            shape: w.shape.clone(),
            codes: codec::pack_codes(w, p, v, Parallelism::Auto),
            scales: codec::block_scale_bytes(&p.scale, BLOCK, &|s_eff, _| {
                e8m0_encode_ceil(s_eff).0
            }),
            s_global: vec![],
        }
    }

    fn decode(&self, q: &QuantTensor) -> Result<Tensor> {
        if q.format != FormatKind::Mxfp4 {
            bail!("mxfp4 codec fed a {} tensor", q.format.name());
        }
        q.validate()?;
        let data = codec::unpack_block_scaled(
            &q.codes,
            &q.shape,
            BLOCK,
            &q.scales,
            &|byte, _| e8m0_decode(byte),
            Parallelism::Auto,
        )?;
        Ok(Tensor::new(data, q.shape.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::nvfp4;
    use crate::util::{rng::Rng, stats};

    fn rand_w(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, 0.05);
        t
    }

    #[test]
    fn e8m0_powers_of_two() {
        for e in [-10i32, -1, 0, 1, 7] {
            let v = 2.0f32.powi(e);
            let (byte, dec) = e8m0_encode_ceil(v);
            assert_eq!(dec, v, "exact power of two must round-trip");
            assert_eq!(e8m0_decode(byte), v);
        }
    }

    #[test]
    fn e8m0_ceil_never_clips() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let raw = (rng.f32() + 1e-6) * 10.0;
            let (_, s) = e8m0_encode_ceil(raw);
            assert!(s >= raw * 0.9999, "scale {s} clips raw {raw}");
            assert!(s < raw * 2.0001, "scale {s} over-covers raw {raw}");
        }
    }

    #[test]
    fn scales_block_structure_32() {
        let w = rand_w(&[64, 8], 2);
        let s = mxfp4_scales(&w);
        for col in 0..8 {
            for r in 1..32 {
                assert_eq!(s.data[r * 8 + col], s.data[col]);
            }
            assert_eq!(s.data[(32 + 1) * 8 + col], s.data[32 * 8 + col]);
        }
        // all scales are powers of two
        for &x in s.data.iter().filter(|x| **x > 0.0) {
            assert_eq!(x.log2().fract(), 0.0, "{x} not a power of two");
        }
    }

    #[test]
    fn zero_block_safe() {
        let w = Tensor::zeros(&[32, 4]);
        let q = mxfp4_rtn_quant(&w);
        assert!(q.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quantized_on_grid_and_bounded() {
        let w = rand_w(&[64, 16], 3);
        let q = mxfp4_rtn_quant(&w);
        let s = mxfp4_scales(&w);
        for i in 0..w.numel() {
            if s.data[i] > 0.0 {
                let wt = q.data[i].abs() / s.data[i];
                let near = e2m1::NODES.iter().map(|&n| (wt - n).abs()).fold(f32::MAX, f32::min);
                assert!(near < 1e-4);
            }
        }
    }

    #[test]
    fn codec_roundtrip_matches_rtn_quant() {
        use crate::formats::codec::{rtn_decisions, FormatCodec};
        let w = rand_w(&[64, 16], 7);
        let p = FormatCodec::prepare(&Mxfp4, &w);
        let q = Mxfp4.encode(&w, &p, &rtn_decisions(&p));
        assert_eq!(q.s_global.len(), 0, "mxfp4 has no global scale");
        assert_eq!(q.scales.len(), (64 / BLOCK) * 16);
        let deq = Mxfp4.decode(&q).unwrap();
        let expect = mxfp4_rtn_quant(&w);
        for i in 0..w.numel() {
            assert!(
                (deq.data[i] - expect.data[i]).abs() <= 1e-6 * expect.data[i].abs().max(1e-6),
                "i={i}: {} vs {}",
                deq.data[i],
                expect.data[i]
            );
        }
    }

    #[test]
    fn nvfp4_beats_mxfp4_on_gaussian_weights() {
        // the ablation behind the paper's format choice: E4M3 block
        // scales (16-elem) track local amax much tighter than power-of-
        // two 32-elem scales → lower RTN MSE
        let mut nv_wins = 0;
        for seed in 0..8 {
            let w = rand_w(&[128, 64], 10 + seed);
            let p = nvfp4::prepare(&w);
            let nv = stats::mse(&nvfp4::rtn_quant(&w, &p).data, &w.data);
            let mx = stats::mse(&mxfp4_rtn_quant(&w).data, &w.data);
            if nv < mx {
                nv_wins += 1;
            }
        }
        assert!(nv_wins >= 7, "NVFP4 only won {nv_wins}/8 trials");
    }
}
