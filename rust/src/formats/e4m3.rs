//! Software FP8 E4M3 (OCP "e4m3fn") codec.
//!
//! Layout: 1 sign, 4 exponent (bias 7), 3 mantissa. No infinities; the
//! all-ones code (S.1111.111) is NaN; max finite = ±448; subnormal step
//! 2^-9. Encoding uses round-to-nearest-even to match `ml_dtypes` /
//! `jnp.float8_e4m3fn` bit-for-bit (verified by the parity tests against
//! the AOT `prepare_*` artifacts, which embed jax's own conversion).

/// Largest finite E4M3 magnitude.
pub const E4M3_MAX: f32 = 448.0;
/// The (positive) E4M3 NaN code.
pub const E4M3_NAN: u8 = 0x7F;

/// Decode one E4M3 byte to f32 (exact — every finite code is an f32).
pub fn decode(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((code >> 3) & 0x0F) as i32;
    let man = (code & 0x07) as i32;
    if exp == 0x0F && man == 0x07 {
        return f32::NAN;
    }
    if exp == 0 {
        // subnormal: m/8 * 2^-6
        sign * (man as f32) * (1.0 / 8.0) * 2.0f32.powi(-6)
    } else {
        sign * (1.0 + man as f32 / 8.0) * 2.0f32.powi(exp - 7)
    }
}

/// Encode f32 to the nearest E4M3 code (round-to-nearest-even).
///
/// Overflow semantics match ml_dtypes: |x| >= 464 (the midpoint above the
/// max finite) becomes NaN; 448 < |x| < 464 rounds down to ±448.
pub fn encode(x: f32) -> u8 {
    if x.is_nan() {
        return E4M3_NAN;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return sign; // ±0
    }
    if a >= 464.0 {
        return sign | E4M3_NAN;
    }
    // Quantize in exact f64 arithmetic: pick the representable grid for
    // a's binade, then round-half-even on the integer grid index.
    let a64 = a as f64;
    let e = a64.log2().floor() as i32;
    // guard log2 boundary imprecision: ensure 2^e <= a < 2^(e+1)
    let e = if 2f64.powi(e) > a64 { e - 1 } else if 2f64.powi(e + 1) <= a64 { e + 1 } else { e };
    if e < -6 {
        // subnormal range: grid step 2^-9
        let q = rne(a64 / 2f64.powi(-9));
        if q == 0 {
            return sign; // underflow to zero
        }
        if q <= 7 {
            return sign | q as u8;
        }
        // rounded up into the first normal binade
        return sign | 0x08;
    }
    let e = e.min(8);
    // normal: mantissa grid step 2^(e-3); index in [8, 16]
    let q = rne(a64 / 2f64.powi(e - 3));
    let (e, q) = if q >= 16 { (e + 1, 8) } else { (e, q) };
    if e > 8 {
        return sign | E4M3_NAN; // can't happen for a < 464, kept for safety
    }
    if e == 8 && q == 15 {
        // 480 is not representable; nearest finite is 448
        return sign | ((15u8) << 3) | 6;
    }
    let exp_bits = (e + 7) as u8;
    let man_bits = (q - 8) as u8;
    sign | (exp_bits << 3) | man_bits
}

/// f32 -> E4M3 -> f32 (the "effective value" the hardware sees).
pub fn roundtrip(x: f32) -> f32 {
    decode(encode(x))
}

/// The 256-entry decode LUT (code → f32), built once per process — the
/// bulk decode path below and the e4m3 KV cache read through this
/// instead of re-deriving the bit fields per element.
pub fn decode_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut lut = [0.0f32; 256];
        for (code, v) in lut.iter_mut().enumerate() {
            *v = decode(code as u8);
        }
        lut
    })
}

/// Bulk encode with **saturation**: every element of `x` becomes its
/// nearest E4M3 code in `out`, except that magnitudes past the finite
/// range clamp to ±448 instead of the scalar [`encode`]'s NaN — the
/// right overflow semantics for a KV cache, where one outlier
/// activation must not poison a whole attention row. NaN inputs still
/// encode to NaN (the value is already meaningless).
pub fn encode_slice(x: &[f32], out: &mut [u8]) {
    assert_eq!(x.len(), out.len(), "encode_slice length mismatch");
    for (o, &v) in out.iter_mut().zip(x) {
        *o = if v > E4M3_MAX {
            0x7E // +448
        } else if v < -E4M3_MAX {
            0xFE // -448
        } else {
            encode(v)
        };
    }
}

/// Bulk decode through [`decode_lut`]: `out[i] = decode(bytes[i])`.
pub fn decode_slice(bytes: &[u8], out: &mut [f32]) {
    assert_eq!(bytes.len(), out.len(), "decode_slice length mismatch");
    let lut = decode_lut();
    for (o, &b) in out.iter_mut().zip(bytes) {
        *o = lut[b as usize];
    }
}

/// Round-half-even to u64 for non-negative x.
fn rne(x: f64) -> u64 {
    let f = x.floor();
    let frac = x - f;
    let base = f as u64;
    if frac > 0.5 {
        base + 1
    } else if frac < 0.5 {
        base
    } else if base % 2 == 0 {
        base
    } else {
        base + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_known_codes() {
        assert_eq!(decode(0x00), 0.0);
        assert!(decode(0x80) == 0.0 && decode(0x80).is_sign_negative());
        assert_eq!(decode(0x38), 1.0); // e=7, m=0
        assert_eq!(decode(0x3C), 1.5);
        assert_eq!(decode(0x7E), 448.0); // max finite
        assert_eq!(decode(0x01), 2.0f32.powi(-9)); // min subnormal
        assert_eq!(decode(0x08), 2.0f32.powi(-6)); // min normal
        assert!(decode(0x7F).is_nan());
        assert!(decode(0xFF).is_nan());
        assert_eq!(decode(0xBC), -1.5);
    }

    #[test]
    fn all_finite_codes_roundtrip() {
        for code in 0u16..=255 {
            let code = code as u8;
            let v = decode(code);
            if v.is_nan() {
                continue;
            }
            let back = encode(v);
            // -0 encodes to 0x80; +0 to 0x00; otherwise exact
            assert_eq!(
                decode(back), v,
                "code {code:#04x} -> {v} -> {back:#04x}"
            );
        }
    }

    #[test]
    fn rne_behaviour() {
        // 1.0625 is halfway between 1.0 (m=0, even) and 1.125 (m=1, odd)
        assert_eq!(roundtrip(1.0625), 1.0);
        // 1.1875 is halfway between 1.125 (odd) and 1.25 (even)
        assert_eq!(roundtrip(1.1875), 1.25);
        assert_eq!(roundtrip(1.1), 1.125);
    }

    #[test]
    fn overflow_rules() {
        assert_eq!(roundtrip(448.0), 448.0);
        assert_eq!(roundtrip(455.0), 448.0);
        assert_eq!(roundtrip(463.9), 448.0);
        assert!(roundtrip(464.0).is_nan());
        assert!(roundtrip(1e30).is_nan());
        assert_eq!(roundtrip(-450.0), -448.0);
    }

    #[test]
    fn subnormals() {
        let step = 2.0f32.powi(-9);
        assert_eq!(roundtrip(step), step);
        assert_eq!(roundtrip(3.0 * step), 3.0 * step);
        assert_eq!(roundtrip(0.4 * step), 0.0);
        assert_eq!(roundtrip(0.6 * step), step);
        // halfway between 0 and step -> even (0)
        assert_eq!(roundtrip(0.5 * step), 0.0);
        // halfway between step and 2*step -> even (2*step)
        assert_eq!(roundtrip(1.5 * step), 2.0 * step);
        // subnormal rounds up into first normal
        let min_normal = 2.0f32.powi(-6);
        assert_eq!(roundtrip(min_normal - 0.01 * step), min_normal);
    }

    #[test]
    fn monotone_on_positives() {
        // encoding is monotone: decode(encode(x)) is non-decreasing in x
        let mut prev = 0.0f32;
        let mut x = 1e-10f32;
        while x < 500.0 {
            let r = roundtrip(x);
            if !r.is_nan() {
                assert!(r >= prev, "x={x} r={r} prev={prev}");
                prev = r;
            }
            x *= 1.01;
        }
    }

    #[test]
    fn error_within_half_ulp() {
        let mut x = 0.001f32;
        while x < 448.0 {
            let r = roundtrip(x);
            let e = x.log2().floor() as i32;
            let ulp = if e < -6 { 2.0f32.powi(-9) } else { 2.0f32.powi(e - 3) };
            assert!((r - x).abs() <= ulp / 2.0 + 1e-12, "x={x} r={r} ulp={ulp}");
            x *= 1.37;
        }
    }
}
