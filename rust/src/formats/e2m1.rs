//! Software FP4 E2M1 codec — the NVFP4 element type.
//!
//! Layout: 1 sign, 2 exponent, 1 mantissa. 16 codes decoding to
//! ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}. No NaN/Inf. Two zeros (±0).
//!
//! `encode_rtn` rounds to the nearest node with **ties toward the lower
//! node** — the project-wide tie rule shared with python (ref.py) and the
//! rust quantizers (DESIGN.md §7).

/// Positive node values indexed by the 3 magnitude bits (exp<<1 | man).
pub const NODES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
/// Largest representable E2M1 magnitude.
pub const FP4_MAX: f32 = 6.0;

/// Decode a 4-bit code (low nibble) to f32.
pub fn decode(code: u8) -> f32 {
    let mag = NODES[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

/// Index of the largest node <= wt (wt >= 0, clamped to the grid).
pub fn lower_idx(wt: f32) -> usize {
    if wt >= 6.0 {
        7
    } else if wt >= 4.0 {
        6
    } else if wt >= 3.0 {
        5
    } else if wt >= 2.0 {
        4
    } else if wt >= 1.5 {
        3
    } else if wt >= 1.0 {
        2
    } else if wt >= 0.5 {
        1
    } else {
        0
    }
}

/// Index of the smallest node >= wt (wt in [0, 6]).
pub fn upper_idx(wt: f32) -> usize {
    if wt <= 0.0 {
        0
    } else if wt <= 0.5 {
        1
    } else if wt <= 1.0 {
        2
    } else if wt <= 1.5 {
        3
    } else if wt <= 2.0 {
        4
    } else if wt <= 3.0 {
        5
    } else if wt <= 4.0 {
        6
    } else {
        7
    }
}

/// (lower, upper) nodes enclosing the normalized magnitude.
pub fn interval(wt: f32) -> (f32, f32) {
    let wt = wt.clamp(0.0, FP4_MAX);
    (NODES[lower_idx(wt)], NODES[upper_idx(wt)])
}

/// Encode a normalized value (already divided by scales) to the nearest
/// node, ties toward lower. Returns the 4-bit code.
pub fn encode_rtn(x: f32) -> u8 {
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let wt = x.abs().min(FP4_MAX);
    let (li, ui) = (lower_idx(wt), upper_idx(wt));
    let (lo, up) = (NODES[li], NODES[ui]);
    let idx = if wt - lo > up - wt { ui } else { li };
    sign | idx as u8
}

/// Encode picking lower (`v = 0`) or upper (`v = 1`) explicitly — the
/// hardened FAAR decision path.
pub fn encode_choice(x: f32, pick_upper: bool) -> u8 {
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let wt = x.abs().min(FP4_MAX);
    let idx = if pick_upper { upper_idx(wt) } else { lower_idx(wt) };
    sign | idx as u8
}

/// Pack a slice of 4-bit codes, two per byte (low nibble first).
pub fn pack(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = pair[0] & 0x0F;
        let hi = if pair.len() > 1 { pair[1] & 0x0F } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack `n` 4-bit codes from packed bytes.
pub fn unpack(bytes: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for (i, &b) in bytes.iter().enumerate() {
        out.push(b & 0x0F);
        if 2 * i + 1 < n {
            out.push(b >> 4);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_all_codes() {
        let expect = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        for i in 0..8 {
            assert_eq!(decode(i), expect[i as usize]);
            assert_eq!(decode(i | 0x8), -expect[i as usize]);
        }
    }

    #[test]
    fn encode_exact_nodes() {
        for (i, &n) in NODES.iter().enumerate() {
            assert_eq!(encode_rtn(n) as usize, i);
            if n > 0.0 {
                assert_eq!(encode_rtn(-n) as usize, i | 0x8);
            }
        }
    }

    #[test]
    fn rtn_ties_to_lower() {
        for w in [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0] {
            let code = encode_rtn(w);
            let (lo, _) = interval(w);
            assert_eq!(decode(code), lo, "tie at {w} must go down");
        }
    }

    #[test]
    fn rtn_nearest_otherwise() {
        assert_eq!(decode(encode_rtn(0.26)), 0.5);
        assert_eq!(decode(encode_rtn(0.24)), 0.0);
        assert_eq!(decode(encode_rtn(5.1)), 6.0);
        assert_eq!(decode(encode_rtn(4.9)), 4.0);
        assert_eq!(decode(encode_rtn(-2.6)), -3.0);
    }

    #[test]
    fn saturates() {
        assert_eq!(decode(encode_rtn(100.0)), 6.0);
        assert_eq!(decode(encode_rtn(-100.0)), -6.0);
    }

    #[test]
    fn interval_encloses() {
        let mut wt = 0.0f32;
        while wt <= 6.0 {
            let (lo, up) = interval(wt);
            assert!(lo <= wt && wt <= up, "wt={wt} lo={lo} up={up}");
            wt += 0.01;
        }
    }

    #[test]
    fn interval_degenerate_at_nodes() {
        for &n in &NODES {
            assert_eq!(interval(n), (n, n));
        }
    }

    #[test]
    fn encode_choice_paths() {
        assert_eq!(decode(encode_choice(0.7, false)), 0.5);
        assert_eq!(decode(encode_choice(0.7, true)), 1.0);
        assert_eq!(decode(encode_choice(-0.7, true)), -1.0);
        // at a node, both choices agree
        assert_eq!(decode(encode_choice(2.0, true)), 2.0);
        assert_eq!(decode(encode_choice(2.0, false)), 2.0);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..31).map(|i| (i % 16) as u8).collect();
        let packed = pack(&codes);
        assert_eq!(packed.len(), 16);
        assert_eq!(unpack(&packed, 31), codes);
        // even count
        let codes2: Vec<u8> = (0..16).map(|i| i as u8).collect();
        assert_eq!(unpack(&pack(&codes2), 16), codes2);
        // empty
        assert!(pack(&[]).is_empty());
        assert!(unpack(&[], 0).is_empty());
    }
}
