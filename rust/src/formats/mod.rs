//! Software codecs for the NVFP4 format family.
//!
//! * [`e4m3`] — FP8 E4M3 (block-scale storage type)
//! * [`e2m1`] — FP4 E2M1 (element type; the non-uniform node grid the
//!   paper's whole argument is about)
//! * [`nvfp4`] — the two-level block format: pack/unpack, prepare
//!   (FindInterval + v_init), RTN/hard quantization

pub mod e2m1;
pub mod e4m3;
pub mod mxfp4;
pub mod nvfp4;

pub use e2m1::{FP4_MAX, NODES};
pub use e4m3::E4M3_MAX;
pub use nvfp4::{prepare, standard_scales, PackedTensor, Prepared, BLOCK};
