//! Software codecs for the 4-bit block-format family.
//!
//! * [`codec`] — the format layer: the [`codec::FormatCodec`] trait, the
//!   packed [`codec::QuantTensor`] (the canonical quantized
//!   representation across the stack) and the shared interval machinery
//! * [`e4m3`] — FP8 E4M3 (NVFP4's block-scale storage type)
//! * [`e2m1`] — FP4 E2M1 (element type; the non-uniform node grid the
//!   paper's whole argument is about)
//! * [`nvfp4`] — the two-level NVFP4 block format + its codec impl
//! * [`mxfp4`] — OCP MXFP4 (32-elem power-of-two scales) + its codec impl

pub mod codec;
pub mod e2m1;
pub mod e4m3;
pub mod mxfp4;
pub mod nvfp4;

pub use codec::{codec_for, FormatCodec, FormatKind, Prepared, QuantTensor};
pub use e2m1::{FP4_MAX, NODES};
pub use e4m3::E4M3_MAX;
pub use nvfp4::{prepare, standard_scales, PackedTensor, BLOCK};
