//! NVFP4 block codec: two-level scaling + E2M1 elements, bit-faithful to
//! the python oracle (`python/compile/kernels/ref.py`) and to what NVFP4
//! hardware would consume.
//!
//! Layout for a weight tensor `[..., K, N]` (K = contraction axis):
//!   * blocks of 16 consecutive K-elements per output column share one
//!     FP8-E4M3 scale (stored relative to the global scale),
//!   * one FP32 global scale per tensor (per leading slice for stacked
//!     `[L, K, N]` weights),
//!   * elements are 4-bit E2M1 codes packed two per byte.
//!
//! `prepare` reproduces ref.quant_prepare exactly (same f32 op order), so
//! rust-side scale/interval math agrees with the AOT graphs — enforced by
//! integration tests against the `prepare_*` artifacts.

use anyhow::{bail, Result};

use super::{e2m1, e4m3};
use crate::tensor::Tensor;

pub const BLOCK: usize = 16;

/// Elementwise quantization context for FAAR / baselines:
/// lower/upper nodes, effective scale, and the paper's v_init.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub lower: Tensor,
    pub upper: Tensor,
    pub scale: Tensor,
    pub v_init: Tensor,
    /// per leading-slice global scale
    pub s_global: Vec<f32>,
}

/// Compute the effective elementwise scale tensor for `w[..., K, N]`
/// given a per-(slice, block, column) raw scale chooser.
///
/// `raw_scale(slice, amax_block)` returns the *pre-E4M3* block scale; the
/// default NVFP4 recipe is `amax / 6`. The 4/6 and strong-baseline
/// methods pass different choosers (see quant/scaling.rs).
pub fn effective_scales(
    w: &Tensor,
    raw_scale: impl Fn(usize, usize, usize, f32) -> f32,
) -> (Tensor, Vec<f32>) {
    let (k, n) = w.mat_dims().expect("weights must be rank >= 2");
    assert_eq!(k % BLOCK, 0, "K={k} not a multiple of {BLOCK}");
    let lead = w.lead();
    let slice_len = k * n;
    let mut scale = vec![0.0f32; w.numel()];
    let mut s_globals = Vec::with_capacity(lead);

    for l in 0..lead {
        let ws = &w.data[l * slice_len..(l + 1) * slice_len];
        let amax_tot = ws.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let s_g = (amax_tot / (e2m1::FP4_MAX * e4m3::E4M3_MAX)).max(1e-30);
        s_globals.push(s_g);
        let out = &mut scale[l * slice_len..(l + 1) * slice_len];
        for kb in 0..k / BLOCK {
            for col in 0..n {
                let mut amax = 0.0f32;
                for r in 0..BLOCK {
                    amax = amax.max(ws[(kb * BLOCK + r) * n + col].abs());
                }
                let raw = raw_scale(l, kb, col, amax);
                let s_eff = e4m3::roundtrip(raw / s_g) * s_g;
                for r in 0..BLOCK {
                    out[(kb * BLOCK + r) * n + col] = s_eff;
                }
            }
        }
    }
    (Tensor::new(scale, w.shape.clone()), s_globals)
}

/// Standard NVFP4 scale recipe: `amax_block / 6`.
pub fn standard_scales(w: &Tensor) -> (Tensor, Vec<f32>) {
    effective_scales(w, |_, _, _, amax| amax / e2m1::FP4_MAX)
}

/// Full FAAR preparation from raw weights using given elementwise scales.
pub fn prepare_with_scales(w: &Tensor, scale: Tensor, s_global: Vec<f32>) -> Prepared {
    let mut lower = vec![0.0f32; w.numel()];
    let mut upper = vec![0.0f32; w.numel()];
    let mut v_init = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let s = scale.data[i];
        let wt = if s > 0.0 {
            (w.data[i].abs() / s.max(1e-30)).clamp(0.0, e2m1::FP4_MAX)
        } else {
            0.0
        };
        let (lo, up) = e2m1::interval(wt);
        lower[i] = lo;
        upper[i] = up;
        let width = up - lo;
        v_init[i] = if width > 0.0 { (wt - lo) / width.max(1e-30) } else { 0.5 };
    }
    Prepared {
        lower: Tensor::new(lower, w.shape.clone()),
        upper: Tensor::new(upper, w.shape.clone()),
        scale,
        v_init: Tensor::new(v_init, w.shape.clone()),
        s_global,
    }
}

/// Standard NVFP4 preparation (ref.quant_prepare equivalent).
pub fn prepare(w: &Tensor) -> Prepared {
    let (scale, s_global) = standard_scales(w);
    prepare_with_scales(w, scale, s_global)
}

/// Dequantized weights for hardened binary decisions `v` (>= 0.5 → upper).
pub fn hard_quant(w: &Tensor, p: &Prepared, v: &Tensor) -> Tensor {
    assert_eq!(w.shape, v.shape);
    let mut out = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let node = if v.data[i] >= 0.5 { p.upper.data[i] } else { p.lower.data[i] };
        out[i] = sign(w.data[i]) * node * p.scale.data[i];
    }
    Tensor::new(out, w.shape.clone())
}

/// Dequantized RTN weights (nearest node, ties → lower). Equivalent to
/// hardening `v_init > 0.5`.
pub fn rtn_quant(w: &Tensor, p: &Prepared) -> Tensor {
    let mut out = vec![0.0f32; w.numel()];
    for i in 0..w.numel() {
        let up = p.v_init.data[i] > 0.5;
        let node = if up { p.upper.data[i] } else { p.lower.data[i] };
        out[i] = sign(w.data[i]) * node * p.scale.data[i];
    }
    Tensor::new(out, w.shape.clone())
}

#[inline]
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Packed on-disk representation (deployable NVFP4 payload)

/// A tensor in true packed NVFP4: 4-bit codes + E4M3 block scales + FP32
/// global scale(s). This is the artifact `faar quantize` writes to disk —
/// 4.25 bits/weight + one f32 per slice, exactly what NVFP4 hardware
/// would consume.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    pub shape: Vec<usize>,
    /// packed E2M1 codes, two per byte, row-major
    pub codes: Vec<u8>,
    /// E4M3-encoded block scales, [lead, K/16, N] row-major
    pub scales: Vec<u8>,
    /// per leading-slice FP32 global scale
    pub s_global: Vec<f32>,
}

impl PackedTensor {
    /// Pack from raw weights + prepared context + (possibly learned)
    /// binary decisions. `v` >= 0.5 picks the upper node.
    pub fn pack(w: &Tensor, p: &Prepared, v: &Tensor) -> PackedTensor {
        let (k, n) = w.mat_dims().unwrap();
        let lead = w.lead();
        let slice_len = k * n;
        let mut codes4 = Vec::with_capacity(w.numel());
        let mut scales = Vec::with_capacity(lead * (k / BLOCK) * n);
        for l in 0..lead {
            let s_g = p.s_global[l];
            for kb in 0..k / BLOCK {
                for col in 0..n {
                    let s_eff = p.scale.data[l * slice_len + (kb * BLOCK) * n + col];
                    scales.push(e4m3::encode(s_eff / s_g));
                }
            }
        }
        for i in 0..w.numel() {
            let wt = if p.scale.data[i] > 0.0 {
                (w.data[i].abs() / p.scale.data[i].max(1e-30)).clamp(0.0, e2m1::FP4_MAX)
            } else {
                0.0
            };
            let x = if w.data[i] < 0.0 { -wt } else { wt };
            codes4.push(e2m1::encode_choice(x, v.data[i] >= 0.5));
        }
        PackedTensor {
            shape: w.shape.clone(),
            codes: e2m1::pack(&codes4),
            scales,
            s_global: p.s_global.clone(),
        }
    }

    /// Dequantize to f32 (what the PJRT graphs consume).
    pub fn unpack(&self) -> Tensor {
        let t = Tensor::zeros(&self.shape);
        let (k, n) = t.mat_dims().unwrap();
        let lead = t.lead();
        let slice_len = k * n;
        let codes = e2m1::unpack(&self.codes, lead * slice_len);
        let mut data = vec![0.0f32; lead * slice_len];
        let sc_cols = n;
        let sc_rows = k / BLOCK;
        for l in 0..lead {
            let s_g = self.s_global[l];
            for row in 0..k {
                let kb = row / BLOCK;
                for col in 0..n {
                    let idx = l * slice_len + row * n + col;
                    let s_eff =
                        e4m3::decode(self.scales[l * sc_rows * sc_cols + kb * sc_cols + col]) * s_g;
                    data[idx] = e2m1::decode(codes[idx]) * s_eff;
                }
            }
        }
        Tensor::new(data, self.shape.clone())
    }

    /// Payload bytes (codes + scales + globals) — the memory-footprint
    /// number reported in EXPERIMENTS.md.
    pub fn payload_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + self.s_global.len() * 4
    }

    /// Serialize to the `.nvfp4` container: magic, rank, dims, globals,
    /// scales, codes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.payload_bytes() + 64);
        buf.extend_from_slice(b"NVF4");
        buf.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(self.s_global.len() as u32).to_le_bytes());
        for &g in &self.s_global {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        buf.extend_from_slice(&(self.scales.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.scales);
        buf.extend_from_slice(&(self.codes.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.codes);
        buf
    }

    pub fn from_bytes(buf: &[u8]) -> Result<PackedTensor> {
        if buf.len() < 8 || &buf[..4] != b"NVF4" {
            bail!("not an NVF4 payload");
        }
        let mut off = 4;
        let rd_u32 = |o: &mut usize| -> Result<u32> {
            let v = u32::from_le_bytes(buf[*o..*o + 4].try_into()?);
            *o += 4;
            Ok(v)
        };
        let rd_u64 = |o: &mut usize| -> Result<u64> {
            let v = u64::from_le_bytes(buf[*o..*o + 8].try_into()?);
            *o += 8;
            Ok(v)
        };
        let rank = rd_u32(&mut off)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(rd_u64(&mut off)? as usize);
        }
        let ng = rd_u32(&mut off)? as usize;
        let mut s_global = Vec::with_capacity(ng);
        for _ in 0..ng {
            s_global.push(f32::from_le_bytes(buf[off..off + 4].try_into()?));
            off += 4;
        }
        let ns = rd_u64(&mut off)? as usize;
        let scales = buf[off..off + ns].to_vec();
        off += ns;
        let nc = rd_u64(&mut off)? as usize;
        if buf.len() < off + nc {
            bail!("truncated NVF4 payload");
        }
        let codes = buf[off..off + nc].to_vec();
        Ok(PackedTensor { shape, codes, scales, s_global })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    #[test]
    fn prepare_invariants() {
        let w = rand_w(&[64, 32], 1, 0.05);
        let p = prepare(&w);
        for i in 0..w.numel() {
            assert!(p.lower.data[i] <= p.upper.data[i]);
            assert!((0.0..=1.0).contains(&p.v_init.data[i]), "v_init oob");
            assert!(p.scale.data[i] >= 0.0);
            assert!(e2m1::NODES.contains(&p.lower.data[i]));
            assert!(e2m1::NODES.contains(&p.upper.data[i]));
        }
    }

    #[test]
    fn scale_block_structure() {
        let w = rand_w(&[32, 8], 2, 0.1);
        let (s, sg) = standard_scales(&w);
        assert_eq!(sg.len(), 1);
        // constant within a 16-block per column
        for col in 0..8 {
            for r in 1..16 {
                assert_eq!(s.data[r * 8 + col], s.data[col]);
                assert_eq!(s.data[(16 + r) * 8 + col], s.data[16 * 8 + col]);
            }
        }
    }

    #[test]
    fn stacked_slices_independent_globals() {
        let mut w = rand_w(&[2, 32, 8], 3, 0.05);
        // second slice much larger magnitudes
        for x in &mut w.data[32 * 8..] {
            *x *= 100.0;
        }
        let p = prepare(&w);
        assert_eq!(p.s_global.len(), 2);
        assert!(p.s_global[1] > p.s_global[0] * 50.0);
    }

    #[test]
    fn zero_block_safe() {
        let mut w = rand_w(&[32, 4], 4, 0.05);
        for col in 0..4 {
            for r in 0..16 {
                w.data[r * 4 + col] = 0.0;
            }
        }
        let p = prepare(&w);
        for col in 0..4 {
            assert_eq!(p.scale.data[col], 0.0);
            assert_eq!(p.v_init.data[col], 0.5); // degenerate interval
        }
        let q = rtn_quant(&w, &p);
        assert!(q.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rtn_equals_hard_of_vinit_threshold() {
        let w = rand_w(&[64, 16], 5, 0.05);
        let p = prepare(&w);
        let v_rtn = p.v_init.map(|v| if v > 0.5 { 1.0 } else { 0.0 });
        let a = rtn_quant(&w, &p);
        let b = hard_quant(&w, &p, &v_rtn);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn hard_quant_on_grid() {
        let w = rand_w(&[64, 16], 6, 0.2);
        let p = prepare(&w);
        let q = hard_quant(&w, &p, &p.v_init);
        for i in 0..q.numel() {
            if p.scale.data[i] > 0.0 {
                let wt = q.data[i].abs() / p.scale.data[i];
                let nearest =
                    e2m1::NODES.iter().map(|&n| (wt - n).abs()).fold(f32::INFINITY, f32::min);
                assert!(nearest < 1e-4, "off grid: {wt}");
            }
        }
    }

    #[test]
    fn rtn_minimizes_elementwise_error() {
        let w = rand_w(&[64, 16], 7, 0.1);
        let p = prepare(&w);
        let q_rtn = rtn_quant(&w, &p);
        let q_lo = hard_quant(&w, &p, &Tensor::zeros(&w.shape));
        let q_up = hard_quant(&w, &p, &Tensor::full(&w.shape, 1.0));
        for i in 0..w.numel() {
            let e = (q_rtn.data[i] - w.data[i]).abs();
            assert!(e <= (q_lo.data[i] - w.data[i]).abs() + 1e-6);
            assert!(e <= (q_up.data[i] - w.data[i]).abs() + 1e-6);
        }
    }

    #[test]
    fn pack_unpack_matches_hard_quant() {
        let w = rand_w(&[2, 32, 16], 8, 0.05);
        let p = prepare(&w);
        let v = p.v_init.map(|v| if v >= 0.5 { 1.0 } else { 0.0 });
        let packed = PackedTensor::pack(&w, &p, &v);
        let deq = packed.unpack();
        let expect = hard_quant(&w, &p, &v);
        for i in 0..w.numel() {
            assert!(
                (deq.data[i] - expect.data[i]).abs() <= 1e-6 * expect.data[i].abs().max(1e-6),
                "i={i}: {} vs {}",
                deq.data[i],
                expect.data[i]
            );
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let w = rand_w(&[32, 16], 9, 0.05);
        let p = prepare(&w);
        let packed = PackedTensor::pack(&w, &p, &p.v_init);
        let back = PackedTensor::from_bytes(&packed.to_bytes()).unwrap();
        assert_eq!(packed, back);
        assert!(PackedTensor::from_bytes(b"junk").is_err());
    }

    #[test]
    fn payload_is_4_25_bits_per_weight() {
        let w = rand_w(&[128, 64], 10, 0.05);
        let p = prepare(&w);
        let packed = PackedTensor::pack(&w, &p, &p.v_init);
        let bits = packed.payload_bytes() as f64 * 8.0 / w.numel() as f64;
        // 4 bits/code + 8 bits per 16-element block = 4.5 bits + f32 global
        assert!((4.4..4.7).contains(&bits), "bits/weight = {bits}");
    }
}
