//! NVFP4 block codec: two-level scaling + E2M1 elements, bit-faithful to
//! the python oracle (`python/compile/kernels/ref.py`) and to what NVFP4
//! hardware would consume.
//!
//! Layout for a weight tensor `[..., K, N]` (K = contraction axis):
//!   * blocks of 16 consecutive K-elements per output column share one
//!     FP8-E4M3 scale (stored relative to the global scale),
//!   * one FP32 global scale per tensor (per leading slice for stacked
//!     `[L, K, N]` weights),
//!   * elements are 4-bit E2M1 codes packed two per byte.
//!
//! This module owns the NVFP4 *scale recipes* (`standard_scales`,
//! `effective_scales`) and the [`Nvfp4`] implementation of
//! [`codec::FormatCodec`]; the format-agnostic interval machinery
//! ([`Prepared`], [`prepare_with_scales`], [`hard_quant`], [`rtn_quant`])
//! lives in [`super::codec`] and is re-exported here for compatibility.
//!
//! `prepare` reproduces ref.quant_prepare exactly (same f32 op order), so
//! rust-side scale/interval math agrees with the AOT graphs — enforced by
//! integration tests against the `prepare_*` artifacts.

use anyhow::{bail, Result};

use super::codec::{self, FormatKind, Parallelism, QuantTensor};
use super::{e2m1, e4m3};
use crate::tensor::Tensor;

pub use super::codec::{hard_quant, prepare_with_scales, rtn_quant, sign, Prepared};

/// NVFP4 block size along the contraction axis (the format fixes 16).
pub const BLOCK: usize = 16;

/// Compute the effective elementwise scale tensor for `w[..., K, N]`
/// given a per-(slice, block, column) raw scale chooser.
///
/// `raw_scale(slice, amax_block)` returns the *pre-E4M3* block scale; the
/// default NVFP4 recipe is `amax / 6`. The 4/6 and strong-baseline
/// methods pass different choosers (see quant/scaling.rs).
pub fn effective_scales(
    w: &Tensor,
    raw_scale: impl Fn(usize, usize, usize, f32) -> f32,
) -> (Tensor, Vec<f32>) {
    let (k, n) = w.mat_dims().expect("weights must be rank >= 2");
    assert_eq!(k % BLOCK, 0, "K={k} not a multiple of {BLOCK}");
    let lead = w.lead();
    let slice_len = k * n;
    let mut scale = vec![0.0f32; w.numel()];
    let mut s_globals = Vec::with_capacity(lead);

    for l in 0..lead {
        let ws = &w.data[l * slice_len..(l + 1) * slice_len];
        let amax_tot = ws.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let s_g = (amax_tot / (e2m1::FP4_MAX * e4m3::E4M3_MAX)).max(1e-30);
        s_globals.push(s_g);
        let out = &mut scale[l * slice_len..(l + 1) * slice_len];
        for kb in 0..k / BLOCK {
            for col in 0..n {
                let mut amax = 0.0f32;
                for r in 0..BLOCK {
                    amax = amax.max(ws[(kb * BLOCK + r) * n + col].abs());
                }
                let raw = raw_scale(l, kb, col, amax);
                let s_eff = e4m3::roundtrip(raw / s_g) * s_g;
                for r in 0..BLOCK {
                    out[(kb * BLOCK + r) * n + col] = s_eff;
                }
            }
        }
    }
    (Tensor::new(scale, w.shape.clone()), s_globals)
}

/// Standard NVFP4 scale recipe: `amax_block / 6`.
pub fn standard_scales(w: &Tensor) -> (Tensor, Vec<f32>) {
    effective_scales(w, |_, _, _, amax| amax / e2m1::FP4_MAX)
}

/// Standard NVFP4 preparation (ref.quant_prepare equivalent).
pub fn prepare(w: &Tensor) -> Prepared {
    let (scale, s_global) = standard_scales(w);
    prepare_with_scales(w, scale, s_global)
}

// ---------------------------------------------------------------------------
// The NVFP4 FormatCodec implementation

/// The NVFP4 codec: 16-element E4M3 block scales over an fp32 global.
pub struct Nvfp4;

impl codec::FormatCodec for Nvfp4 {
    fn kind(&self) -> FormatKind {
        FormatKind::Nvfp4
    }

    fn block_size(&self) -> usize {
        BLOCK
    }

    fn prepare(&self, w: &Tensor) -> Prepared {
        prepare(w)
    }

    fn encode(&self, w: &Tensor, p: &Prepared, v: &Tensor) -> QuantTensor {
        self.encode_mode(w, p, v, Parallelism::Auto)
    }

    fn decode(&self, q: &QuantTensor) -> Result<Tensor> {
        self.decode_mode(q, Parallelism::Auto)
    }
}

impl Nvfp4 {
    /// Encode with an explicit parallelism policy (benchmarking; the
    /// trait method uses `Auto`).
    pub fn encode_mode(
        &self,
        w: &Tensor,
        p: &Prepared,
        v: &Tensor,
        par: Parallelism,
    ) -> QuantTensor {
        QuantTensor {
            format: FormatKind::Nvfp4,
            shape: w.shape.clone(),
            codes: codec::pack_codes(w, p, v, par),
            scales: codec::nvfp4_scale_bytes(&p.scale, &p.s_global),
            s_global: p.s_global.clone(),
        }
    }

    /// Decode with an explicit parallelism policy.
    pub fn decode_mode(&self, q: &QuantTensor, par: Parallelism) -> Result<Tensor> {
        if q.format != FormatKind::Nvfp4 {
            bail!("nvfp4 codec fed a {} tensor", q.format.name());
        }
        q.validate()?;
        let s_global = &q.s_global;
        let data = codec::unpack_block_scaled(
            &q.codes,
            &q.shape,
            BLOCK,
            &q.scales,
            &|byte, l| e4m3::decode(byte) * s_global[l],
            par,
        )?;
        Ok(Tensor::new(data, q.shape.clone()))
    }
}

// ---------------------------------------------------------------------------
// Packed on-disk representation (deployable NVFP4 payload)

/// A tensor in true packed NVFP4: 4-bit codes + E4M3 block scales + FP32
/// global scale(s) — 4.5 bits/weight + one f32 per slice, exactly what
/// NVFP4 hardware would consume. Kept as the legacy `.nvfp4` (`NVF4`)
/// container type; [`codec::QuantTensor`] is the format-tagged
/// generalization the pipeline carries in memory.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    /// logical tensor shape (`[..., K, N]`)
    pub shape: Vec<usize>,
    /// packed E2M1 codes, two per byte, row-major
    pub codes: Vec<u8>,
    /// E4M3-encoded block scales, [lead, K/16, N] row-major
    pub scales: Vec<u8>,
    /// per leading-slice FP32 global scale
    pub s_global: Vec<f32>,
}

impl PackedTensor {
    /// Pack from raw weights + prepared context + (possibly learned)
    /// binary decisions. `v` >= 0.5 picks the upper node.
    pub fn pack(w: &Tensor, p: &Prepared, v: &Tensor) -> PackedTensor {
        let q = Nvfp4.encode_mode(w, p, v, Parallelism::Auto);
        PackedTensor { shape: q.shape, codes: q.codes, scales: q.scales, s_global: q.s_global }
    }

    /// Convert into a format-tagged [`QuantTensor`] (same payload
    /// layout; the code/scale vectors are cloned).
    pub fn to_quant(&self) -> QuantTensor {
        QuantTensor {
            format: FormatKind::Nvfp4,
            shape: self.shape.clone(),
            codes: self.codes.clone(),
            scales: self.scales.clone(),
            s_global: self.s_global.clone(),
        }
    }

    /// Dequantize to f32 (what the PJRT graphs consume). Decodes by
    /// borrowing the payload — no intermediate copy.
    pub fn unpack(&self) -> Tensor {
        let s_global = &self.s_global;
        let data = codec::unpack_block_scaled(
            &self.codes,
            &self.shape,
            BLOCK,
            &self.scales,
            &|byte, l| e4m3::decode(byte) * s_global[l],
            Parallelism::Auto,
        )
        .expect("PackedTensor payload consistent with its shape");
        Tensor::new(data, self.shape.clone())
    }

    /// Payload bytes (codes + scales + globals) — the memory-footprint
    /// number reported in EXPERIMENTS.md.
    pub fn payload_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + self.s_global.len() * 4
    }

    /// Serialize to the legacy `.nvfp4` container: magic, rank, dims,
    /// globals, scales, codes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.payload_bytes() + 64);
        buf.extend_from_slice(b"NVF4");
        buf.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(self.s_global.len() as u32).to_le_bytes());
        for &g in &self.s_global {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        buf.extend_from_slice(&(self.scales.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.scales);
        buf.extend_from_slice(&(self.codes.len() as u64).to_le_bytes());
        buf.extend_from_slice(&self.codes);
        buf
    }

    /// Parse a packed NVFP4 payload — the legacy `NVF4` container or an
    /// NVFP4-tagged `FAQ1` container (what `pack_model` writes under the
    /// `.nvfp4` extension today). Every section length is bounds-checked
    /// and the payload is validated against the declared shape before
    /// use — truncated or inconsistent buffers return errors, never
    /// panic.
    pub fn from_bytes(buf: &[u8]) -> Result<PackedTensor> {
        let q = QuantTensor::from_bytes(buf)?;
        if q.format != FormatKind::Nvfp4 {
            bail!("not an NVFP4 payload (format {})", q.format.name());
        }
        Ok(PackedTensor { shape: q.shape, codes: q.codes, scales: q.scales, s_global: q.s_global })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    #[test]
    fn prepare_invariants() {
        let w = rand_w(&[64, 32], 1, 0.05);
        let p = prepare(&w);
        for i in 0..w.numel() {
            assert!(p.lower.data[i] <= p.upper.data[i]);
            assert!((0.0..=1.0).contains(&p.v_init.data[i]), "v_init oob");
            assert!(p.scale.data[i] >= 0.0);
            assert!(e2m1::NODES.contains(&p.lower.data[i]));
            assert!(e2m1::NODES.contains(&p.upper.data[i]));
        }
    }

    #[test]
    fn scale_block_structure() {
        let w = rand_w(&[32, 8], 2, 0.1);
        let (s, sg) = standard_scales(&w);
        assert_eq!(sg.len(), 1);
        // constant within a 16-block per column
        for col in 0..8 {
            for r in 1..16 {
                assert_eq!(s.data[r * 8 + col], s.data[col]);
                assert_eq!(s.data[(16 + r) * 8 + col], s.data[16 * 8 + col]);
            }
        }
    }

    #[test]
    fn stacked_slices_independent_globals() {
        let mut w = rand_w(&[2, 32, 8], 3, 0.05);
        // second slice much larger magnitudes
        for x in &mut w.data[32 * 8..] {
            *x *= 100.0;
        }
        let p = prepare(&w);
        assert_eq!(p.s_global.len(), 2);
        assert!(p.s_global[1] > p.s_global[0] * 50.0);
    }

    #[test]
    fn zero_block_safe() {
        let mut w = rand_w(&[32, 4], 4, 0.05);
        for col in 0..4 {
            for r in 0..16 {
                w.data[r * 4 + col] = 0.0;
            }
        }
        let p = prepare(&w);
        for col in 0..4 {
            assert_eq!(p.scale.data[col], 0.0);
            assert_eq!(p.v_init.data[col], 0.5); // degenerate interval
        }
        let q = rtn_quant(&w, &p);
        assert!(q.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rtn_equals_hard_of_vinit_threshold() {
        let w = rand_w(&[64, 16], 5, 0.05);
        let p = prepare(&w);
        let v_rtn = p.v_init.map(|v| if v > 0.5 { 1.0 } else { 0.0 });
        let a = rtn_quant(&w, &p);
        let b = hard_quant(&w, &p, &v_rtn);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn hard_quant_on_grid() {
        let w = rand_w(&[64, 16], 6, 0.2);
        let p = prepare(&w);
        let q = hard_quant(&w, &p, &p.v_init);
        for i in 0..q.numel() {
            if p.scale.data[i] > 0.0 {
                let wt = q.data[i].abs() / p.scale.data[i];
                let nearest =
                    e2m1::NODES.iter().map(|&n| (wt - n).abs()).fold(f32::INFINITY, f32::min);
                assert!(nearest < 1e-4, "off grid: {wt}");
            }
        }
    }

    #[test]
    fn rtn_minimizes_elementwise_error() {
        let w = rand_w(&[64, 16], 7, 0.1);
        let p = prepare(&w);
        let q_rtn = rtn_quant(&w, &p);
        let q_lo = hard_quant(&w, &p, &Tensor::zeros(&w.shape));
        let q_up = hard_quant(&w, &p, &Tensor::full(&w.shape, 1.0));
        for i in 0..w.numel() {
            let e = (q_rtn.data[i] - w.data[i]).abs();
            assert!(e <= (q_lo.data[i] - w.data[i]).abs() + 1e-6);
            assert!(e <= (q_up.data[i] - w.data[i]).abs() + 1e-6);
        }
    }

    #[test]
    fn pack_unpack_matches_hard_quant() {
        let w = rand_w(&[2, 32, 16], 8, 0.05);
        let p = prepare(&w);
        let v = p.v_init.map(|v| if v >= 0.5 { 1.0 } else { 0.0 });
        let packed = PackedTensor::pack(&w, &p, &v);
        let deq = packed.unpack();
        let expect = hard_quant(&w, &p, &v);
        for i in 0..w.numel() {
            assert!(
                (deq.data[i] - expect.data[i]).abs() <= 1e-6 * expect.data[i].abs().max(1e-6),
                "i={i}: {} vs {}",
                deq.data[i],
                expect.data[i]
            );
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let w = rand_w(&[32, 16], 9, 0.05);
        let p = prepare(&w);
        let packed = PackedTensor::pack(&w, &p, &p.v_init);
        let back = PackedTensor::from_bytes(&packed.to_bytes()).unwrap();
        assert_eq!(packed, back);
        assert!(PackedTensor::from_bytes(b"junk").is_err());
        // the FAQ1 container pack_model writes under .nvfp4 parses too
        let via_faq1 = PackedTensor::from_bytes(&packed.to_quant().to_bytes()).unwrap();
        assert_eq!(packed, via_faq1);
    }

    #[test]
    fn from_bytes_validates_payload_against_shape() {
        let w = rand_w(&[32, 16], 11, 0.05);
        let p = prepare(&w);
        let bytes = PackedTensor::pack(&w, &p, &p.v_init).to_bytes();
        // every truncation errors (no panics, no trusting the header)
        for cut in [3usize, 4, 7, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(PackedTensor::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // header lying about the code-section length errors too
        let mut lying = bytes.clone();
        let nc_off = bytes.len() - (32 * 16 / 2) - 8;
        lying[nc_off..nc_off + 8].copy_from_slice(&(u64::MAX).to_le_bytes());
        assert!(PackedTensor::from_bytes(&lying).is_err());
    }

    #[test]
    fn payload_is_4_25_bits_per_weight() {
        let w = rand_w(&[128, 64], 10, 0.05);
        let p = prepare(&w);
        let packed = PackedTensor::pack(&w, &p, &p.v_init);
        let bits = packed.payload_bytes() as f64 * 8.0 / w.numel() as f64;
        // 4 bits/code + 8 bits per 16-element block = 4.5 bits + f32 global
        assert!((4.4..4.7).contains(&bits), "bits/weight = {bits}");
    }
}
