//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Records the model config, the canonical weight layout
//! (names, shapes, init spec, quantized flags) and, for every artifact,
//! the exact positional input/output order.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Artifact tensor dtype (the runtime marshals only these two).
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
/// One artifact input/output: name, shape, dtype.
pub struct TensorSpec {
    /// tensor name as lowered
    pub name: String,
    /// static shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count of the spec'd shape.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.usize_arr()?,
            dtype: DType::parse(v.req("dtype")?.as_str()?)?,
        })
    }
}

#[derive(Clone, Debug)]
/// One AOT-lowered artifact: HLO file plus exact positional IO.
pub struct ArtifactSpec {
    /// artifact name (the manifest key)
    pub name: String,
    /// HLO text filename within the artifact directory
    pub file: String,
    /// positional inputs, in lowering order
    pub inputs: Vec<TensorSpec>,
    /// positional outputs, in lowering order
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Position of a named input, or error.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input '{name}'", self.name))
    }

    /// Position of a named output, or error.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output '{name}'", self.name))
    }
}

/// Weight-init spec parsed from the manifest ("normal:0.02",
/// "normal_scaled:0.02", "ones").
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    /// N(0, std).
    Normal(f32),
    /// std scaled by 1/sqrt(2 L) — residual-out projections
    NormalScaled(f32),
    /// All ones (norm gains).
    Ones,
}

impl Init {
    fn parse(s: &str) -> Result<Init> {
        if s == "ones" {
            return Ok(Init::Ones);
        }
        let (kind, std) = s.split_once(':').ok_or_else(|| anyhow!("bad init '{s}'"))?;
        let std: f32 = std.parse()?;
        match kind {
            "normal" => Ok(Init::Normal(std)),
            "normal_scaled" => Ok(Init::NormalScaled(std)),
            _ => bail!("bad init kind '{kind}'"),
        }
    }
}

#[derive(Clone, Debug)]
/// One model weight: name, shape, init recipe, quantization flag.
pub struct WeightSpec {
    /// canonical weight name
    pub name: String,
    /// weight shape (per-layer tensors stacked on a leading L axis)
    pub shape: Vec<usize>,
    /// initialization recipe
    pub init: Init,
    /// true for the NVFP4-target linears
    pub quantized: bool,
}

/// The model configuration as exported by configs.py.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// preset name
    pub name: String,
    /// vocabulary size
    pub vocab: usize,
    /// model width
    pub d_model: usize,
    /// decoder layers
    pub n_layers: usize,
    /// attention heads
    pub n_heads: usize,
    /// context window length
    pub seq_len: usize,
    /// NVFP4 block size the dims must tile (16)
    pub block: usize,
    /// SwiGLU hidden width
    pub mlp_hidden: usize,
    /// per-head width (`d_model / n_heads`)
    pub head_dim: usize,
    /// pretraining batch size
    pub train_batch: usize,
    /// evaluation batch size
    pub eval_batch: usize,
    /// calibration rows per stage-1 layer problem
    pub stage1_rows: usize,
    /// stage-2 batch size
    pub stage2_batch: usize,
}

/// One quantized linear: weight stack name + the capture tensor feeding it.
#[derive(Clone, Debug)]
pub struct QLinear {
    /// weight-stack name of this linear
    pub name: String,
    /// capture tensor feeding this linear
    pub capture: String,
    /// input (contraction) dimension
    pub k: usize,
    /// output dimension
    pub n: usize,
}

#[derive(Clone, Debug)]
/// The full artifact manifest: model config, weight layout,
/// quantized-linear map, capture points, and artifact IO specs.
pub struct Manifest {
    /// model configuration
    pub config: ModelConfig,
    /// canonical weight layout, in artifact parameter order
    pub weights: Vec<WeightSpec>,
    /// the quantized linears and their capture points
    pub qlinears: Vec<QLinear>,
    /// capture tensor names
    pub captures: Vec<String>,
    /// artifact specs by name
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse and validate a manifest from JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let c = v.req("config")?;
        let config = ModelConfig {
            name: c.req("name")?.as_str()?.to_string(),
            vocab: c.req("vocab")?.as_usize()?,
            d_model: c.req("d_model")?.as_usize()?,
            n_layers: c.req("n_layers")?.as_usize()?,
            n_heads: c.req("n_heads")?.as_usize()?,
            seq_len: c.req("seq_len")?.as_usize()?,
            block: c.req("block")?.as_usize()?,
            mlp_hidden: c.req("mlp_hidden")?.as_usize()?,
            head_dim: c.req("head_dim")?.as_usize()?,
            train_batch: c.req("train_batch")?.as_usize()?,
            eval_batch: c.req("eval_batch")?.as_usize()?,
            stage1_rows: c.req("stage1_rows")?.as_usize()?,
            stage2_batch: c.req("stage2_batch")?.as_usize()?,
        };

        let mut weights = vec![];
        for w in v.req("weights")?.as_arr()? {
            weights.push(WeightSpec {
                name: w.req("name")?.as_str()?.to_string(),
                shape: w.req("shape")?.usize_arr()?,
                init: Init::parse(w.req("init")?.as_str()?)?,
                quantized: w.req("quantized")?.as_bool()?,
            });
        }

        let mut qlinears = vec![];
        for q in v.req("qlinears")?.as_arr()? {
            qlinears.push(QLinear {
                name: q.req("name")?.as_str()?.to_string(),
                capture: q.req("capture")?.as_str()?.to_string(),
                k: q.req("k")?.as_usize()?,
                n: q.req("n")?.as_usize()?,
            });
        }

        let captures = v
            .req("captures")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in v.req("artifacts")?.as_obj()? {
            let inputs = a
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.req("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let m = Manifest { config, weights, qlinears, captures, artifacts };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        let c = &self.config;
        if c.d_model % c.block != 0 || c.mlp_hidden % c.block != 0 {
            bail!("dims not multiples of NVFP4 block {}", c.block);
        }
        if c.head_dim * c.n_heads != c.d_model {
            bail!("head_dim * n_heads != d_model");
        }
        for q in &self.qlinears {
            if !self.weights.iter().any(|w| w.name == q.name && w.quantized) {
                bail!("qlinear '{}' not a quantized weight", q.name);
            }
            if !self.captures.contains(&q.capture) {
                bail!("qlinear '{}' capture '{}' unknown", q.name, q.capture);
            }
        }
        for must in ["pretrain_step", "lm_fwd", "lm_fwd_aq", "lm_capture", "stage2_step"] {
            if !self.artifacts.contains_key(must) {
                bail!("manifest missing required artifact '{must}'");
            }
        }
        Ok(())
    }

    /// Spec of a named artifact, or error.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Spec of a named weight, or error.
    pub fn weight(&self, name: &str) -> Result<&WeightSpec> {
        self.weights
            .iter()
            .find(|w| w.name == name)
            .ok_or_else(|| anyhow!("unknown weight '{name}'"))
    }

    /// Distinct (k, n) shapes among quantized linears (stage-1 artifacts
    /// are emitted per shape).
    pub fn qshapes(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = vec![];
        for q in &self.qlinears {
            if !out.contains(&(q.k, q.n)) {
                out.push((q.k, q.n));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "config": {"name":"t","vocab":16,"d_model":32,"n_layers":1,"n_heads":2,
                 "seq_len":8,"block":16,"mlp_hidden":32,"head_dim":16,
                 "train_batch":2,"eval_batch":2,"stage1_rows":8,"stage2_batch":2},
      "weights": [
        {"name":"layers.wq","shape":[1,32,32],"init":"normal:0.02","quantized":true,"wd":true},
        {"name":"out_norm","shape":[32],"init":"ones","quantized":false,"wd":false}
      ],
      "qlinears": [{"name":"layers.wq","capture":"attn_in","k":32,"n":32}],
      "captures": ["attn_in"],
      "artifacts": {
        "pretrain_step": {"file":"p.hlo.txt","inputs":[{"name":"w","shape":[1,32,32],"dtype":"f32"}],
          "outputs":[{"name":"loss","shape":[],"dtype":"f32"}]},
        "lm_fwd": {"file":"f.hlo.txt","inputs":[],"outputs":[]},
        "lm_fwd_aq": {"file":"fa.hlo.txt","inputs":[],"outputs":[]},
        "lm_capture": {"file":"c.hlo.txt","inputs":[],"outputs":[]},
        "stage2_step": {"file":"s2.hlo.txt","inputs":[],"outputs":[]}
      }
    }"#;

    #[test]
    fn parse_mini() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.config.d_model, 32);
        assert_eq!(m.weights.len(), 2);
        assert_eq!(m.weight("out_norm").unwrap().init, Init::Ones);
        assert_eq!(m.qshapes(), vec![(32, 32)]);
        let a = m.artifact("pretrain_step").unwrap();
        assert_eq!(a.inputs[0].numel(), 1024);
        assert_eq!(a.input_index("w").unwrap(), 0);
        assert!(a.input_index("nope").is_err());
    }

    #[test]
    fn validation_catches_bad_block() {
        let bad = MINI.replace("\"d_model\":32", "\"d_model\":33");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn validation_requires_artifacts() {
        let bad = MINI.replace("\"stage2_step\"", "\"stage2_other\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn init_parsing() {
        assert_eq!(Init::parse("normal:0.02").unwrap(), Init::Normal(0.02));
        assert_eq!(Init::parse("normal_scaled:0.5").unwrap(), Init::NormalScaled(0.5));
        assert!(Init::parse("uniform:1").is_err());
    }
}
