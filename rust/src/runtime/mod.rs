//! PJRT runtime: load AOT-lowered HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 CPU PJRT). Executables are
//! compiled lazily on first use and cached for the process lifetime; all
//! argument marshalling is validated against the manifest so a shape
//! mismatch fails loudly in rust rather than deep inside XLA.
//!
//! `Value` is the host-side currency: an f32 tensor or an i32 tensor.
//! Outputs of an artifact come back as a flat `Vec<Value>` in manifest
//! order (the graphs are lowered with `return_tuple=True`; PJRT hands the
//! tuple back as a single literal which we decompose).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::tensor::Tensor;
pub use manifest::{ArtifactSpec, DType, Manifest, ModelConfig, QLinear, TensorSpec, WeightSpec};

/// Host value: what flows in and out of artifacts.
#[derive(Clone, Debug)]
pub enum Value {
    /// An f32 tensor.
    F32(Tensor),
    /// An i32 tensor as flat data plus shape.
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    /// A rank-0 f32 value.
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::scalar(x))
    }

    /// A rank-0 i32 value.
    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(vec![x], vec![])
    }

    /// The value's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(_, s) => s,
        }
    }

    /// The value's dtype.
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    /// Borrow as an f32 tensor, or error.
    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    /// Consume into an f32 tensor, or error.
    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    /// The single f32 element of a scalar value, or error.
    pub fn as_f32_scalar(&self) -> Result<f32> {
        let t = self.as_tensor()?;
        if t.numel() != 1 {
            bail!("expected scalar, got shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }

    /// Upload to a rust-owned device buffer (freed on Drop).
    ///
    /// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
    /// (literal path): its C wrapper `release()`s every input device
    /// buffer without freeing it — ~input-size bytes leaked per call,
    /// which is fatal for 10^4-step optimization loops. The `execute_b`
    /// path takes caller-owned buffers instead (see EXPERIMENTS.md §Perf).
    fn to_buffer(&self, client: &PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            Value::F32(t) => Ok(client.buffer_from_host_buffer(&t.data, &t.shape, None)?),
            Value::I32(data, shape) => {
                Ok(client.buffer_from_host_buffer(data, shape, None)?)
            }
        }
    }

    fn from_literal(lit: &Literal, spec: &TensorSpec) -> Result<Value> {
        match spec.dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()?;
                if data.len() != spec.numel() {
                    bail!(
                        "output '{}': got {} elements, expected {}",
                        spec.name,
                        data.len(),
                        spec.numel()
                    );
                }
                Ok(Value::F32(Tensor::new(data, spec.shape.clone())))
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I32(data, spec.shape.clone()))
            }
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// Compiled-executable cache + manifest for one artifact directory.
pub struct Runtime {
    /// the PJRT client every executable runs on
    pub client: PjRtClient,
    /// artifact directory (`artifacts/<config>/`)
    pub dir: PathBuf,
    /// the artifact manifest loaded from that directory
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative executions per artifact (metrics)
    exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Load the runtime for `artifacts/<config>/`.
    pub fn load(artifact_root: &Path, config: &str) -> Result<Runtime> {
        let dir = artifact_root.join(config);
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    /// The model configuration from the manifest.
    pub fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let (exe, secs) = crate::util::timed(|| -> Result<_> {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?)
        });
        let exe = Rc::new(exe?);
        crate::debug!("compiled artifact '{name}' in {secs:.2}s");
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (pipeline warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// True when the manifest lowered an artifact under `name` (batched
    /// serve variants like `lm_logits_pos_aq_b4` are optional per preset).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Execute an artifact with positional values; returns outputs in
    /// manifest order. Validates shapes and dtypes on the way in.
    pub fn exec(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        if args.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': {} args given, {} expected",
                args.len(),
                spec.inputs.len()
            );
        }
        check_args(&spec, &spec.inputs, args)?;
        let buffers: Vec<xla::PjRtBuffer> =
            args.iter().map(|v| v.to_buffer(&self.client)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        self.run_buffers(&spec, &refs)
    }

    /// Upload a shared argument *prefix* (typically the full weight set)
    /// to device buffers once, returning a handle that executes with only
    /// the per-call tail marshalled. This is the batched-serving entry:
    /// a decode step re-sends tokens + positions (a few KiB) instead of
    /// the whole model (MiBs) on every scheduler tick.
    pub fn prepare(&self, name: &str, prefix: &[Value]) -> Result<PreparedExec> {
        Ok(self.prepare_many(&[name], prefix)?.pop().expect("one name, one handle"))
    }

    /// Like [`Self::prepare`], but binds several artifacts that take the
    /// same leading inputs (e.g. the single-request and batched serve
    /// variants, which all start with the full weight set) to ONE
    /// uploaded copy of the prefix — the device holds the weights once,
    /// not once per artifact.
    pub fn prepare_many(&self, names: &[&str], prefix: &[Value]) -> Result<Vec<PreparedExec>> {
        let buffers: Rc<Vec<xla::PjRtBuffer>> = Rc::new(
            prefix.iter().map(|v| v.to_buffer(&self.client)).collect::<Result<_>>()?,
        );
        names
            .iter()
            .map(|name| {
                let spec = self.manifest.artifact(name)?.clone();
                if prefix.len() > spec.inputs.len() {
                    bail!(
                        "artifact '{name}': prefix of {} args for {} inputs",
                        prefix.len(),
                        spec.inputs.len()
                    );
                }
                check_args(&spec, &spec.inputs[..prefix.len()], prefix)?;
                let exe = self.executable(name)?;
                Ok(PreparedExec { spec, exe, prefix: buffers.clone() })
            })
            .collect()
    }

    /// Shared back half of every execution path: run the executable over
    /// already-uploaded buffers and decompose the output tuple.
    fn run_buffers(&self, spec: &ArtifactSpec, buffers: &[&xla::PjRtBuffer]) -> Result<Vec<Value>> {
        let name = &spec.name;
        let exe = self.executable(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(buffers)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        *self.exec_counts.borrow_mut().entry(name.clone()).or_insert(0) += 1;

        let tuple_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} outputs: {e}"))?;
        let parts = tuple_lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': {} outputs returned, {} expected",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                Value::from_literal(lit, ospec)
                    .with_context(|| format!("artifact '{name}' output '{}'", ospec.name))
            })
            .collect()
    }

    /// Execution counters (for metrics / EXPERIMENTS.md).
    pub fn exec_counts(&self) -> HashMap<String, u64> {
        self.exec_counts.borrow().clone()
    }
}

fn check_args(spec: &ArtifactSpec, ispecs: &[TensorSpec], args: &[Value]) -> Result<()> {
    for (v, ispec) in args.iter().zip(ispecs) {
        if v.shape() != ispec.shape.as_slice() {
            bail!(
                "artifact '{}' input '{}': shape {:?} != expected {:?}",
                spec.name,
                ispec.name,
                v.shape(),
                ispec.shape
            );
        }
        if v.dtype() != ispec.dtype {
            bail!("artifact '{}' input '{}': dtype mismatch", spec.name, ispec.name);
        }
    }
    Ok(())
}

/// An artifact with a shared argument prefix resident on device. Created
/// by [`Runtime::prepare`]/[`Runtime::prepare_many`] (handles from one
/// `prepare_many` call share the uploaded prefix); not `Send` (device
/// buffers belong to the thread that owns the PJRT client, i.e. the
/// scheduler thread).
pub struct PreparedExec {
    spec: ArtifactSpec,
    #[allow(dead_code)] // keeps the compiled executable alive with its buffers
    exe: Rc<PjRtLoadedExecutable>,
    prefix: Rc<Vec<xla::PjRtBuffer>>,
}

impl PreparedExec {
    /// Number of per-call tail arguments this handle still expects.
    pub fn n_tail(&self) -> usize {
        self.spec.inputs.len() - self.prefix.len()
    }

    /// Execute with the per-call tail; the prefix rides along from device
    /// memory. Validates the tail against the manifest like `exec`.
    pub fn exec(&self, rt: &Runtime, tail: &[Value]) -> Result<Vec<Value>> {
        if tail.len() != self.n_tail() {
            bail!(
                "artifact '{}': {} tail args given, {} expected",
                self.spec.name,
                tail.len(),
                self.n_tail()
            );
        }
        check_args(&self.spec, &self.spec.inputs[self.prefix.len()..], tail)?;
        let tail_bufs: Vec<xla::PjRtBuffer> =
            tail.iter().map(|v| v.to_buffer(&rt.client)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> =
            self.prefix.iter().chain(tail_bufs.iter()).collect();
        rt.run_buffers(&self.spec, &refs)
    }
}

/// Helper: pull a named output out of an exec() result.
pub fn take_output(
    spec: &ArtifactSpec,
    outputs: &mut Vec<Value>,
    name: &str,
) -> Result<Value> {
    let idx = spec.output_index(name)?;
    Ok(outputs[idx].clone())
}
