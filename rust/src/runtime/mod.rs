//! PJRT runtime: load AOT-lowered HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 CPU PJRT). Executables are
//! compiled lazily on first use and cached for the process lifetime; all
//! argument marshalling is validated against the manifest so a shape
//! mismatch fails loudly in rust rather than deep inside XLA.
//!
//! `Value` is the host-side currency: an f32 tensor or an i32 tensor.
//! Outputs of an artifact come back as a flat `Vec<Value>` in manifest
//! order (the graphs are lowered with `return_tuple=True`; PJRT hands the
//! tuple back as a single literal which we decompose).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::tensor::Tensor;
pub use manifest::{ArtifactSpec, DType, Manifest, ModelConfig, QLinear, TensorSpec, WeightSpec};

/// Host value: what flows in and out of artifacts.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::scalar(x))
    }

    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(vec![x], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_f32_scalar(&self) -> Result<f32> {
        let t = self.as_tensor()?;
        if t.numel() != 1 {
            bail!("expected scalar, got shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }

    /// Upload to a rust-owned device buffer (freed on Drop).
    ///
    /// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
    /// (literal path): its C wrapper `release()`s every input device
    /// buffer without freeing it — ~input-size bytes leaked per call,
    /// which is fatal for 10^4-step optimization loops. The `execute_b`
    /// path takes caller-owned buffers instead (see EXPERIMENTS.md §Perf).
    fn to_buffer(&self, client: &PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            Value::F32(t) => Ok(client.buffer_from_host_buffer(&t.data, &t.shape, None)?),
            Value::I32(data, shape) => {
                Ok(client.buffer_from_host_buffer(data, shape, None)?)
            }
        }
    }

    fn from_literal(lit: &Literal, spec: &TensorSpec) -> Result<Value> {
        match spec.dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()?;
                if data.len() != spec.numel() {
                    bail!(
                        "output '{}': got {} elements, expected {}",
                        spec.name,
                        data.len(),
                        spec.numel()
                    );
                }
                Ok(Value::F32(Tensor::new(data, spec.shape.clone())))
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I32(data, spec.shape.clone()))
            }
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// Compiled-executable cache + manifest for one artifact directory.
pub struct Runtime {
    pub client: PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative executions per artifact (metrics)
    exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Load the runtime for `artifacts/<config>/`.
    pub fn load(artifact_root: &Path, config: &str) -> Result<Runtime> {
        let dir = artifact_root.join(config);
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let (exe, secs) = crate::util::timed(|| -> Result<_> {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?)
        });
        let exe = Rc::new(exe?);
        crate::debug!("compiled artifact '{name}' in {secs:.2}s");
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (pipeline warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with positional values; returns outputs in
    /// manifest order. Validates shapes and dtypes on the way in.
    pub fn exec(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        if args.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': {} args given, {} expected",
                args.len(),
                spec.inputs.len()
            );
        }
        for (v, ispec) in args.iter().zip(&spec.inputs) {
            if v.shape() != ispec.shape.as_slice() {
                bail!(
                    "artifact '{name}' input '{}': shape {:?} != expected {:?}",
                    ispec.name,
                    v.shape(),
                    ispec.shape
                );
            }
            if v.dtype() != ispec.dtype {
                bail!("artifact '{name}' input '{}': dtype mismatch", ispec.name);
            }
        }
        let exe = self.executable(name)?;
        let buffers: Vec<xla::PjRtBuffer> =
            args.iter().map(|v| v.to_buffer(&self.client)).collect::<Result<_>>()?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;

        let tuple_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} outputs: {e}"))?;
        let parts = tuple_lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': {} outputs returned, {} expected",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                Value::from_literal(lit, ospec)
                    .with_context(|| format!("artifact '{name}' output '{}'", ospec.name))
            })
            .collect()
    }

    /// Execution counters (for metrics / EXPERIMENTS.md).
    pub fn exec_counts(&self) -> HashMap<String, u64> {
        self.exec_counts.borrow().clone()
    }
}

/// Helper: pull a named output out of an exec() result.
pub fn take_output(
    spec: &ArtifactSpec,
    outputs: &mut Vec<Value>,
    name: &str,
) -> Result<Value> {
    let idx = spec.output_index(name)?;
    Ok(outputs[idx].clone())
}
