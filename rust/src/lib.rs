//! # nvfp4-faar
//!
//! Full-system reproduction of **"FAAR: Format-Aware Adaptive Rounding for
//! NVFP4"** (Li Auto Inc., 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the runtime coordinator: config system, synthetic
//!   data substrate, the pluggable 4-bit format layer
//!   ([`formats::codec::FormatCodec`] + packed [`formats::QuantTensor`] as
//!   the canonical quantized representation), GPTQ/RTN/4-6 baselines, the
//!   FAAR + 2FA quantization pipeline, evaluation harness, table
//!   reproduction, and a small inference server that holds models packed.
//!   Python never runs here.
//! * **L2 (python/compile)** — JAX graphs (Llama-style decoder, pretrain /
//!   stage-1 / stage-2 optimization steps) AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the paper's
//!   compute hot-spot (format-aware soft-quant), lowered into the same HLO.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

// Index-heavy numeric kernels: iterating several parallel arrays by index
// is the idiom here, and the hot signatures mirror the AOT artifacts.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// Every public item carries rustdoc; CI builds `cargo doc --no-deps` with
// `-D warnings`, so a missing doc is a build failure there, not just lint
// noise here.
#![warn(missing_docs)]

pub mod calib;
pub mod config;
pub mod data;
pub mod eval;
pub mod formats;
pub mod gptq;
pub mod infer;
pub mod pipeline;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
