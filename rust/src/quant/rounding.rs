//! Rounding schemes over a prepared interval context (Table 1).
//!
//! All schemes produce a binary decision tensor `v` (1 → upper node) that
//! plugs into `formats::codec::hard_quant` — they are format-agnostic:
//! any [`crate::formats::FormatCodec`]'s `Prepared` context works.
//! Stochastic rounding picks the upper node with probability = relative
//! position in the interval (unbiased: E[q] = w̃).

use crate::formats::codec::{hard_quant, rtn_decisions, Prepared};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Elementwise rounding decision rules (Table 1's comparison axis).
pub enum RoundingScheme {
    /// nearest node, ties → lower (the paper's baseline)
    Rtn,
    /// always the lower enclosing node
    Lower,
    /// always the upper enclosing node
    Upper,
    /// upper with probability v_init (seeded)
    Stochastic(u64),
}

impl RoundingScheme {
    /// Canonical scheme name (table row labels).
    pub fn name(&self) -> String {
        match self {
            RoundingScheme::Rtn => "rtn".into(),
            RoundingScheme::Lower => "lower".into(),
            RoundingScheme::Upper => "upper".into(),
            RoundingScheme::Stochastic(s) => format!("stochastic[{s}]"),
        }
    }

    /// Binary decisions for this scheme.
    pub fn decisions(&self, p: &Prepared) -> Tensor {
        match self {
            RoundingScheme::Rtn => rtn_decisions(p),
            RoundingScheme::Lower => Tensor::zeros(&p.v_init.shape),
            RoundingScheme::Upper => Tensor::full(&p.v_init.shape, 1.0),
            RoundingScheme::Stochastic(seed) => {
                let mut rng = Rng::new(*seed);
                p.v_init.map(|v| if rng.f64() < v as f64 { 1.0 } else { 0.0 })
            }
        }
    }
}

/// Dequantized weights under a rounding scheme.
pub fn round_with(w: &Tensor, p: &Prepared, scheme: RoundingScheme) -> Tensor {
    hard_quant(w, p, &scheme.decisions(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::nvfp4::prepare;
    use crate::util::stats::mse;

    fn rand_w(seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(&[64, 32]);
        rng.fill_normal(&mut t.data, 0.0, 0.05);
        t
    }

    #[test]
    fn rtn_beats_lower_and_upper_on_mse() {
        let w = rand_w(1);
        let p = prepare(&w);
        let rtn = mse(&round_with(&w, &p, RoundingScheme::Rtn).data, &w.data);
        let lo = mse(&round_with(&w, &p, RoundingScheme::Lower).data, &w.data);
        let up = mse(&round_with(&w, &p, RoundingScheme::Upper).data, &w.data);
        assert!(rtn <= lo && rtn <= up, "rtn {rtn} lo {lo} up {up}");
    }

    #[test]
    fn lower_never_exceeds_magnitude() {
        let w = rand_w(2);
        let p = prepare(&w);
        let q = round_with(&w, &p, RoundingScheme::Lower);
        for i in 0..w.numel() {
            // lower node magnitude <= |w~| (modulo scale clamp)
            assert!(q.data[i].abs() <= w.data[i].abs() + 1e-6);
        }
    }

    #[test]
    fn stochastic_seeded_reproducible() {
        let w = rand_w(3);
        let p = prepare(&w);
        let a = round_with(&w, &p, RoundingScheme::Stochastic(7));
        let b = round_with(&w, &p, RoundingScheme::Stochastic(7));
        let c = round_with(&w, &p, RoundingScheme::Stochastic(8));
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn stochastic_unbiased() {
        // average many stochastic quantizations → approaches w (in the
        // non-clipped region)
        let w = rand_w(4);
        let p = prepare(&w);
        let n = 200;
        let mut acc = vec![0.0f64; w.numel()];
        for s in 0..n {
            let q = round_with(&w, &p, RoundingScheme::Stochastic(s as u64));
            for i in 0..w.numel() {
                acc[i] += q.data[i] as f64;
            }
        }
        let mut bias = 0.0f64;
        let mut count = 0;
        for i in 0..w.numel() {
            let wt = w.data[i].abs() / p.scale.data[i].max(1e-30);
            if wt < 5.9 && p.scale.data[i] > 0.0 {
                bias += acc[i] / n as f64 - w.data[i] as f64;
                count += 1;
            }
        }
        let mean_bias = (bias / count as f64).abs();
        assert!(mean_bias < 5e-4, "mean bias {mean_bias}");
    }

    #[test]
    fn some_stochastic_trial_differs_from_rtn() {
        let w = rand_w(5);
        let p = prepare(&w);
        let rtn = round_with(&w, &p, RoundingScheme::Rtn);
        let st = round_with(&w, &p, RoundingScheme::Stochastic(1));
        assert_ne!(rtn.data, st.data);
    }

    #[test]
    fn names() {
        assert_eq!(RoundingScheme::Rtn.name(), "rtn");
        assert_eq!(RoundingScheme::Stochastic(3).name(), "stochastic[3]");
    }
}
