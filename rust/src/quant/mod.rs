//! Quantization strategies on the NVFP4 grid.
//!
//! * [`scaling`] — block-scale selection: standard amax/6, the "4/6"
//!   adaptive choice (paper baseline [23]), and the strong-baseline
//!   MSE-optimal scale search.
//! * [`rounding`] — rounding schemes over a prepared interval context:
//!   RTN, always-lower, always-upper, stochastic (Table 1), and FAAR
//!   hardening.
//!
//! The FAAR *learning* itself runs through the AOT stage-1/stage-2 graphs
//! (pipeline/); this module covers everything training-free.

pub mod rounding;
pub mod scaling;

pub use rounding::{round_with, RoundingScheme};
pub use scaling::{prepare_with_method, scales_for};
